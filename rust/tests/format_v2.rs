//! Cross-format integration tests: the v2 (delta+varint) image must be
//! byte-smaller than v1, read less, convert losslessly in both
//! directions, open transparently through every layer (CLI path,
//! service registry), and — the load-bearing property — every algorithm
//! must produce identical results on v1 and v2 images of the same
//! graph, both matching the in-memory oracle.

use std::path::PathBuf;

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::bfs::bfs;
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::louvain::{louvain, LouvainMode};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::sssp::sssp;
use graphyti::algs::triangles::{triangles, TriangleOptions};
use graphyti::algs::wcc::wcc;
use graphyti::coordinator::RunConfig;
use graphyti::graph::builder::{convert_image, GraphBuilder};
use graphyti::graph::csr::Csr;
use graphyti::graph::format::{EdgeRequest, GraphIndex, VERSION_V1, VERSION_V2};
use graphyti::graph::gen;
use graphyti::graph::source::{EdgeSource, SemGraph};
use graphyti::safs::IoConfig;
use graphyti::service::GraphRegistry;
use graphyti::VertexId;

fn build_image(
    n: usize,
    edges: &[(VertexId, VertexId)],
    directed: bool,
    version: u32,
    tag: &str,
) -> PathBuf {
    let base = std::env::temp_dir().join(format!(
        "graphyti-fmt2-{}-{tag}-v{version}",
        std::process::id()
    ));
    let mut b = GraphBuilder::new(n, directed);
    b.add_edges(edges).format_version(version);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

fn adj_len(base: &PathBuf) -> u64 {
    std::fs::metadata(base.with_extension("gy-adj")).unwrap().len()
}

#[test]
fn all_algorithms_identical_on_v1_and_v2() {
    let n = 1024;
    let edges = gen::rmat(10, 12_000, 77);
    let csr_d = Csr::from_edges(n, &edges, true);
    let csr_u = Csr::from_edges(n, &edges, false);
    let cfg = RunConfig { cache_mb: 1, io_threads: 3, ..Default::default() };
    let ecfg = cfg.engine();

    let mut bases = Vec::new();
    for version in [VERSION_V1, VERSION_V2] {
        let base_d = build_image(n, &edges, true, version, "algs-d");
        let base_u = build_image(n, &edges, false, version, "algs-u");
        let gd = SemGraph::open(&base_d, 64 * 4096, cfg.io()).unwrap();
        let gu = SemGraph::open(&base_u, 64 * 4096, cfg.io()).unwrap();

        let (lv, _) = bfs(&gd, 0, &ecfg);
        assert_eq!(lv, oracle::bfs_levels(&csr_d, 0), "bfs v{version}");

        let (dist, _) = sssp(&gd, 0, &ecfg);
        assert_eq!(dist, oracle::sssp(&csr_d, 0), "sssp v{version}");

        let (labels, _) = wcc(&gd, &ecfg);
        assert_eq!(labels, oracle::wcc(&csr_d), "wcc v{version}");

        let r = pagerank_push(&gd, 0.85, 1e-12, &ecfg);
        let want = oracle::pagerank(&csr_d, 0.85, 200);
        let l1: f64 = r.rank.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "pagerank v{version}: L1 {l1}");

        assert_eq!(
            coreness(&gu, CorenessOptions::graphyti(), &ecfg).core,
            oracle::coreness(&csr_u),
            "coreness v{version}"
        );

        assert_eq!(
            triangles(&gu, TriangleOptions::graphyti(), &ecfg).triangles,
            oracle::triangle_count(&csr_u),
            "triangles v{version}"
        );

        let sources: Vec<VertexId> = vec![0, 1, 2, 5, 17];
        let want_bc = oracle::betweenness(&csr_d, &sources);
        let got = betweenness(&gd, &sources, BcVariant::MultiSourceAsync, &ecfg);
        for (i, (a, b)) in got.bc.iter().zip(&want_bc).enumerate() {
            assert!(
                (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                "bc v{version} [{i}]: {a} vs {b}"
            );
        }

        let r = louvain(&gu, LouvainMode::Graphyti, 8, &ecfg);
        let q = oracle::modularity(&csr_u, &r.community);
        assert!((r.modularity - q).abs() < 1e-6, "louvain v{version}: {} vs {q}", r.modularity);

        bases.push(base_d);
        bases.push(base_u);
    }
    // v2 must actually be smaller on disk (directed and undirected)
    assert!(adj_len(&bases[2]) * 2 < adj_len(&bases[0]), "directed v2 not small enough");
    assert!(adj_len(&bases[3]) * 2 < adj_len(&bases[1]), "undirected v2 not small enough");
    for b in &bases {
        cleanup(b);
    }
}

#[test]
fn v2_reads_fewer_bytes_under_cache_pressure() {
    let n = 2048;
    let edges = gen::rmat(11, 30_000, 5);
    let base1 = build_image(n, &edges, true, VERSION_V1, "press");
    let base2 = build_image(n, &edges, true, VERSION_V2, "press");
    let cfg = RunConfig { cache_mb: 1, io_threads: 3, ..Default::default() };
    // identical tiny cache (16 pages) for both formats: constant eviction
    let cache = 16 * 4096;
    let g1 = SemGraph::open(&base1, cache, cfg.io()).unwrap();
    let g2 = SemGraph::open(&base2, cache, cfg.io()).unwrap();
    let r1 = pagerank_push(&g1, 0.85, 1e-10, &cfg.engine());
    let r2 = pagerank_push(&g2, 0.85, 1e-10, &cfg.engine());
    // same fixpoint
    let l1: f64 = r1.rank.iter().zip(&r2.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-9, "formats must not change results: L1 {l1}");
    // compressed image => strictly less data crosses every boundary
    assert!(
        r2.report.io.logical_bytes < r1.report.io.logical_bytes,
        "logical: v2 {} !< v1 {}",
        r2.report.io.logical_bytes,
        r1.report.io.logical_bytes
    );
    assert!(
        r2.report.io.bytes_read < r1.report.io.bytes_read,
        "disk: v2 {} !< v1 {}",
        r2.report.io.bytes_read,
        r1.report.io.bytes_read
    );
    cleanup(&base1);
    cleanup(&base2);
}

#[test]
fn convert_files_roundtrip_preserves_graph_exactly() {
    let n = 512;
    let edges = gen::rmat(9, 6000, 21);
    let v1 = build_image(n, &edges, true, VERSION_V1, "conv");
    let v2 = std::env::temp_dir()
        .join(format!("graphyti-fmt2-{}-conv-out-v2", std::process::id()));
    let back = std::env::temp_dir()
        .join(format!("graphyti-fmt2-{}-conv-back-v1", std::process::id()));
    convert_image(&v1, &v2, VERSION_V2).unwrap();
    convert_image(&v2, &back, VERSION_V1).unwrap();
    // the double conversion restores both files byte-for-byte
    assert_eq!(
        std::fs::read(v1.with_extension("gy-idx")).unwrap(),
        std::fs::read(back.with_extension("gy-idx")).unwrap()
    );
    assert_eq!(
        std::fs::read(v1.with_extension("gy-adj")).unwrap(),
        std::fs::read(back.with_extension("gy-adj")).unwrap()
    );
    // and the v2 image decodes to the same per-vertex lists via SEM
    let cfg = RunConfig::default();
    let g1 = SemGraph::open(&v1, 64 * 4096, cfg.io()).unwrap();
    let g2 = SemGraph::open(&v2, 64 * 4096, cfg.io()).unwrap();
    assert_eq!(g1.index().num_edges(), g2.index().num_edges());
    for v in 0..n as VertexId {
        let a = g1.fetch(v, EdgeRequest::Both).unwrap();
        let b = g2.fetch(v, EdgeRequest::Both).unwrap();
        assert_eq!(a.in_neighbors, b.in_neighbors, "v={v}");
        assert_eq!(a.out_neighbors, b.out_neighbors, "v={v}");
    }
    for b in [&v1, &v2, &back] {
        cleanup(b);
    }
}

#[test]
fn registry_opens_v2_images_transparently() {
    let n = 256;
    let edges = gen::rmat(8, 1500, 3);
    let base = build_image(n, &edges, true, VERSION_V2, "reg");
    let reg = GraphRegistry::new(64 * 4096, IoConfig::default());
    let g = reg.open(&base).unwrap();
    assert_eq!(g.index().header().version, VERSION_V2);
    let csr = Csr::from_edges(n, &edges, true);
    for v in (0..n as VertexId).step_by(17) {
        let e = g.fetch(v, EdgeRequest::Both).unwrap();
        assert_eq!(e.out_neighbors, csr.out(v), "v={v}");
        assert_eq!(e.in_neighbors, csr.inn(v), "v={v}");
    }
    cleanup(&base);
}

#[test]
fn v2_index_decodes_from_disk_with_section_lengths() {
    let n = 128;
    let edges = gen::rmat(7, 900, 13);
    let base = build_image(n, &edges, true, VERSION_V2, "idx");
    let idx = GraphIndex::decode(&std::fs::read(base.with_extension("gy-idx")).unwrap()).unwrap();
    assert_eq!(idx.header().version, VERSION_V2);
    let adj = std::fs::read(base.with_extension("gy-adj")).unwrap();
    // the last vertex's record must end exactly at the end of the data
    // region: stored section lengths and offsets tile the adjacency
    // data with no gaps (the checksum footer, when present, sits after)
    let data_len = if idx.header().checksums {
        graphyti::graph::format::ChecksumFooter::from_bytes(&adj).unwrap().data_len
    } else {
        adj.len() as u64
    };
    let mut expected_off = 0u64;
    for v in 0..n as VertexId {
        let (off, len) = idx.byte_range(v, EdgeRequest::Both);
        assert_eq!(off, expected_off, "records must be contiguous at v={v}");
        expected_off = off + len as u64;
    }
    assert_eq!(expected_off, data_len);
    cleanup(&base);
}
