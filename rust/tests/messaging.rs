//! Transport-equivalence and message-memory tests for the engine's two
//! message lanes (combiner vs queue).
//!
//! Every combinable algorithm must produce *identical* results on the
//! dense combiner lanes and on the queue-lane baseline (bit-identical
//! for integer state, oracle-tight for floats), at 1/2/8 workers, on
//! both a star (worst-case skew: the whole frontier funnels through one
//! hub) and an R-MAT power-law graph. On top of that, the combiner
//! path's peak message memory must be O(n): independent of the edge
//! factor at fixed n.

use graphyti::algs::bfs::{bfs, ms_bfs};
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::{pagerank_pull, pagerank_push};
use graphyti::algs::sssp::sssp;
use graphyti::algs::wcc::wcc;
use graphyti::engine::{EngineConfig, TransportMode};
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::MemGraph;
use graphyti::VertexId;

const WORKER_COUNTS: [usize; 3] = [1, 2, 8];
const TRANSPORTS: [TransportMode; 2] = [TransportMode::Queue, TransportMode::Auto];

fn cfg(workers: usize, transport: TransportMode) -> EngineConfig {
    EngineConfig { workers, transport, batch: 64, ..Default::default() }
}

fn l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Integer-state algorithms: results must be bit-identical across both
/// transports and all worker counts, and match the in-memory oracle.
#[test]
fn integer_algorithms_bit_identical_across_transports() {
    let rmat = gen::rmat(9, 4000, 33);
    let star = gen::star(512);
    for (name, edges) in [("rmat", &rmat), ("star", &star)] {
        let n = 512;
        let csr_d = Csr::from_edges(n, edges, true);
        let want_bfs = oracle::bfs_levels(&csr_d, 0);
        let want_sssp = oracle::sssp(&csr_d, 0);
        let want_wcc = oracle::wcc(&csr_d);
        for workers in WORKER_COUNTS {
            for transport in TRANSPORTS {
                let tag = format!("{name} workers={workers} transport={transport:?}");
                let g = MemGraph::from_edges(n, edges, true);
                let e = cfg(workers, transport);
                assert_eq!(bfs(&g, 0, &e).0, want_bfs, "bfs {tag}");
                assert_eq!(sssp(&g, 0, &e).0, want_sssp, "sssp {tag}");
                assert_eq!(wcc(&g, &e).0, want_wcc, "wcc {tag}");
            }
        }
    }
}

/// Coreness: decrement counts fold by addition on the combiner path —
/// the peel must come out identical to the queue path and the oracle
/// for every messaging discipline (p2p / multicast / hybrid).
#[test]
fn coreness_decrement_folds_match_queue_path() {
    let edges = gen::rmat(9, 5000, 29);
    let n = 512;
    let csr = Csr::from_edges(n, &edges, false);
    let want = oracle::coreness(&csr);
    for opts in [
        CorenessOptions::unoptimized(),
        CorenessOptions::pruned(),
        CorenessOptions::graphyti(),
    ] {
        for workers in WORKER_COUNTS {
            for transport in TRANSPORTS {
                let g = MemGraph::from_edges(n, &edges, false);
                let r = coreness(&g, opts, &cfg(workers, transport));
                assert_eq!(
                    r.core, want,
                    "coreness {opts:?} workers={workers} transport={transport:?}"
                );
            }
        }
    }
}

/// Multi-source BFS lane bitsets fold by OR; eccentricities (and hence
/// diameter estimates) must be transport- and worker-count-invariant.
#[test]
fn ms_bfs_or_folds_match_queue_path() {
    let edges = gen::rmat(9, 4000, 61);
    let n = 512;
    let csr = Csr::from_edges(n, &edges, true);
    let sources: Vec<VertexId> = vec![0, 3, 17, 42, 99, 256];
    let want: Vec<i64> = sources.iter().map(|&s| oracle::eccentricity(&csr, s)).collect();
    for workers in WORKER_COUNTS {
        for transport in TRANSPORTS {
            let g = MemGraph::from_edges(n, &edges, true);
            let (ecc, _) = ms_bfs(&g, &sources, &cfg(workers, transport));
            assert_eq!(ecc, want, "workers={workers} transport={transport:?}");
        }
    }
}

/// PageRank (float mass): both transports and all worker counts must be
/// oracle-tight; the two transports must agree to well under the
/// convergence tolerance.
#[test]
fn pagerank_oracle_tight_on_both_transports() {
    let edges = gen::rmat(9, 4000, 45);
    let n = 512;
    let csr = Csr::from_edges(n, &edges, true);
    let want = oracle::pagerank(&csr, 0.85, 200);
    for workers in WORKER_COUNTS {
        let mut per_transport: Vec<Vec<f64>> = Vec::new();
        for transport in TRANSPORTS {
            let g = MemGraph::from_edges(n, &edges, true);
            let e = cfg(workers, transport);
            let push = pagerank_push(&g, 0.85, 1e-12, &e);
            let pull = pagerank_pull(&g, 0.85, 1e-12, 500, &e);
            assert!(
                l1(&push.rank, &want) < 1e-6,
                "push workers={workers} transport={transport:?} L1 {}",
                l1(&push.rank, &want)
            );
            assert!(
                l1(&pull.rank, &want) < 1e-6,
                "pull workers={workers} transport={transport:?} L1 {}",
                l1(&pull.rank, &want)
            );
            per_transport.push(push.rank);
        }
        let cross = l1(&per_transport[0], &per_transport[1]);
        assert!(cross < 1e-8, "transports disagree beyond fold-order noise: {cross}");
    }
}

/// The acceptance bound: combiner-lane peak message bytes at fixed n
/// must not move when the edge count quadruples, and must stay within a
/// small multiple of n × 4 B — while the counters prove the combiner
/// path actually ran (folds > 0, allocation-free).
#[test]
fn combiner_message_memory_is_o_n_not_o_m() {
    let n = 512;
    let workers = 2;
    let mut pr_peaks = Vec::new();
    let mut wcc_peaks = Vec::new();
    for ef in [4usize, 16] {
        let edges = gen::rmat(9, n * ef, 7);
        let g = MemGraph::from_edges(n, &edges, true);
        let e = cfg(workers, TransportMode::Auto);
        let pr = pagerank_push(&g, 0.85, 1e-9, &e).report;
        assert!(pr.engine.combined_msgs > 0, "ef={ef}: PR must fold on the combiner path");
        assert_eq!(pr.engine.msg_allocs, 0, "combiner path allocates nothing");
        pr_peaks.push(pr.engine.peak_msg_bytes);
        let (_, wr) = wcc(&g, &e);
        assert!(wr.engine.combined_msgs > 0, "ef={ef}: WCC must fold on the combiner path");
        wcc_peaks.push(wr.engine.peak_msg_bytes);
    }
    assert_eq!(pr_peaks[0], pr_peaks[1], "PR message memory must not scale with edges");
    assert_eq!(wcc_peaks[0], wcc_peaks[1], "WCC message memory must not scale with edges");
    // small multiple of n × size_of::<f32>(): 3 × workers × 8 B/vertex
    // = 12 × (n × 4 B) at 2 workers
    let bound = (3 * workers * std::mem::size_of::<f64>() * n) as u64;
    assert!(pr_peaks[0] > 0 && pr_peaks[0] <= bound, "peak {} bound {bound}", pr_peaks[0]);
    // the queue baseline on the same PR workload allocates real segment
    // memory and combines nothing — the counters tell the paths apart
    let edges = gen::rmat(9, n * 16, 7);
    let g = MemGraph::from_edges(n, &edges, true);
    let qr = pagerank_push(&g, 0.85, 1e-9, &cfg(workers, TransportMode::Queue)).report;
    assert_eq!(qr.engine.combined_msgs, 0, "queue path never folds");
    assert!(qr.engine.msg_allocs > 0 && qr.engine.peak_msg_bytes > 0);
}

/// Cross-round segment recycling at the engine level: a long-lived
/// queue-transport run (one message per round for hundreds of rounds)
/// must allocate no more segments than it has lanes.
#[test]
fn queue_transport_allocation_bounded_by_lanes_not_rounds() {
    let n = 512;
    let edges = gen::path(n);
    let g = MemGraph::from_edges(n, &edges, true);
    let workers = 4;
    let (_, r) = bfs(&g, 0, &cfg(workers, TransportMode::Queue));
    assert_eq!(r.rounds, n as u64, "path BFS pays one round per hop");
    let lane_bound = (2 * workers * workers) as u64;
    assert!(
        r.engine.msg_allocs <= lane_bound,
        "{} rounds allocated {} segments (lane bound {lane_bound})",
        r.rounds,
        r.engine.msg_allocs
    );
}
