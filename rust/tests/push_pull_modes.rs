//! Push/pull equivalence — the direction of a round is an optimization,
//! never an answer change.
//!
//! * The full matrix: pagerank / wcc / bfs / sssp / coreness under
//!   `mode=push|pull|auto` at 1/2/8 workers, on a star and an R-MAT
//!   graph, every cell checked against the in-memory oracle.
//! * SEM spot checks: the same contract through the on-disk image +
//!   page-cache path, with forced pull actually running pull rounds.
//! * The I/O acceptance claim: on a dense PageRank round over a fan-in
//!   graph, pull reads strictly fewer bytes than push — the FlashGraph /
//!   Ligra direction-switch payoff the `mode=auto` heuristic chases.

use std::path::PathBuf;

use graphyti::algs::bfs::bfs;
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::sssp::sssp;
use graphyti::algs::wcc::wcc;
use graphyti::engine::{EngineConfig, RunMode};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::{MemGraph, SemGraph};
use graphyti::safs::IoConfig;
use graphyti::VertexId;

const MODES: [RunMode; 3] = [RunMode::Push, RunMode::Pull, RunMode::Auto];
const WORKERS: [usize; 3] = [1, 2, 8];

fn cfg(mode: RunMode, workers: usize) -> EngineConfig {
    EngineConfig { workers, batch: 64, mode, ..Default::default() }
}

/// Star with spokes in both directions plus a chord cycle, so BFS/SSSP
/// see real depth and wcc sees one component.
fn star_edges(n: usize) -> Vec<(VertexId, VertexId)> {
    let mut e = Vec::new();
    for v in 1..n as VertexId {
        e.push((0, v));
        e.push((v, 0));
    }
    for v in 0..n as VertexId {
        e.push((v, (v + 1) % n as VertexId));
    }
    e
}

fn l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

fn check_matrix(n: usize, edges: &[(VertexId, VertexId)], tag: &str) {
    let csr_d = Csr::from_edges(n, edges, true);
    let csr_u = Csr::from_edges(n, edges, false);
    let want_pr = oracle::pagerank(&csr_d, 0.85, 200);
    let want_bfs = oracle::bfs_levels(&csr_d, 0);
    let want_wcc = oracle::wcc(&csr_d);
    let want_sssp = oracle::sssp(&csr_d, 0);
    let want_core = oracle::coreness(&csr_u);
    for mode in MODES {
        for workers in WORKERS {
            let c = cfg(mode, workers);
            let ctx = format!("{tag} mode={mode:?} workers={workers}");

            let g = MemGraph::from_edges(n, edges, true);
            let pr = pagerank_push(&g, 0.85, 1e-12, &c);
            assert!(l1(&pr.rank, &want_pr) < 1e-6, "{ctx}: pagerank L1 {}", l1(&pr.rank, &want_pr));
            if mode == RunMode::Pull {
                assert_eq!(
                    pr.report.engine.pull_rounds, pr.report.engine.rounds,
                    "{ctx}: forced pull must pull every round"
                );
            }

            let g = MemGraph::from_edges(n, edges, true);
            assert_eq!(bfs(&g, 0, &c).0, want_bfs, "{ctx}: bfs");

            let g = MemGraph::from_edges(n, edges, true);
            assert_eq!(wcc(&g, &c).0, want_wcc, "{ctx}: wcc");

            let g = MemGraph::from_edges(n, edges, true);
            assert_eq!(sssp(&g, 0, &c).0, want_sssp, "{ctx}: sssp");

            // coreness has no pull opt-in: forced pull must degrade to
            // push (zero pull rounds) and still match the oracle
            let g = MemGraph::from_edges(n, edges, false);
            let core = coreness(&g, CorenessOptions::graphyti(), &c);
            assert_eq!(core.core, want_core, "{ctx}: coreness");
            assert_eq!(core.report.engine.pull_rounds, 0, "{ctx}: coreness can't pull");
        }
    }
}

#[test]
fn all_modes_match_oracles_on_star() {
    check_matrix(256, &star_edges(256), "star");
}

#[test]
fn all_modes_match_oracles_on_rmat() {
    check_matrix(256, &gen::rmat(8, 2000, 11), "rmat");
}

// ------------------------------------------------------------- SEM side

fn build_image(n: usize, edges: &[(VertexId, VertexId)], tag: &str) -> PathBuf {
    let base =
        std::env::temp_dir().join(format!("graphyti-ppmode-{}-{tag}", std::process::id()));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(edges);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

#[test]
fn sem_pull_and_auto_match_oracle_under_cache_pressure() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 23);
    let base = build_image(n, &edges, "sem");
    let csr = Csr::from_edges(n, &edges, true);
    let want_pr = oracle::pagerank(&csr, 0.85, 200);
    let want_bfs = oracle::bfs_levels(&csr, 0);
    for mode in [RunMode::Pull, RunMode::Auto] {
        let c = cfg(mode, 2);
        let g = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let pr = pagerank_push(&g, 0.85, 1e-12, &c);
        assert!(l1(&pr.rank, &want_pr) < 1e-6, "{mode:?}: L1 {}", l1(&pr.rank, &want_pr));
        if mode == RunMode::Pull {
            assert!(pr.report.engine.pull_rounds > 0, "forced pull never pulled");
        }
        let g = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        assert_eq!(bfs(&g, 0, &c).0, want_bfs, "{mode:?}: bfs");
    }
    cleanup(&base);
}

/// Core pinning is an execution-placement knob, never an answer change:
/// the oracle matrix must hold bit-for-bit with `pin_workers` on and
/// off at every worker count, through the full SEM path. (On kernels or
/// sandboxes that deny `sched_setaffinity` the pin silently degrades to
/// unpinned — the equality still must hold, which is the point.)
#[test]
fn pinning_never_changes_results_at_any_worker_count() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 29);
    let base = build_image(n, &edges, "pin");
    let csr = Csr::from_edges(n, &edges, true);
    let want_pr = oracle::pagerank(&csr, 0.85, 200);
    let want_bfs = oracle::bfs_levels(&csr, 0);
    let want_wcc = oracle::wcc(&csr);
    for workers in WORKERS {
        for pin in [false, true] {
            let c = EngineConfig { pin_workers: pin, ..cfg(RunMode::Auto, workers) };
            let ctx = format!("workers={workers} pin={pin}");

            let g = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
            let pr = pagerank_push(&g, 0.85, 1e-12, &c);
            assert!(l1(&pr.rank, &want_pr) < 1e-6, "{ctx}: pagerank L1 {}", l1(&pr.rank, &want_pr));

            let g = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
            assert_eq!(bfs(&g, 0, &c).0, want_bfs, "{ctx}: bfs");

            let g = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
            assert_eq!(wcc(&g, &c).0, want_wcc, "{ctx}: wcc");
        }
    }
    cleanup(&base);
}

/// The acceptance claim: pull reads strictly fewer bytes than push on a
/// dense PageRank round.
///
/// Fan-in workload: every vertex has 8 out-edges, all landing in
/// vertices 0..64. Adjacency records interleave each vertex's in- and
/// out-lists at one offset, so a dense *push* round must touch every
/// record in the image (every vertex is an active source), while a
/// *pull* round touches only the 64 records with nonzero in-degree —
/// about half the image, contiguous at the front. The cache is sized to
/// hold the whole image so each mode pays its page set exactly once.
#[test]
fn pull_reads_fewer_bytes_than_push_on_dense_pagerank() {
    let n = 1usize << 15;
    let mut edges = Vec::with_capacity(n * 8);
    for v in 0..n as VertexId {
        for i in 0..8u32 {
            edges.push((v, (v + i * 3) % 64));
        }
    }
    let base = build_image(n, &edges, "fanin");
    let thr = 1e-3 / n as f64;
    let run = |mode: RunMode| {
        let g = SemGraph::open(&base, 4 << 20, IoConfig::default()).unwrap();
        pagerank_push(&g, 0.85, thr, &cfg(mode, 2))
    };
    let push = run(RunMode::Push);
    let pull = run(RunMode::Pull);
    assert!(
        l1(&push.rank, &pull.rank) < 1e-9,
        "modes disagree: L1 {}",
        l1(&push.rank, &pull.rank)
    );
    assert_eq!(pull.report.engine.pull_rounds, pull.report.engine.rounds);
    assert!(
        pull.report.io.bytes_read < push.report.io.bytes_read,
        "pull must read strictly fewer bytes: pull {} vs push {}",
        pull.report.io.bytes_read,
        push.report.io.bytes_read
    );
    cleanup(&base);
}
