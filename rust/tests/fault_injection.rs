//! Seeded fault injection through the SAFS I/O pool.
//!
//! The plan lives in the pool, not the test: [`FaultPlan`] derives every
//! decision (latency jitter, completion reordering, transient EIO) from
//! `splitmix64(seed, request_id)`, so a chaotic schedule is still a
//! *repeatable* schedule. That buys three proofs:
//!
//! * **Determinism** — the same seed produces bit-identical results AND
//!   bit-identical I/O counters across runs (window 0, one worker, one
//!   I/O thread: the only nondeterminism left would be the faults
//!   themselves).
//! * **Fault transparency** — transient read errors are retried inside
//!   the pool; algorithms see correct data and only the `retries`
//!   counter betrays that anything happened.
//! * **Overlap regression** (the acceptance bar) — under injected
//!   latency + reordering, the completion-driven fetch pipeline
//!   (`fetch_window > 0`) must beat the forced-sync baseline
//!   (`fetch_window == 0`): same answers, strictly less time blocked on
//!   I/O, strictly higher overlap ratio.

use std::path::PathBuf;

use graphyti::algs::bfs::bfs;
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::engine::EngineConfig;
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::SemGraph;
use graphyti::safs::{FaultPlan, IoConfig};
use graphyti::VertexId;

fn build_image(n: usize, edges: &[(VertexId, VertexId)], tag: &str) -> PathBuf {
    let base =
        std::env::temp_dir().join(format!("graphyti-fault-{}-{tag}", std::process::id()));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(edges);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

/// Same seed, same schedule: run BFS twice under a chaos plan and demand
/// identical answers *and* identical I/O counters. Window 0 + one worker
/// + one I/O thread pins the submission order, so any counter drift
/// would mean the fault plan itself is nondeterministic.
#[test]
fn chaos_plan_is_deterministic() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 31);
    let base = build_image(n, &edges, "det");
    let io = IoConfig { threads: 1, fault: Some(FaultPlan::chaos(7)), ..Default::default() };
    let ecfg = EngineConfig { workers: 1, batch: 64, fetch_window: 0, ..Default::default() };
    let run = || {
        let g = SemGraph::open(&base, 64 * 4096, io.clone()).unwrap();
        let (levels, report) = bfs(&g, 0, &ecfg);
        (levels, report.io.bytes_read, report.io.physical_reads, report.io.retries)
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "levels differ across identically-seeded runs");
    assert_eq!((a.1, a.2, a.3), (b.1, b.2, b.3), "io counters differ: {a:?} vs {b:?}");
    assert!(a.3 > 0, "chaos plan (eio_period 7) should have forced retries");
    assert_eq!(a.0, oracle::bfs_levels(&Csr::from_edges(n, &edges, true), 0));
    cleanup(&base);
}

/// Transient EIOs stay inside the pool: with every 3rd request failing
/// once, the overlapped multi-worker path still matches the oracle and
/// only `retries` records the damage.
#[test]
fn transient_read_errors_are_retried_transparently() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 37);
    let base = build_image(n, &edges, "eio");
    let io = IoConfig {
        threads: 2,
        fault: Some(FaultPlan {
            seed: 3,
            jitter_us: 0,
            reorder: false,
            eio_period: 3,
            fail_path: None,
            flip_period: 0,
            flip_path: None,
        }),
        ..Default::default()
    };
    let ecfg = EngineConfig { workers: 2, batch: 64, fetch_window: 2, ..Default::default() };
    let g = SemGraph::open(&base, 64 * 4096, io).unwrap();
    let r = pagerank_push(&g, 0.85, 1e-12, &ecfg);
    let want = oracle::pagerank(&Csr::from_edges(n, &edges, true), 0.85, 200);
    let l1: f64 = r.rank.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-6, "faulty reads leaked into results: L1 {l1}");
    assert!(r.report.io.retries > 0, "eio_period 3 must have triggered retries");
    cleanup(&base);
}

/// The overlap acceptance bar. Dense PageRank under a cache much
/// smaller than the image, 400µs injected latency per physical read,
/// plus seeded jitter and completion reordering — every round does real
/// disk work. The pipelined run must produce the same answers while
/// spending strictly less time blocked on fetches than the forced-sync
/// baseline — that delta is exactly the I/O the window hid behind
/// `run_on_vertex`.
#[test]
fn overlapped_fetch_beats_forced_sync_under_injected_latency() {
    let n = 1024;
    let edges = gen::rmat(10, 16000, 41);
    let base = build_image(n, &edges, "overlap");
    let io = IoConfig {
        threads: 4,
        io_delay_us: 400,
        fault: Some(FaultPlan {
            seed: 11,
            jitter_us: 200,
            reorder: true,
            eio_period: 0,
            fail_path: None,
            flip_period: 0,
            flip_path: None,
        }),
        ..Default::default()
    };
    let run = |window: usize| {
        let g = SemGraph::open(&base, 16 * 4096, io.clone()).unwrap();
        let ecfg =
            EngineConfig { workers: 2, batch: 64, fetch_window: window, ..Default::default() };
        pagerank_push(&g, 0.85, 1e-9, &ecfg)
    };
    let sync = run(0);
    let ovl = run(2);
    let want = oracle::pagerank(&Csr::from_edges(n, &edges, true), 0.85, 200);
    for (tag, r) in [("sync", &sync), ("overlapped", &ovl)] {
        let l1: f64 = r.rank.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 < 1e-6, "{tag}: L1 vs oracle {l1}");
    }
    // window choice may reorder float folds and flip near-threshold
    // activations, so demand convergence-level agreement, not bitwise
    let drift: f64 =
        sync.rank.iter().zip(&ovl.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(drift < 1e-6, "fetch window changed ranks: L1 {drift}");
    assert!(
        ovl.report.engine.io_wait_ns < sync.report.engine.io_wait_ns,
        "pipelining did not reduce I/O stall: overlapped {} ns vs sync {} ns",
        ovl.report.engine.io_wait_ns,
        sync.report.engine.io_wait_ns
    );
    assert!(
        ovl.report.engine.overlap_ratio() > sync.report.engine.overlap_ratio(),
        "overlap ratio did not improve: overlapped {:.3} vs sync {:.3}",
        ovl.report.engine.overlap_ratio(),
        sync.report.engine.overlap_ratio()
    );
    cleanup(&base);
}
