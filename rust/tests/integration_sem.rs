//! Integration tests: the full stack (on-disk image → SAFS page cache →
//! BSP engine → algorithms) against in-memory oracles, under cache
//! pressure, latency injection and failure conditions.

use std::path::PathBuf;

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::bfs::bfs;
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::diameter::{estimate_diameter, DiameterVariant};
use graphyti::algs::louvain::{louvain, LouvainMode};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::{pagerank_pull, pagerank_push};
use graphyti::algs::sssp::sssp;
use graphyti::algs::triangles::{triangles, TriangleOptions};
use graphyti::algs::wcc::wcc;
use graphyti::coordinator::{open_graph, GraphMode, RunConfig};
use graphyti::engine::EngineConfig;
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::csr::Csr;
use graphyti::graph::gen;
use graphyti::graph::source::{EdgeSource, SemGraph};
use graphyti::VertexId;

fn build_image(
    n: usize,
    edges: &[(VertexId, VertexId)],
    directed: bool,
    tag: &str,
) -> PathBuf {
    let base = std::env::temp_dir().join(format!(
        "graphyti-itest-{}-{tag}",
        std::process::id()
    ));
    let mut b = GraphBuilder::new(n, directed);
    b.add_edges(edges);
    b.build_files(&base).unwrap();
    base
}

fn tiny_cache_cfg() -> RunConfig {
    // 64 pages = 256 KiB: guarantees eviction pressure on every workload
    RunConfig { cache_mb: 1, io_threads: 3, ..Default::default() }
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

#[test]
fn full_stack_pagerank_under_cache_pressure() {
    let n = 2048;
    let edges = gen::rmat(11, 30_000, 5);
    let base = build_image(n, &edges, true, "pr");
    let csr = Csr::from_edges(n, &edges, true);
    let cfg = tiny_cache_cfg();
    // open with a cache far smaller than the adjacency data
    let g = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
    let r = pagerank_push(&g, 0.85, 1e-12, &cfg.engine());
    let want = oracle::pagerank(&csr, 0.85, 200);
    let l1: f64 = r.rank.iter().zip(&want).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-6, "L1 {l1}");
    let s = g.io_stats().snapshot();
    assert!(s.evictions > 0, "test must run under cache pressure: {s:?}");
    assert!(s.bytes_read > 0);
    cleanup(&base);
}

#[test]
fn full_stack_all_algorithms_match_oracles() {
    let n = 1024;
    let edges = gen::rmat(10, 12_000, 77);
    let base_d = build_image(n, &edges, true, "all-d");
    let base_u = build_image(n, &edges, false, "all-u");
    let csr_d = Csr::from_edges(n, &edges, true);
    let csr_u = Csr::from_edges(n, &edges, false);
    let cfg = tiny_cache_cfg();
    let ecfg = cfg.engine();

    let gd = SemGraph::open(&base_d, 64 * 4096, cfg.io()).unwrap();
    let gu = SemGraph::open(&base_u, 64 * 4096, cfg.io()).unwrap();

    // BFS
    let (lv, _) = bfs(&gd, 0, &ecfg);
    assert_eq!(lv, oracle::bfs_levels(&csr_d, 0));

    // SSSP
    let (dist, _) = sssp(&gd, 0, &ecfg);
    assert_eq!(dist, oracle::sssp(&csr_d, 0));

    // WCC
    let (labels, _) = wcc(&gd, &ecfg);
    assert_eq!(labels, oracle::wcc(&csr_d));

    // Coreness (all variants)
    let want_core = oracle::coreness(&csr_u);
    for opts in [
        CorenessOptions::unoptimized(),
        CorenessOptions::pruned(),
        CorenessOptions::graphyti(),
    ] {
        assert_eq!(coreness(&gu, opts, &ecfg).core, want_core);
    }

    // Triangles (naive + optimized)
    let want_tri = oracle::triangle_count(&csr_u);
    assert_eq!(triangles(&gu, TriangleOptions::naive(), &ecfg).triangles, want_tri);
    assert_eq!(triangles(&gu, TriangleOptions::graphyti(), &ecfg).triangles, want_tri);

    // BC (all variants, few sources)
    let sources: Vec<VertexId> = vec![0, 1, 2, 5, 17];
    let want_bc = oracle::betweenness(&csr_d, &sources);
    for variant in [BcVariant::UniSource, BcVariant::MultiSourceSync, BcVariant::MultiSourceAsync]
    {
        let got = betweenness(&gd, &sources, variant, &ecfg);
        for (i, (a, b)) in got.bc.iter().zip(&want_bc).enumerate() {
            assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "{variant:?} bc[{i}]: {a} vs {b}");
        }
    }

    // Diameter agreement between variants
    let du = estimate_diameter(&gd, 8, DiameterVariant::UniSource, &ecfg);
    let dm = estimate_diameter(&gd, 8, DiameterVariant::MultiSource, &ecfg);
    assert_eq!(du.diameter, dm.diameter);

    // Louvain: internal Q must match the oracle formula
    let r = louvain(&gu, LouvainMode::Graphyti, 8, &ecfg);
    let q = oracle::modularity(&csr_u, &r.community);
    assert!((r.modularity - q).abs() < 1e-6, "{} vs {q}", r.modularity);

    cleanup(&base_d);
    cleanup(&base_u);
}

#[test]
fn latency_injection_slows_sem_but_not_results() {
    let n = 1024;
    let edges = gen::rmat(10, 12_000, 9);
    let base = build_image(n, &edges, true, "delay");
    let mut cfg = tiny_cache_cfg();
    // single-page runs on one I/O thread so every miss pays the delay
    cfg.max_run_pages = 1;
    cfg.io_threads = 1;
    let g_fast = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
    let fast = pagerank_push(&g_fast, 0.85, 1e-10, &cfg.engine());
    cfg.io_delay_us = 2000;
    let g_slow = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
    let slow = pagerank_push(&g_slow, 0.85, 1e-10, &cfg.engine());
    let l1: f64 = fast.rank.iter().zip(&slow.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-9, "latency must not change results");
    // with one I/O thread the injected sleeps serialize, so wall time is
    // bounded below by preads x delay — deterministic, unlike comparing
    // against the fast run on a noisy shared machine
    let floor = std::time::Duration::from_micros(slow.report.io.physical_reads * 2000);
    assert!(slow.report.io.physical_reads > 0, "slow run must hit disk");
    assert!(
        slow.report.wall >= floor,
        "injected latency must show up in wall time: {:?} < floor {:?}",
        slow.report.wall,
        floor
    );
    cleanup(&base);
}

#[test]
fn corrupted_index_is_rejected() {
    let n = 64;
    let edges = gen::cycle(n);
    let base = build_image(n, &edges, true, "corrupt");
    // truncate the index
    let idx = base.with_extension("gy-idx");
    let bytes = std::fs::read(&idx).unwrap();
    std::fs::write(&idx, &bytes[..bytes.len() / 2]).unwrap();
    let cfg = tiny_cache_cfg();
    assert!(SemGraph::open(&base, 64 * 4096, cfg.io()).is_err());
    // garbage magic
    let mut bad = bytes.clone();
    bad[0] = b'Z';
    std::fs::write(&idx, &bad).unwrap();
    assert!(SemGraph::open(&base, 64 * 4096, cfg.io()).is_err());
    cleanup(&base);
}

#[test]
fn truncated_adjacency_fails_loudly_not_wrongly() {
    let n = 256;
    let edges = gen::rmat(8, 3000, 3);
    // default (checksummed) image: truncation clips the checksum
    // footer, so the image refuses to open at all — failure at the
    // earliest possible moment
    let base = build_image(n, &edges, true, "truncadj");
    let adj = base.with_extension("gy-adj");
    let bytes = std::fs::read(&adj).unwrap();
    std::fs::write(&adj, &bytes[..bytes.len() / 2]).unwrap();
    let cfg = tiny_cache_cfg();
    assert!(
        SemGraph::open(&base, 64 * 4096, cfg.io()).is_err(),
        "a truncated checksummed image must fail to open"
    );
    cleanup(&base);
    // legacy (unfooted) image: opens fine, but fetches past EOF must
    // error (the index promises more data than the file holds)
    let base = std::env::temp_dir()
        .join(format!("graphyti-itest-{}-truncadj-plain", std::process::id()));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(&edges).checksums(false);
    b.build_files(&base).unwrap();
    let adj = base.with_extension("gy-adj");
    let bytes = std::fs::read(&adj).unwrap();
    std::fs::write(&adj, &bytes[..bytes.len() / 2]).unwrap();
    let g = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
    // some vertex's record now lies past EOF
    let mut saw_error = false;
    for v in (0..n as VertexId).rev() {
        if g.fetch(v, graphyti::graph::format::EdgeRequest::Both).is_err() {
            saw_error = true;
            break;
        }
    }
    assert!(saw_error, "reads past the truncated file must error");
    cleanup(&base);
}

#[test]
fn coordinator_modes_agree_under_pressure() {
    let n = 2048;
    let edges = gen::rmat(11, 24_000, 13);
    let base = build_image(n, &edges, false, "modes");
    let cfg = tiny_cache_cfg();
    let sem = open_graph(&base, GraphMode::Sem, &cfg).unwrap();
    let mem = open_graph(&base, GraphMode::Mem, &cfg).unwrap();
    let ecfg = EngineConfig { workers: 4, ..Default::default() };
    let a = coreness(sem.as_ref(), CorenessOptions::graphyti(), &ecfg);
    let b = coreness(mem.as_ref(), CorenessOptions::graphyti(), &ecfg);
    assert_eq!(a.core, b.core);
    // SEM must have read from disk, Mem must not
    assert!(sem.io_stats().snapshot().bytes_read > 0);
    assert_eq!(mem.io_stats().snapshot().bytes_read, 0);
    cleanup(&base);
}

/// The work-stealing scheduler's correctness contract: on adversarially
/// skewed inputs (a star whose hub dominates, and a power-law R-MAT),
/// every algorithm's output is independent of the worker count. Results
/// with exact (order-independent) semantics — BFS, SSSP, WCC, coreness,
/// triangles — must be bit-identical across 1/2/8 workers; floating-
/// point algorithms (PageRank, BC) accumulate messages in a
/// parallelism-dependent order, so each worker count is held to the
/// in-memory oracle within tight tolerance instead.
#[test]
fn work_stealing_all_algorithms_deterministic_under_skew() {
    let star = gen::star(512);
    let rmat = gen::rmat(9, 6000, 21);
    for (tag, edges) in [("star", &star), ("rmat", &rmat)] {
        let n = 512;
        let base_d = build_image(n, edges, true, &format!("ws-{tag}-d"));
        let base_u = build_image(n, edges, false, &format!("ws-{tag}-u"));
        let csr_d = Csr::from_edges(n, edges, true);
        let csr_u = Csr::from_edges(n, edges, false);
        let want_bfs = oracle::bfs_levels(&csr_d, 0);
        let want_sssp = oracle::sssp(&csr_d, 0);
        let want_wcc = oracle::wcc(&csr_d);
        let want_core = oracle::coreness(&csr_u);
        let want_tri = oracle::triangle_count(&csr_u);
        let want_pr = oracle::pagerank(&csr_d, 0.85, 200);
        let bc_sources: Vec<VertexId> = vec![0, 3, 17];
        let want_bc = oracle::betweenness(&csr_d, &bc_sources);
        for workers in [1usize, 2, 8] {
            let cfg = tiny_cache_cfg();
            let ecfg = EngineConfig { workers, batch: 64, ..Default::default() };
            let gd = SemGraph::open(&base_d, 64 * 4096, cfg.io()).unwrap();
            let gu = SemGraph::open(&base_u, 64 * 4096, cfg.io()).unwrap();

            // exact algorithms: bit-identical to the oracle at every
            // worker count (hence bit-identical across counts)
            assert_eq!(bfs(&gd, 0, &ecfg).0, want_bfs, "{tag} bfs workers={workers}");
            assert_eq!(sssp(&gd, 0, &ecfg).0, want_sssp, "{tag} sssp workers={workers}");
            assert_eq!(wcc(&gd, &ecfg).0, want_wcc, "{tag} wcc workers={workers}");
            assert_eq!(
                coreness(&gu, CorenessOptions::graphyti(), &ecfg).core,
                want_core,
                "{tag} coreness workers={workers}"
            );
            assert_eq!(
                triangles(&gu, TriangleOptions::graphyti(), &ecfg).triangles,
                want_tri,
                "{tag} triangles workers={workers}"
            );

            // floating-point algorithms: oracle-tight at every count
            let pr = pagerank_push(&gd, 0.85, 1e-12, &ecfg);
            let l1: f64 =
                pr.rank.iter().zip(&want_pr).map(|(a, b)| (a - b).abs()).sum();
            assert!(l1 < 1e-6, "{tag} pagerank workers={workers}: L1 {l1}");
            let got_bc = betweenness(&gd, &bc_sources, BcVariant::MultiSourceAsync, &ecfg);
            for (i, (a, b)) in got_bc.bc.iter().zip(&want_bc).enumerate() {
                assert!(
                    (a - b).abs() < 1e-9 * (1.0 + b.abs()),
                    "{tag} bc[{i}] workers={workers}: {a} vs {b}"
                );
            }
        }
        cleanup(&base_d);
        cleanup(&base_u);
    }
}

/// The work-stealing scheduler's performance contract (acceptance
/// criterion): a frontier confined to one worker's static span, with
/// real injected I/O latency, keeps the max/min per-worker busy-time
/// ratio bounded — the static partition left it unbounded (idle workers
/// accrue ~zero busy time while the span owner does everything).
#[test]
fn skewed_frontier_busy_ratio_bounded_under_io_delay() {
    use graphyti::engine::{Engine, VertexProgram, WorkerCtx};
    use graphyti::graph::format::{EdgeRequest, VertexEdges};
    use graphyti::util::SharedVec;

    struct SkewTouch {
        ran: SharedVec<u32>,
        rounds: usize,
    }
    impl VertexProgram for SkewTouch {
        type Msg = ();
        fn edge_request(&self, _v: VertexId) -> EdgeRequest {
            EdgeRequest::Out
        }
        fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
            *self.ran.get_mut(v as usize) += 1;
            if ctx.round() + 1 < self.rounds {
                ctx.activate(v);
            }
        }
        fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
    }

    let n = 16_384;
    let edges = gen::rmat(14, n * 8, 23);
    let base = build_image(n, &edges, true, "busyratio");
    // busy time is wall-clock, so a loaded CI machine can deschedule one
    // worker asymmetrically; allow one retry — systematic imbalance (the
    // thing this test guards) fails both attempts, noise does not
    let mut last_ratio = f64::INFINITY;
    for attempt in 0..2 {
        // tiny cache (64 pages) + injected latency: every round
        // re-misses, so per-worker busy time is dominated by real fetch
        // cost
        let mut cfg = tiny_cache_cfg();
        cfg.io_threads = 2;
        cfg.io_delay_us = 400;
        let g = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
        // enough rounds that per-round chunk-quantization noise (±1
        // chunk of ~16 per round) averages out below the 2x bound
        let rounds = 8usize;
        let prog = SkewTouch { ran: SharedVec::new(n, 0), rounds };
        // adversarial skew: the whole frontier lives in the first
        // quarter of the id space — the static partition would leave
        // most of 4 workers idle every round
        let active: Vec<VertexId> = (0..(n / 4) as VertexId).collect();
        let ecfg = EngineConfig { workers: 4, batch: 128, ..Default::default() };
        let report = Engine::run(&prog, &g, &active, &ecfg);
        // deterministic contracts hold on every attempt
        assert_eq!(report.rounds as usize, rounds);
        for v in 0..n {
            let want = if v < n / 4 { rounds as u32 } else { 0 };
            assert_eq!(*prog.ran.get(v), want, "vertex {v}");
        }
        assert!(report.io.physical_reads > 0, "must hit disk: {:?}", report.io);
        assert!(
            report.engine.steals > 0,
            "skewed frontier must induce steals: {:?}",
            report.engine
        );
        last_ratio = report.engine.busy_ratio();
        if last_ratio <= 2.0 {
            cleanup(&base);
            return;
        }
        eprintln!("attempt {attempt}: busy ratio {last_ratio:.2} > 2.0, retrying once");
    }
    cleanup(&base);
    panic!("work stealing must bound the busy imbalance: ratio {last_ratio:.2} on both attempts");
}

#[test]
fn determinism_across_worker_counts_sem() {
    let n = 512;
    let edges = gen::rmat(9, 6000, 21);
    let base = build_image(n, &edges, true, "det");
    let csr = Csr::from_edges(n, &edges, true);
    let want = oracle::betweenness(&csr, &[0, 7, 99]);
    for workers in [1, 2, 8] {
        let cfg = tiny_cache_cfg();
        let g = SemGraph::open(&base, 64 * 4096, cfg.io()).unwrap();
        let ecfg = EngineConfig { workers, ..Default::default() };
        let got = betweenness(&g, &[0, 7, 99], BcVariant::MultiSourceAsync, &ecfg);
        for (i, (a, b)) in got.bc.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-9 * (1.0 + b.abs()), "workers={workers} bc[{i}]");
        }
    }
    cleanup(&base);
}

#[test]
fn pagerank_push_pull_converge_to_same_fixpoint_sem() {
    let n = 1024;
    let edges = gen::rmat(10, 15_000, 31);
    let base = build_image(n, &edges, true, "fixpoint");
    let cfg = tiny_cache_cfg();
    let g = SemGraph::open(&base, 128 * 4096, cfg.io()).unwrap();
    let push = pagerank_push(&g, 0.85, 1e-13, &cfg.engine());
    let pull = pagerank_pull(&g, 0.85, 1e-13, 1000, &cfg.engine());
    let l1: f64 = push.rank.iter().zip(&pull.rank).map(|(a, b)| (a - b).abs()).sum();
    assert!(l1 < 1e-7, "push/pull fixpoint divergence: {l1}");
    cleanup(&base);
}
