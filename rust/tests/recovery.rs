//! Durable-recovery integration: engine checkpoints, WAL replay, and
//! clean per-job failure under permanent I/O errors.
//!
//! The durability contract under test:
//!
//! * **Checkpoint/resume is bit-identical** — a run interrupted at a
//!   round boundary and resumed from its snapshot produces exactly the
//!   bytes an uninterrupted run produces (WCC at any worker count;
//!   PageRank at a fixed single worker, since f64 folding order is
//!   worker-dependent).
//! * **Torn files degrade, never wedge** — a corrupt checkpoint falls
//!   back to a fresh (still correct) run; a torn WAL tail is skipped
//!   and counted, with the intact prefix fully replayed.
//! * **WAL replay re-admits exactly once** — queued jobs survive a
//!   service restart under their original ids and run to completion;
//!   gracefully-interrupted jobs come back flagged to resume.
//! * **Permanent I/O errors have a one-job blast radius** — the owning
//!   job fails cleanly with a descriptive error while a concurrent
//!   healthy job on another graph completes.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::wcc::wcc;
use graphyti::engine::EngineConfig;
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::graph::source::SemGraph;
use graphyti::safs::{FaultPlan, IoConfig};
use graphyti::service::{GraphService, JobRequest, JobState, ServiceConfig};
use graphyti::VertexId;

fn build_image(n: usize, edges: &[(VertexId, VertexId)], tag: &str) -> PathBuf {
    let base =
        std::env::temp_dir().join(format!("graphyti-recov-{}-{tag}", std::process::id()));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(edges);
    b.build_files(&base).unwrap();
    base
}

fn cleanup_image(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("graphyti-recov-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// rmat core plus a long appended chain, so min-label propagation needs
/// many rounds — an interruption at a small `max_rounds` is guaranteed
/// to cut mid-run, never after convergence.
fn chained_graph() -> (usize, Vec<(VertexId, VertexId)>) {
    let n = 600usize;
    let mut edges = gen::rmat(9, 3000, 13);
    edges.push((0, 512));
    for v in 512..(n as VertexId - 1) {
        edges.push((v, v + 1));
    }
    (n, edges)
}

fn block_until_running(svc: &GraphService, id: u64) {
    for _ in 0..2000 {
        if svc.status(id).map(|s| s.state) == Some(JobState::Running) {
            return;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("job {id} never reached Running");
}

/// Interrupt WCC at a round boundary via `max_rounds`, resume from the
/// published snapshot, and demand the labels match an uninterrupted run
/// bit-for-bit — at one, two and eight workers (integer min folding is
/// order-independent, so worker count must not matter).
#[test]
fn wcc_checkpoint_resume_is_bit_identical_at_any_worker_count() {
    let (n, edges) = chained_graph();
    let base = build_image(n, &edges, "wcc-ckpt");
    for workers in [1usize, 2, 8] {
        let ckpt = std::env::temp_dir()
            .join(format!("graphyti-recov-wcc-{}-{workers}.ckpt", std::process::id()));
        let _ = std::fs::remove_file(&ckpt);
        let cfg = EngineConfig { workers, batch: 64, ..Default::default() };
        let io = || IoConfig { threads: 2, ..Default::default() };

        let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
        let (full, full_report) = wcc(&g, &cfg);
        assert!(
            full_report.rounds > 6,
            "chain too short to interrupt: converged in {} rounds",
            full_report.rounds
        );

        // interrupted leg: stop hard at round 4, with a final snapshot
        // cut at the stop (stopping_early), plus periodic ones before
        let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
        let interrupted = EngineConfig {
            max_rounds: 4,
            checkpoint_every: 2,
            checkpoint_path: Some(ckpt.clone()),
            ..cfg.clone()
        };
        let (partial, partial_report) = wcc(&g, &interrupted);
        assert!(partial_report.engine.checkpoints >= 1, "{partial_report:?}");
        assert!(partial_report.engine.checkpoint_bytes > 0);
        assert!(ckpt.exists(), "interrupted run must leave a snapshot");
        assert_ne!(partial, full, "4 rounds must not be enough to converge");

        // resumed leg: a fresh program restored from the snapshot
        let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
        let resumed_cfg = EngineConfig {
            checkpoint_every: 2,
            checkpoint_path: Some(ckpt.clone()),
            resume: true,
            ..cfg.clone()
        };
        let (resumed, _) = wcc(&g, &resumed_cfg);
        assert_eq!(resumed, full, "resumed labels diverged at workers={workers}");
        assert!(
            !ckpt.exists(),
            "a converged run must remove its now-stale snapshot"
        );
    }
    cleanup_image(&base);
}

/// Same interruption oracle for PageRank at a single fixed worker:
/// f64 rank/residual/share state plus the pending folded messages
/// restore exactly, so the resumed ranks are bit-identical (`==` on
/// f64, no tolerance).
#[test]
fn pagerank_checkpoint_resume_is_bit_identical_single_worker() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 21);
    let base = build_image(n, &edges, "pr-ckpt");
    let ckpt = std::env::temp_dir()
        .join(format!("graphyti-recov-pr-{}.ckpt", std::process::id()));
    let _ = std::fs::remove_file(&ckpt);
    let cfg = EngineConfig { workers: 1, batch: 64, ..Default::default() };
    let io = || IoConfig { threads: 1, ..Default::default() };

    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let full = pagerank_push(&g, 0.85, 1e-10, &cfg);
    assert!(full.report.rounds > 6, "converged too fast: {}", full.report.rounds);

    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let interrupted = EngineConfig {
        max_rounds: 5,
        checkpoint_every: 2,
        checkpoint_path: Some(ckpt.clone()),
        ..cfg.clone()
    };
    let partial = pagerank_push(&g, 0.85, 1e-10, &interrupted);
    assert!(partial.report.engine.checkpoints >= 1);
    assert!(ckpt.exists());

    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let resumed_cfg = EngineConfig {
        checkpoint_every: 2,
        checkpoint_path: Some(ckpt.clone()),
        resume: true,
        ..cfg
    };
    let resumed = pagerank_push(&g, 0.85, 1e-10, &resumed_cfg);
    assert_eq!(resumed.rank, full.rank, "resumed ranks are not bit-identical");
    assert!(!ckpt.exists(), "converged resume must remove the snapshot");
    cleanup_image(&base);
}

/// A corrupt snapshot degrades to "no checkpoint": the resume flag
/// falls back to a fresh run and the answers are still exactly right.
#[test]
fn torn_checkpoint_falls_back_to_fresh_run() {
    let (n, edges) = chained_graph();
    let base = build_image(n, &edges, "torn-ckpt");
    let ckpt = std::env::temp_dir()
        .join(format!("graphyti-recov-torn-{}.ckpt", std::process::id()));
    let _ = std::fs::remove_file(&ckpt);
    let cfg = EngineConfig { workers: 2, batch: 64, ..Default::default() };
    let io = || IoConfig { threads: 2, ..Default::default() };

    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let (full, _) = wcc(&g, &cfg);

    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let interrupted = EngineConfig {
        max_rounds: 4,
        checkpoint_every: 2,
        checkpoint_path: Some(ckpt.clone()),
        ..cfg.clone()
    };
    let _ = wcc(&g, &interrupted);
    assert!(ckpt.exists());

    // tear the snapshot: truncation must fail the checksum, and the
    // resumed run must start fresh rather than wedge or corrupt state
    let bytes = std::fs::read(&ckpt).unwrap();
    std::fs::write(&ckpt, &bytes[..bytes.len() - 10]).unwrap();
    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let resumed_cfg = EngineConfig {
        checkpoint_every: 2,
        checkpoint_path: Some(ckpt.clone()),
        resume: true,
        ..cfg
    };
    let (labels, _) = wcc(&g, &resumed_cfg);
    assert_eq!(labels, full, "fresh-run fallback must still be correct");
    let _ = std::fs::remove_file(&ckpt);
    cleanup_image(&base);
}

/// Kill a service with queued work; a restart over the same WAL dir
/// re-admits each queued job exactly once under its original id and
/// runs it to completion. Terminal history replays as history, and the
/// id counter resumes past the replayed maximum.
#[test]
fn wal_replay_readmits_queued_jobs_exactly_once() {
    let n = 256;
    let edges = gen::rmat(8, 1500, 17);
    let base = build_image(n, &edges, "wal-replay");
    let wal_dir = tmpdir("wal-replay-dir");

    let mk_cfg = || ServiceConfig {
        cache_mb: 1,
        exec_threads: 1,
        wal_dir: Some(wal_dir.clone()),
        ..Default::default()
    };
    let (blocker_id, wcc_id, deg_id) = {
        let svc = GraphService::start(mk_cfg());
        // blocker: negative threshold never converges, so it pins the
        // single executor until shutdown cancels it
        let mut blocker = JobRequest::new(base.clone(), "pagerank");
        blocker.overrides.push(("threshold".into(), "-1".into()));
        blocker.overrides.push(("workers".into(), "1".into()));
        let blocker_id = svc.submit(blocker).unwrap();
        block_until_running(&svc, blocker_id);
        let wcc_id = svc.submit(JobRequest::new(base.clone(), "wcc")).unwrap();
        let deg_id = svc.submit(JobRequest::new(base.clone(), "degree")).unwrap();
        assert_eq!(svc.status(wcc_id).unwrap().state, JobState::Queued);
        assert_eq!(svc.status(deg_id).unwrap().state, JobState::Queued);
        // abrupt stop: queued jobs never ran, blocker is cancelled
        svc.shutdown();
        assert_eq!(svc.status(blocker_id).unwrap().state, JobState::Cancelled);
        assert_eq!(svc.status(wcc_id).unwrap().state, JobState::Queued);
        (blocker_id, wcc_id, deg_id)
    };

    let svc = GraphService::start(mk_cfg());
    let h = svc.health();
    assert!(h.wal_enabled);
    assert!(h.wal_replayed > 0, "{h:?}");
    // the queued jobs run to completion under their original ids
    let w = svc.wait(wcc_id, Duration::from_secs(120)).expect("replayed job known");
    assert_eq!(w.state, JobState::Done, "{w:?}");
    assert!(w.summary.as_deref().unwrap_or("").starts_with("wcc:"), "{w:?}");
    let d = svc.wait(deg_id, Duration::from_secs(120)).unwrap();
    assert_eq!(d.state, JobState::Done, "{d:?}");
    // exactly once: one entry per id, no duplicates, history intact
    let jobs = svc.list();
    assert_eq!(jobs.len(), 3, "{jobs:?}");
    for id in [blocker_id, wcc_id, deg_id] {
        assert_eq!(jobs.iter().filter(|j| j.id == id).count(), 1);
    }
    assert_eq!(svc.status(blocker_id).unwrap().state, JobState::Cancelled);
    // fresh ids continue past the replayed maximum
    let new_id = svc.submit(JobRequest::new(base.clone(), "degree")).unwrap();
    assert!(new_id > deg_id, "id counter must resume past the WAL ({new_id})");
    let st = svc.wait(new_id, Duration::from_secs(120)).unwrap();
    assert_eq!(st.state, JobState::Done);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&wal_dir);
    cleanup_image(&base);
}

/// Graceful shutdown drains a checkpointing job to its round boundary,
/// stamps it `interrupted` (resumable) rather than dead, and leaves a
/// final snapshot behind; the restarted service re-queues it flagged to
/// resume.
#[test]
fn graceful_shutdown_marks_running_job_resumable() {
    let n = 256;
    let edges = gen::rmat(8, 1500, 23);
    let base = build_image(n, &edges, "graceful");
    let wal_dir = tmpdir("graceful-dir");

    let mk_cfg = || ServiceConfig {
        cache_mb: 1,
        exec_threads: 1,
        wal_dir: Some(wal_dir.clone()),
        ..Default::default()
    };
    let id = {
        let svc = GraphService::start(mk_cfg());
        let mut job = JobRequest::new(base.clone(), "pagerank");
        job.overrides.push(("threshold".into(), "-1".into())); // never converges
        job.overrides.push(("workers".into(), "1".into()));
        job.overrides.push(("checkpoint_every".into(), "2".into()));
        let id = svc.submit(job).unwrap();
        block_until_running(&svc, id);
        // let a few rounds (and periodic snapshots) happen
        std::thread::sleep(Duration::from_millis(300));
        svc.shutdown_graceful(Duration::from_secs(60));
        let st = svc.status(id).unwrap();
        assert_eq!(st.state, JobState::Cancelled, "{st:?}");
        assert!(
            st.error.as_deref().unwrap_or("").contains("resumes on restart"),
            "graceful drain must mark the job resumable: {st:?}"
        );
        assert!(st.engine.checkpoints >= 1, "{st:?}");
        id
    };
    // the service parks per-job snapshots next to the WAL
    let ckpt = wal_dir.join(format!("job-{id}.ckpt"));
    assert!(ckpt.exists(), "drained job must leave its final snapshot");

    let svc = GraphService::start(mk_cfg());
    assert_eq!(svc.resumed_jobs(), 1, "interrupted job must come back resumable");
    assert_eq!(svc.health().resumed_jobs, 1);
    // it restores from the snapshot and keeps running (threshold=-1
    // never converges); cancel it cooperatively and wind down
    block_until_running(&svc, id);
    std::thread::sleep(Duration::from_millis(100));
    assert!(svc.cancel(id));
    let st = svc.wait(id, Duration::from_secs(120)).unwrap();
    assert_eq!(st.state, JobState::Cancelled, "{st:?}");
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&wal_dir);
    cleanup_image(&base);
}

/// A torn WAL tail (crash mid-append) is skipped and counted at the
/// next start; the intact prefix — including terminal history — replays
/// fully and the service keeps accepting work.
#[test]
fn torn_wal_tail_is_skipped_on_service_restart() {
    let n = 256;
    let edges = gen::rmat(8, 1500, 29);
    let base = build_image(n, &edges, "torn-wal");
    let wal_dir = tmpdir("torn-wal-dir");

    let mk_cfg = || ServiceConfig {
        cache_mb: 1,
        exec_threads: 1,
        wal_dir: Some(wal_dir.clone()),
        ..Default::default()
    };
    let done_id = {
        let svc = GraphService::start(mk_cfg());
        let id = svc.submit(JobRequest::new(base.clone(), "wcc")).unwrap();
        let st = svc.wait(id, Duration::from_secs(120)).unwrap();
        assert_eq!(st.state, JobState::Done);
        svc.shutdown();
        id
    };
    // crash mid-append: a truncated line with no trailing newline
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new()
        .append(true)
        .open(wal_dir.join("jobs.wal"))
        .unwrap();
    f.write_all(b"{\"ck\":\"dead\",\"rec\":{\"kind\":\"sta").unwrap();
    drop(f);

    let svc = GraphService::start(mk_cfg());
    let h = svc.health();
    assert!(h.wal_skipped >= 1, "torn tail must be counted: {h:?}");
    assert_eq!(
        svc.status(done_id).unwrap().state,
        JobState::Done,
        "intact prefix must replay"
    );
    let id = svc.submit(JobRequest::new(base.clone(), "degree")).unwrap();
    let st = svc.wait(id, Duration::from_secs(120)).unwrap();
    assert_eq!(st.state, JobState::Done);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(&wal_dir);
    cleanup_image(&base);
}

/// Permanent I/O failure blast radius: the job reading the failing
/// file fails cleanly with a descriptive error — no panic, no wedge —
/// while a concurrent job on a healthy graph completes, and the
/// substrate counters attribute the damage.
#[test]
fn permanent_io_failure_fails_job_cleanly_while_healthy_job_completes() {
    let n = 256;
    let edges = gen::rmat(8, 1500, 31);
    let bad = build_image(n, &edges, "badio");
    let good = build_image(n, &edges, "goodio");

    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1,
        exec_threads: 2,
        // fail every adjacency read whose file path contains "badio";
        // the index loads outside the pool, so submit-time validation
        // still passes and the failure surfaces inside the run
        fault: Some(FaultPlan {
            seed: 1,
            jitter_us: 0,
            reorder: false,
            eio_period: 0,
            fail_path: Some(Arc::from("badio")),
            flip_period: 0,
            flip_path: None,
        }),
        ..Default::default()
    });
    let bad_id = svc.submit(JobRequest::new(bad.clone(), "wcc")).unwrap();
    let good_id = svc.submit(JobRequest::new(good.clone(), "wcc")).unwrap();

    let b = svc.wait(bad_id, Duration::from_secs(120)).unwrap();
    assert_eq!(b.state, JobState::Failed, "{b:?}");
    let err = b.error.as_deref().unwrap_or("");
    assert!(
        err.contains("injected permanent I/O failure") && err.contains("badio"),
        "failure must name the cause and the file: {err}"
    );
    let g = svc.wait(good_id, Duration::from_secs(120)).unwrap();
    assert_eq!(g.state, JobState::Done, "healthy job must be unaffected: {g:?}");
    assert!(g.summary.as_deref().unwrap_or("").starts_with("wcc:"));
    let io = svc.substrate_stats();
    assert!(io.permanent_errors >= 1, "{io:?}");
    assert_eq!(svc.health().io_permanent_errors, io.permanent_errors);
    svc.shutdown();
    cleanup_image(&bad);
    cleanup_image(&good);
}
