//! Differential tests for the word-level varint decode fast path.
//!
//! The fast path (`graph::varint::decode_deltas`) must be
//! bit-identical — output values AND cursor position — to the
//! byte-at-a-time reference (`decode_deltas_scalar`) on every stream:
//! round-trips of encoded lists, adversarial width mixes covering all
//! 1–5 byte varint lengths, runs straddling the 8-byte window boundary,
//! maximum-value deltas, and whole converted v2 images decoded through
//! `VertexEdges::decode_into`.

use graphyti::graph::builder::{convert_image, GraphBuilder};
use graphyti::graph::csr::Csr;
use graphyti::graph::format::{EdgeRequest, GraphIndex, VertexEdges, VERSION_V1, VERSION_V2};
use graphyti::graph::gen;
use graphyti::graph::varint::{
    decode_deltas, decode_deltas_scalar, deltas_len, encode_deltas, encode_u32,
};
use graphyti::util::XorShift;
use graphyti::VertexId;

/// Assert scalar and word decoders agree (values + cursor) on a raw
/// delta stream of `count` values, then return the decoded list.
fn differential(bytes: &[u8], count: usize) -> Vec<VertexId> {
    let (mut ps, mut pw) = (0usize, 0usize);
    let (mut outs, mut outw) = (Vec::new(), Vec::new());
    decode_deltas_scalar(bytes, count, &mut ps, &mut outs);
    decode_deltas(bytes, count, &mut pw, &mut outw);
    assert_eq!(outs, outw, "decoded values diverged");
    assert_eq!(ps, pw, "cursor positions diverged");
    outw
}

/// Encode a sorted list and assert the word decoder round-trips it.
fn roundtrip(sorted: &[VertexId]) {
    let mut buf = Vec::new();
    encode_deltas(sorted, &mut buf);
    assert_eq!(buf.len(), deltas_len(sorted));
    let got = differential(&buf, sorted.len());
    assert_eq!(got, sorted, "round-trip mismatch");
}

#[test]
fn roundtrip_all_varint_widths() {
    // first elements (absolute values) at every encoded width boundary
    let firsts = [
        0u32,
        1,
        0x7F,
        0x80,
        0x3FFF,
        0x4000,
        0x1F_FFFF,
        0x20_0000,
        0xFFF_FFFF,
        0x1000_0000,
        u32::MAX - 64,
    ];
    for first in firsts {
        // deltas at every width, in every order relative to the window
        for gap in [1u32, 0x7F, 0x80, 0x3FFF, 0x4000, 0x1F_FFFF, 0x20_0000, 0xFFF_FFFF] {
            let mut v = first;
            let mut list = vec![v];
            for _ in 0..10 {
                let Some(next) = v.checked_add(gap) else { break };
                v = next;
                list.push(v);
            }
            roundtrip(&list);
        }
    }
}

#[test]
fn window_boundary_straddles() {
    // lead one-byte values push the first multi-byte delta through every
    // position of the 8-byte window, including straddling its edge
    for width_gap in [0x80u32, 0x4000, 0x20_0000, 0x1000_0000] {
        for lead in 0..=9usize {
            for trail in 0..=9usize {
                let mut v = 1u32;
                let mut list = vec![v];
                for _ in 0..lead {
                    v += 1;
                    list.push(v);
                }
                v = v.saturating_add(width_gap);
                list.push(v);
                for _ in 0..trail {
                    v += 1;
                    list.push(v);
                }
                roundtrip(&list);
            }
        }
    }
}

#[test]
fn max_value_deltas() {
    roundtrip(&[u32::MAX]);
    roundtrip(&[0, u32::MAX]);
    roundtrip(&[u32::MAX - 1, u32::MAX]);
    roundtrip(&[0]);
    roundtrip(&[]);
    // largest possible gap after a one-byte lead
    roundtrip(&[1, 2, 3, u32::MAX - 3, u32::MAX - 2, u32::MAX - 1, u32::MAX]);
}

#[test]
fn randomized_streams_match_scalar() {
    let mut rng = XorShift::new(0xFA57_DECD);
    for trial in 0..500 {
        let len = (rng.next_below(40) + 1) as usize;
        let mut v = (rng.next_u64() & 0xFFFF) as u32;
        let mut list = Vec::with_capacity(len);
        for _ in 0..len {
            list.push(v);
            // mixed gap widths, biased toward one byte like real lists
            let gap = match rng.next_below(10) {
                0 => rng.next_below(1 << 28) as u32,
                1 | 2 => rng.next_below(1 << 14) as u32,
                _ => rng.next_below(127) as u32,
            };
            v = v.saturating_add(gap.max(1));
        }
        list.dedup();
        let mut buf = Vec::new();
        encode_deltas(&list, &mut buf);
        let got = differential(&buf, list.len());
        assert_eq!(got, list, "trial {trial}");
    }
}

#[test]
fn concatenated_streams_do_not_bleed() {
    // the 8-byte window may PEEK past a stream's end into the next one
    // (the v2 record layout concatenates [in][out]) but must never
    // CONSUME across the boundary
    let a: Vec<VertexId> = (1..=65).collect(); // 65 one-byte deltas
    let b: Vec<VertexId> = vec![7, 0x5000, 0x5001];
    let mut buf = Vec::new();
    encode_deltas(&a, &mut buf);
    let split = buf.len();
    encode_deltas(&b, &mut buf);
    let mut pos = 0usize;
    let mut out = Vec::new();
    decode_deltas(&buf, a.len(), &mut pos, &mut out);
    assert_eq!(out, a);
    assert_eq!(pos, split, "cursor must stop exactly at the stream boundary");
    out.clear();
    decode_deltas(&buf, b.len(), &mut pos, &mut out);
    assert_eq!(out, b);
    assert_eq!(pos, buf.len());
}

#[test]
fn raw_u32_streams_via_encode_u32() {
    // decode_deltas over a stream built value-by-value with encode_u32
    // (what encode_deltas does internally, but exercised independently)
    let deltas = [5u32, 0x7F, 0x80, 1, 0x3FFF, 0x4000, 2, 3, 4, 5, 6, 7, 8, 9, 0x1F_FFFF, 1];
    let mut buf = Vec::new();
    for d in deltas {
        encode_u32(d, &mut buf);
    }
    let got = differential(&buf, deltas.len());
    let mut prev = 0u32;
    let want: Vec<u32> = deltas
        .iter()
        .map(|&d| {
            prev = prev.wrapping_add(d);
            prev
        })
        .collect();
    assert_eq!(got, want);
}

#[test]
fn decode_into_identical_on_converted_v2_image() {
    let n = 600usize;
    let edges = gen::rmat(10, 8000, 99);
    let edges: Vec<_> =
        edges.into_iter().filter(|&(u, v)| (u as usize) < n && (v as usize) < n).collect();
    let csr = Csr::from_edges(n, &edges, true);

    let pid = std::process::id();
    let v1 = std::env::temp_dir().join(format!("graphyti-decfp-{pid}-v1"));
    let v2 = std::env::temp_dir().join(format!("graphyti-decfp-{pid}-v2"));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(&edges).format_version(VERSION_V1);
    b.build_files(&v1).unwrap();
    convert_image(&v1, &v2, VERSION_V2).unwrap();

    let idx = GraphIndex::decode(&std::fs::read(v2.with_extension("gy-idx")).unwrap()).unwrap();
    assert_eq!(idx.header().version, VERSION_V2);
    let adj = std::fs::read(v2.with_extension("gy-adj")).unwrap();

    let mut scratch = VertexEdges::default();
    for v in 0..n as VertexId {
        let (off, len) = idx.byte_range(v, EdgeRequest::Both);
        let rec = &adj[off as usize..off as usize + len];
        let (in_deg, out_deg) = (idx.in_deg(v), idx.out_deg(v));

        // production path: decode_into (word-level via decode_deltas)
        scratch.decode_into(rec, in_deg, out_deg, EdgeRequest::Both, idx.encoding());

        // reference path: scalar decoder applied to the same record
        let mut pos = 0usize;
        let (mut inn, mut out) = (Vec::new(), Vec::new());
        decode_deltas_scalar(rec, in_deg as usize, &mut pos, &mut inn);
        decode_deltas_scalar(rec, out_deg as usize, &mut pos, &mut out);
        assert_eq!(pos, rec.len(), "v={v}: record not fully consumed");

        assert_eq!(scratch.in_neighbors, inn, "v={v} in");
        assert_eq!(scratch.out_neighbors, out, "v={v} out");
        // and both must match the in-memory oracle
        assert_eq!(scratch.in_neighbors, csr.inn(v), "v={v} in vs oracle");
        assert_eq!(scratch.out_neighbors, csr.out(v), "v={v} out vs oracle");
    }

    for base in [&v1, &v2] {
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }
}
