//! Verified-storage integration: per-page checksums must turn silent
//! bit rot into loud, job-scoped failures.
//!
//! The integrity contract under test:
//!
//! * **Detection** — a single flipped bit anywhere in a checksummed
//!   image (either file, either format version, any worker count) fails
//!   the read with a checksum error; it never reaches an algorithm as
//!   plausible-but-wrong edge data.
//! * **Blast radius** — the failure is confined to the job that touched
//!   the damage: a concurrent job on a healthy graph in the same
//!   service completes oracle-correct, and the bad page stays
//!   quarantined for every later job.
//! * **Scrub** — `scrub_image` deterministically reports exactly the
//!   damaged pages, sweep after sweep.
//! * **Compatibility** — legacy unfooted images still open and run;
//!   checksummed ↔ plain conversion round-trips the data bytes
//!   identically.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use graphyti::algs::bfs::bfs;
use graphyti::algs::oracle;
use graphyti::algs::wcc::wcc;
use graphyti::engine::EngineConfig;
use graphyti::graph::builder::{convert_image_opts, GraphBuilder};
use graphyti::graph::csr::Csr;
use graphyti::graph::format::{
    footer_len, ChecksumFooter, EdgeRequest, VERSION_V1, VERSION_V2,
};
use graphyti::graph::gen;
use graphyti::graph::scrub::{scrub_image, ScrubOptions};
use graphyti::graph::source::{EdgeSource, SemGraph};
use graphyti::safs::{FaultPlan, IoConfig};
use graphyti::service::{GraphService, JobRequest, JobState, ServiceConfig};
use graphyti::VertexId;

fn build_image(
    n: usize,
    edges: &[(VertexId, VertexId)],
    version: u32,
    checksums: bool,
    tag: &str,
) -> PathBuf {
    let base = std::env::temp_dir()
        .join(format!("graphyti-integ-{}-{tag}", std::process::id()));
    let mut b = GraphBuilder::new(n, true);
    b.add_edges(edges).format_version(version).checksums(checksums);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

/// Flip one bit of the file in place — the smallest possible storage
/// fault, and exactly what a crc32c per-page footer must catch.
fn flip_bit(path: &Path, byte: u64, bit: u8) {
    use std::os::unix::fs::FileExt;
    let f = std::fs::OpenOptions::new().read(true).write(true).open(path).unwrap();
    let mut b = [0u8; 1];
    f.read_exact_at(&mut b, byte).unwrap();
    b[0] ^= 1 << bit;
    f.write_all_at(&b, byte).unwrap();
    f.sync_all().unwrap();
}

/// Checksummed data length of the image's adjacency file (excludes the
/// footer), so tests can place flips inside real data pages.
fn adj_data_len(base: &PathBuf) -> u64 {
    let f = std::fs::File::open(base.with_extension("gy-adj")).unwrap();
    let len = f.metadata().unwrap().len();
    ChecksumFooter::read_from(&f, len).unwrap().data_len
}

fn io() -> IoConfig {
    IoConfig { threads: 2, ..Default::default() }
}

fn ncomponents(labels: &[VertexId]) -> usize {
    let mut ls: Vec<VertexId> = labels.to_vec();
    ls.sort_unstable();
    ls.dedup();
    ls.len()
}

/// The corruption matrix: one flipped bit in an adjacency page, across
/// both format versions and 1/2/8 workers. Every cell must (a) fail the
/// run with a checksum error rather than converge on garbage, (b) leave
/// the page quarantined on the same open — later reads fast-fail
/// without re-touching disk — and (c) count the damage in the substrate
/// stats.
#[test]
fn disk_bit_flip_fails_the_run_and_quarantines_across_formats_and_workers() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 17);
    for version in [VERSION_V1, VERSION_V2] {
        let base = build_image(n, &edges, version, true, &format!("matrix-v{version}"));
        let data_len = adj_data_len(&base);
        assert!(data_len > 4096 + 300, "graph too small to damage page 1: {data_len}");
        flip_bit(&base.with_extension("gy-adj"), 4096 + 123, 5);

        for workers in [1usize, 2, 8] {
            let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
            let cfg = EngineConfig { workers, batch: 64, ..Default::default() };
            let (_labels, report) = wcc(&g, &cfg);
            let err = report.failure.unwrap_or_else(|| {
                panic!("v{version} workers={workers}: corrupt page must fail the run")
            });
            assert!(
                err.contains("checksum mismatch") || err.contains("quarantined"),
                "v{version} workers={workers}: {err}"
            );

            // quarantine holds on this open: the damaged page refuses
            // service forever, everything else still reads fine
            let mut refused = 0usize;
            for v in 0..n as VertexId {
                if let Err(e) = g.fetch(v, EdgeRequest::Both) {
                    let msg = format!("{e:#}");
                    assert!(msg.contains("quarantined"), "unexpected error: {msg}");
                    refused += 1;
                }
            }
            assert!(refused > 0, "some vertex must live on the quarantined page");
            assert!(refused < n, "damage must not spread beyond the bad page");

            let s = g.adj_file().stats().snapshot();
            // the first mismatch plus the failed corrective re-read
            assert!(s.checksum_failures >= 2, "{s:?}");
            assert_eq!(s.quarantined_pages, 1, "{s:?}");
        }
        cleanup(&base);
    }
}

/// The index is verified in full at open (it is RAM-resident and read
/// once), so a flipped index bit must fail `SemGraph::open` before any
/// job can run on the graph.
#[test]
fn index_corruption_is_detected_eagerly_at_open() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 29);
    let base = build_image(n, &edges, VERSION_V1, true, "idxflip");
    // past the 40-byte header, well inside the offsets column
    flip_bit(&base.with_extension("gy-idx"), 100, 2);
    let err = SemGraph::open(&base, 64 * 4096, io()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("checksum mismatch"), "{msg}");
    cleanup(&base);
}

/// Single-job blast radius through the whole service stack: the job on
/// the damaged image fails with a checksum error, the co-tenant on the
/// healthy image converges oracle-correct, a second job on the damaged
/// image fast-fails against the quarantine (no new quarantined pages),
/// and the health op reports the damage.
#[test]
fn bit_flip_fails_exactly_the_owning_job_while_cotenant_converges() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 23);
    let bad = build_image(n, &edges, VERSION_V2, true, "svc-bad");
    let good = build_image(n, &edges, VERSION_V2, true, "svc-good");
    assert!(adj_data_len(&bad) > 4096 + 100);
    flip_bit(&bad.with_extension("gy-adj"), 4096 + 77, 3);

    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1,
        exec_threads: 2,
        ..Default::default()
    });
    let bad_id = svc.submit(JobRequest::new(bad.clone(), "wcc")).unwrap();
    let good_id = svc.submit(JobRequest::new(good.clone(), "wcc")).unwrap();

    let b = svc.wait(bad_id, Duration::from_secs(120)).unwrap();
    assert_eq!(b.state, JobState::Failed, "{b:?}");
    let err = b.error.as_deref().unwrap_or("");
    assert!(err.contains("quarantined"), "failure must name the cause: {err}");

    let g = svc.wait(good_id, Duration::from_secs(120)).unwrap();
    assert_eq!(g.state, JobState::Done, "co-tenant must be unaffected: {g:?}");
    let csr = Csr::from_edges(n, &edges, true);
    let want = format!("wcc: {} components", ncomponents(&oracle::wcc(&csr)));
    assert_eq!(g.summary.as_deref(), Some(want.as_str()), "co-tenant must be correct");

    let before = svc.substrate_stats();
    assert!(before.checksum_failures >= 2, "{before:?}");
    assert!(before.quarantined_pages >= 1, "{before:?}");

    // quarantine outlives the job: the next job on the same image fails
    // against the quarantined page without growing the quarantine
    let again_id = svc.submit(JobRequest::new(bad.clone(), "wcc")).unwrap();
    let a = svc.wait(again_id, Duration::from_secs(120)).unwrap();
    assert_eq!(a.state, JobState::Failed, "{a:?}");
    assert!(a.error.as_deref().unwrap_or("").contains("quarantined"), "{a:?}");
    let after = svc.substrate_stats();
    assert_eq!(after.quarantined_pages, before.quarantined_pages, "{after:?}");

    let h = svc.health();
    assert_eq!(h.checksum_failures, after.checksum_failures);
    assert!(h.quarantined_pages >= 1, "{h:?}");
    svc.shutdown();
    cleanup(&bad);
    cleanup(&good);
}

/// Seeded in-memory bit-flip injection: with `flip_period: 1` on the
/// adjacency path every pool read is corrupted — including the
/// corrective re-read — so verify-on-read must detect, quarantine, and
/// fail the run. The disk itself is untouched: a clean re-open of the
/// same image converges oracle-correct and scrubs clean.
#[test]
fn injected_bit_flips_are_detected_and_leave_the_disk_clean() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 41);
    let base = build_image(n, &edges, VERSION_V1, true, "inject");
    let cfg = EngineConfig { workers: 2, batch: 64, ..Default::default() };

    // CI's corruption-chaos step sweeps several seeds; detection and
    // quarantine must hold whichever bits the plan picks
    let seed: u64 = std::env::var("GRAPHYTI_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    let faulty = IoConfig {
        threads: 2,
        fault: Some(FaultPlan {
            seed,
            jitter_us: 0,
            reorder: false,
            eio_period: 0,
            fail_path: None,
            flip_period: 1,
            flip_path: Some(Arc::from("gy-adj")),
        }),
        ..Default::default()
    };
    let g = SemGraph::open(&base, 64 * 4096, faulty).unwrap();
    let (_labels, report) = wcc(&g, &cfg);
    let err = report.failure.expect("every-read flips must fail the run");
    assert!(err.contains("checksum mismatch") || err.contains("quarantined"), "{err}");
    let s = g.adj_file().stats().snapshot();
    assert!(s.checksum_failures >= 2, "{s:?}");
    assert!(s.quarantined_pages >= 1, "{s:?}");

    // the injection lived in memory only: the image on disk is intact
    let g2 = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    let (labels, r2) = wcc(&g2, &cfg);
    assert!(r2.failure.is_none(), "{:?}", r2.failure);
    let csr = Csr::from_edges(n, &edges, true);
    assert_eq!(labels, oracle::wcc(&csr));

    let opts = ScrubOptions { rate_limit_bytes_per_sec: 0, cancel: None };
    for r in scrub_image(&base, &opts, None).unwrap() {
        assert!(r.bad_pages.is_empty(), "disk must be clean: {r:?}");
    }
    cleanup(&base);
}

/// Scrub determinism: flips in two adjacency pages and one index page
/// are reported — exactly those pages, in order — on every sweep.
#[test]
fn scrub_reports_every_injected_flip_deterministically() {
    let n = 1024;
    let edges = gen::rmat(10, 9000, 11);
    let base = build_image(n, &edges, VERSION_V2, true, "scrub");
    let data_len = adj_data_len(&base);
    assert!(data_len > 2 * 4096 + 200, "need at least three adj pages: {data_len}");

    flip_bit(&base.with_extension("gy-adj"), 100, 0);
    flip_bit(&base.with_extension("gy-adj"), 2 * 4096 + 100, 7);
    flip_bit(&base.with_extension("gy-idx"), 100, 4);

    let opts = ScrubOptions { rate_limit_bytes_per_sec: 0, cancel: None };
    for sweep in 0..2 {
        let reports = scrub_image(&base, &opts, None).unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(!r.skipped && !r.cancelled, "sweep {sweep}: {r:?}");
            let ext = r.path.extension().unwrap().to_str().unwrap();
            match ext {
                "gy-idx" => assert_eq!(r.bad_pages, vec![0], "sweep {sweep}"),
                "gy-adj" => assert_eq!(r.bad_pages, vec![0, 2], "sweep {sweep}"),
                other => panic!("unexpected scrub target {other}"),
            }
            assert!(r.pages_scrubbed >= r.bad_pages.len() as u64);
        }
    }
    cleanup(&base);
}

/// Legacy compatibility: an image written without footers (the pre-
/// checksum format, byte-for-byte) opens through the same code path,
/// runs oracle-correct, and never trips a checksum counter. Scrub
/// skips it instead of erroring.
#[test]
fn legacy_unfooted_images_open_and_run_unchanged() {
    let n = 512;
    let edges = gen::rmat(9, 4000, 53);
    let base = build_image(n, &edges, VERSION_V1, false, "legacy");
    let g = SemGraph::open(&base, 64 * 4096, io()).unwrap();
    assert!(!g.index().header().checksums);

    let csr = Csr::from_edges(n, &edges, true);
    let (lv, report) = bfs(&g, 0, &EngineConfig { workers: 2, ..Default::default() });
    assert!(report.failure.is_none());
    assert_eq!(lv, oracle::bfs_levels(&csr, 0));
    let s = g.adj_file().stats().snapshot();
    assert_eq!(s.checksum_failures, 0, "{s:?}");
    assert_eq!(s.quarantined_pages, 0, "{s:?}");

    let opts = ScrubOptions { rate_limit_bytes_per_sec: 0, cancel: None };
    for r in scrub_image(&base, &opts, None).unwrap() {
        assert!(r.skipped, "unfooted files are skipped, not failed: {r:?}");
        assert_eq!(r.pages_scrubbed, 0);
    }
    cleanup(&base);
}

/// Checksummed ↔ plain conversion round-trips byte-identically in both
/// format versions: adding footers only appends (data region unchanged
/// except the header flag), and stripping them restores the original
/// plain files exactly.
#[test]
fn checksummed_and_plain_images_round_trip_byte_identically() {
    for version in [VERSION_V1, VERSION_V2] {
        let n = 512;
        let edges = gen::rmat(9, 4000, 61);
        let plain = build_image(n, &edges, version, false, &format!("rt-plain-v{version}"));
        let cs = std::env::temp_dir()
            .join(format!("graphyti-integ-{}-rt-cs-v{version}", std::process::id()));
        let back = std::env::temp_dir()
            .join(format!("graphyti-integ-{}-rt-back-v{version}", std::process::id()));

        convert_image_opts(&plain, &cs, version, true).unwrap();
        // the checksummed adjacency is the plain bytes plus a footer
        let plain_adj = std::fs::read(plain.with_extension("gy-adj")).unwrap();
        let cs_adj = std::fs::read(cs.with_extension("gy-adj")).unwrap();
        assert_eq!(&cs_adj[..plain_adj.len()], &plain_adj[..], "v{version}");
        assert_eq!(
            cs_adj.len() as u64,
            plain_adj.len() as u64 + footer_len(plain_adj.len() as u64),
            "v{version}"
        );
        let g = SemGraph::open(&cs, 64 * 4096, io()).unwrap();
        assert!(g.index().header().checksums, "v{version}");

        // stripping the footers restores the plain image exactly
        convert_image_opts(&cs, &back, version, false).unwrap();
        assert_eq!(
            std::fs::read(plain.with_extension("gy-idx")).unwrap(),
            std::fs::read(back.with_extension("gy-idx")).unwrap(),
            "v{version}"
        );
        assert_eq!(
            std::fs::read(back.with_extension("gy-adj")).unwrap(),
            plain_adj,
            "v{version}"
        );
        for b in [&plain, &cs, &back] {
            cleanup(b);
        }
    }
}
