//! Trace invariants over the full stack: with `EngineConfig.trace` on,
//! the per-round boundary-snapshot deltas must sum *exactly* to the
//! run-level I/O delta (the telescoping contract in `engine/trace.rs`),
//! the ring must hold one sample per executed round, and recording must
//! not perturb the engine's allocation behavior.

use std::path::PathBuf;

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::pagerank::pagerank_push;
use graphyti::engine::{EngineConfig, RunReport, TransportMode};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::graph::source::SemGraph;
use graphyti::safs::{IoConfig, IoStatsSnapshot};
use graphyti::VertexId;

fn build_image(n: usize, edges: &[(VertexId, VertexId)], directed: bool, tag: &str) -> PathBuf {
    let base =
        std::env::temp_dir().join(format!("graphyti-trace-{}-{tag}", std::process::id()));
    let mut b = GraphBuilder::new(n, directed);
    b.add_edges(edges);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

fn open_small(base: &PathBuf) -> SemGraph {
    // 64-page cache keeps real misses (and evictions) in play
    SemGraph::open(base, 64 * 4096, IoConfig { threads: 2, ..Default::default() }).unwrap()
}

/// Zero the cumulative latency summaries so whole-struct equality
/// compares only the nine differenceable counters.
fn counters_only(mut s: IoStatsSnapshot) -> IoStatsSnapshot {
    s.latency = Default::default();
    s
}

/// The tentpole invariant: one sample per round, per-round I/O deltas
/// telescoping exactly to the run-level delta, and per-round engine
/// counters summing to the run totals.
fn assert_trace_consistent(r: &RunReport, workers: usize, what: &str) {
    let tr = r.trace.as_ref().unwrap_or_else(|| panic!("{what}: trace missing"));
    assert_eq!(tr.dropped(), 0, "{what}: ring must not overflow here");
    assert_eq!(tr.len() as u64, r.rounds, "{what}: one sample per round");
    assert_eq!(tr.rounds_recorded(), r.rounds, "{what}");
    assert_eq!(
        counters_only(tr.io_sum()),
        counters_only(r.io),
        "{what}: per-round I/O deltas must sum exactly to the run delta"
    );
    let sent: u64 = tr.samples().map(|s| s.sent).sum();
    let delivered: u64 = tr.samples().map(|s| s.delivered).sum();
    let combined: u64 = tr.samples().map(|s| s.combined).sum();
    let steals: u64 = tr.samples().map(|s| s.steals).sum();
    assert_eq!(sent, r.engine.p2p_msgs + r.engine.multicast_msgs, "{what}: sends");
    assert_eq!(delivered, r.engine.deliveries, "{what}: deliveries");
    assert_eq!(combined, r.engine.combined_msgs, "{what}: combiner folds");
    assert_eq!(steals, r.engine.steals, "{what}: steals");
    for s in tr.samples() {
        assert_eq!(s.workers.len(), workers, "{what}: phase slots per round");
    }
    // the export is valid JSON with one entry per round
    let j = graphyti::util::Json::parse(&tr.to_json().encode()).unwrap();
    assert_eq!(j.get("rounds").unwrap().as_u64(), Some(r.rounds), "{what}: JSON rounds");
    let samples = j.get("samples").unwrap().as_array().unwrap();
    assert_eq!(samples.len() as u64, r.rounds, "{what}: JSON samples");
}

/// Test-unique `tag` prefix keeps concurrently-running tests from
/// racing on the same temp image paths.
fn workloads(tag: &str) -> Vec<(PathBuf, &'static str)> {
    // a hub star (frontier collapses onto vertex 0) and a hubby R-MAT
    vec![
        (build_image(512, &gen::star(512), true, &format!("{tag}-star")), "star"),
        (build_image(1024, &gen::rmat(10, 12_000, 7), true, &format!("{tag}-rmat")), "rmat"),
    ]
}

#[test]
fn pagerank_trace_deltas_sum_to_run_delta() {
    for (base, name) in workloads("pr") {
        for workers in [1usize, 2, 8] {
            let g = open_small(&base);
            let ecfg = EngineConfig { workers, trace: true, ..Default::default() };
            let r = pagerank_push(&g, 0.85, 1e-10, &ecfg).report;
            assert!(r.rounds > 1, "{name}: need a multi-round run");
            assert_trace_consistent(&r, workers, &format!("pagerank/{name}/w{workers}"));
        }
        cleanup(&base);
    }
}

#[test]
fn bc_queue_transport_trace_deltas_sum_to_run_delta() {
    for (base, name) in workloads("bc") {
        for workers in [1usize, 2, 8] {
            let g = open_small(&base);
            let ecfg = EngineConfig {
                workers,
                trace: true,
                transport: TransportMode::Queue,
                ..Default::default()
            };
            let sources: Vec<VertexId> = vec![0, 1, 2];
            let r = betweenness(&g, &sources, BcVariant::MultiSourceSync, &ecfg).report;
            assert!(r.rounds > 1, "{name}: need a multi-round run");
            assert_trace_consistent(&r, workers, &format!("bc/{name}/w{workers}"));
        }
        cleanup(&base);
    }
}

#[test]
fn tracing_is_allocation_free_once_warm() {
    // the trace recorder preallocates its ring: a traced run must show
    // exactly the allocation counters of an untraced one
    let base = build_image(1024, &gen::rmat(10, 12_000, 9), true, "alloc");
    let run = |trace: bool| {
        let g = open_small(&base);
        let ecfg = EngineConfig { workers: 1, trace, ..Default::default() };
        pagerank_push(&g, 0.85, 1e-10, &ecfg).report
    };
    let off = run(false);
    let on = run(true);
    assert!(off.trace.is_none() && on.trace.is_some());
    assert_eq!(
        on.engine.fetch_allocs, off.engine.fetch_allocs,
        "tracing must not change fetch-arena allocations"
    );
    assert_eq!(
        on.engine.msg_allocs, off.engine.msg_allocs,
        "tracing must not change message-lane allocations"
    );
    assert_eq!(on.engine.msg_allocs, 0, "combiner steady state allocates nothing");
    cleanup(&base);
}
