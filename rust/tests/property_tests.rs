//! Property-based tests over random graphs (hand-rolled driver —
//! proptest is unavailable offline; see `graphyti::util::prop`).
//!
//! Each property runs over many seeded random graphs and shrinks the
//! failing size on violation. These pin down the *invariants* of the
//! engine and the algorithms rather than specific outputs.

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::bfs::{bfs, ms_bfs};
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::sssp::sssp;
use graphyti::algs::triangles::{triangles, IntersectStrategy, OrderMode, TriangleOptions};
use graphyti::algs::wcc::wcc;
use graphyti::engine::EngineConfig;
use graphyti::graph::csr::Csr;
use graphyti::graph::source::MemGraph;
use graphyti::prop_assert;
use graphyti::util::prop::{for_random_cases, Size};
use graphyti::util::XorShift;
use graphyti::VertexId;

/// Random edge list over `size` vertices with ~4x edges.
fn random_edges(rng: &mut XorShift, n: usize) -> Vec<(VertexId, VertexId)> {
    let m = n * 4;
    (0..m)
        .map(|_| (rng.next_below(n as u64) as VertexId, rng.next_below(n as u64) as VertexId))
        .collect()
}

fn cfg() -> EngineConfig {
    EngineConfig { workers: 4, batch: 64, ..Default::default() }
}

#[test]
fn prop_pagerank_mass_conserved_and_positive() {
    for_random_cases(12, 256, 0xA1, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let r = pagerank_push(&g, 0.85, 1e-12, &cfg());
        let total: f64 = r.rank.iter().sum();
        prop_assert!(r.rank.iter().all(|&x| x >= 0.0), "negative rank");
        prop_assert!(total <= 1.0 + 1e-9, "mass {total} exceeds 1");
        prop_assert!(total > 0.1, "mass {total} vanished");
        Ok(())
    });
}

#[test]
fn prop_bfs_levels_respect_edges() {
    // triangle inequality on levels: an edge (u, v) implies
    // level(v) <= level(u) + 1 when u is reachable
    for_random_cases(12, 256, 0xB2, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (lv, _) = bfs(&g, 0, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        for u in 0..n as VertexId {
            if lv[u as usize] < 0 {
                continue;
            }
            for &v in csr.out(u) {
                prop_assert!(
                    lv[v as usize] >= 0 && lv[v as usize] <= lv[u as usize] + 1,
                    "edge ({u},{v}) violates BFS levels {} -> {}",
                    lv[u as usize],
                    lv[v as usize]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ms_bfs_equals_repeated_uni_bfs() {
    for_random_cases(10, 200, 0xC3, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let k = 1 + rng.next_below(16) as usize;
        let sources: Vec<VertexId> =
            (0..k).map(|_| rng.next_below(n as u64) as VertexId).collect();
        let mut distinct = sources.clone();
        distinct.sort_unstable();
        distinct.dedup();
        let (ecc, _) = ms_bfs(&g, &distinct, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        for (lane, &s) in distinct.iter().enumerate() {
            let want = oracle::eccentricity(&csr, s);
            prop_assert!(ecc[lane] == want, "lane {lane} src {s}: {} != {want}", ecc[lane]);
        }
        Ok(())
    });
}

#[test]
fn prop_coreness_invariants() {
    // every vertex's coreness <= its degree; the k-max core is non-empty;
    // all three variants agree
    for_random_cases(10, 200, 0xD4, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, false);
        let a = coreness(&g, CorenessOptions::unoptimized(), &cfg());
        let b = coreness(&g, CorenessOptions::graphyti(), &cfg());
        prop_assert!(a.core == b.core, "variants disagree");
        let csr = Csr::from_edges(n, &edges, false);
        for v in 0..n as VertexId {
            prop_assert!(
                a.core[v as usize] <= csr.out_deg(v),
                "core[{v}]={} > deg={}",
                a.core[v as usize],
                csr.out_deg(v)
            );
        }
        // maximality: in the subgraph of vertices with core >= kmax, every
        // vertex has degree >= kmax
        let kmax = *a.core.iter().max().unwrap();
        for v in 0..n as VertexId {
            if a.core[v as usize] == kmax {
                let d = csr
                    .out(v)
                    .iter()
                    .filter(|&&u| a.core[u as usize] >= kmax)
                    .count() as u32;
                prop_assert!(d >= kmax, "v{v} in kmax-core has only {d} core-neighbors");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_triangle_strategies_agree() {
    for_random_cases(8, 128, 0xE5, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let csr = Csr::from_edges(n, &edges, false);
        let want = oracle::triangle_count(&csr);
        for strategy in [
            IntersectStrategy::Scan,
            IntersectStrategy::RestartBinary,
            IntersectStrategy::Hash { threshold: 8 },
        ] {
            for order in [OrderMode::LowId, OrderMode::HighDegree] {
                let g = MemGraph::from_edges(n, &edges, false);
                let got = triangles(
                    &g,
                    TriangleOptions { strategy, order, prefetch: false, prefilter: true },
                    &cfg(),
                );
                prop_assert!(
                    got.triangles == want,
                    "{strategy:?}/{order:?}: {} != {want}",
                    got.triangles
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wcc_is_equivalence_over_edges() {
    for_random_cases(12, 256, 0xF6, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (labels, _) = wcc(&g, &cfg());
        // every edge endpoint pair shares a label; labels are canonical
        // (the minimum vertex id of the component)
        for &(u, v) in &edges {
            if u != v {
                prop_assert!(
                    labels[u as usize] == labels[v as usize],
                    "edge ({u},{v}) crosses components"
                );
            }
        }
        for v in 0..n as VertexId {
            prop_assert!(labels[v as usize] <= v, "label above own id at {v}");
            let l = labels[v as usize];
            prop_assert!(labels[l as usize] == l, "label {l} not canonical");
        }
        Ok(())
    });
}

#[test]
fn prop_sssp_triangle_inequality() {
    for_random_cases(10, 200, 0x17, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (dist, _) = sssp(&g, 0, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        prop_assert!(dist[0] == 0, "source distance nonzero");
        for u in 0..n as VertexId {
            if dist[u as usize] == u64::MAX {
                continue;
            }
            for &v in csr.out(u) {
                let w = oracle::edge_weight(u, v);
                prop_assert!(
                    dist[v as usize] <= dist[u as usize] + w,
                    "edge ({u},{v}) relaxable: {} > {} + {w}",
                    dist[v as usize],
                    dist[u as usize]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bc_variants_agree_and_nonnegative() {
    for_random_cases(6, 128, 0x28, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let sources: Vec<VertexId> = vec![
            rng.next_below(n as u64) as VertexId,
            rng.next_below(n as u64) as VertexId,
            rng.next_below(n as u64) as VertexId,
        ];
        let mut distinct = sources;
        distinct.sort_unstable();
        distinct.dedup();
        let g = MemGraph::from_edges(n, &edges, true);
        let a = betweenness(&g, &distinct, BcVariant::MultiSourceAsync, &cfg());
        let b = betweenness(&g, &distinct, BcVariant::UniSource, &cfg());
        for (i, (x, y)) in a.bc.iter().zip(&b.bc).enumerate() {
            prop_assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "bc[{i}]: {x} vs {y}");
            prop_assert!(*x >= -1e-12, "negative centrality at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_engine_deterministic_across_workers() {
    for_random_cases(8, 200, 0x39, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (lv1, _) = bfs(&g, 0, &EngineConfig { workers: 1, ..Default::default() });
        let (lv8, _) = bfs(&g, 0, &EngineConfig { workers: 8, batch: 16, ..Default::default() });
        prop_assert!(lv1 == lv8, "BFS differs across worker counts");
        Ok(())
    });
}
