//! Property-based tests over random graphs (hand-rolled driver —
//! proptest is unavailable offline; see `graphyti::util::prop`).
//!
//! Each property runs over many seeded random graphs and shrinks the
//! failing size on violation. These pin down the *invariants* of the
//! engine and the algorithms rather than specific outputs.

use graphyti::algs::bc::{betweenness, BcVariant};
use graphyti::algs::bfs::{bfs, ms_bfs};
use graphyti::algs::coreness::{coreness, CorenessOptions};
use graphyti::algs::oracle;
use graphyti::algs::pagerank::pagerank_push;
use graphyti::algs::sssp::sssp;
use graphyti::algs::triangles::{triangles, IntersectStrategy, OrderMode, TriangleOptions};
use graphyti::algs::wcc::wcc;
use graphyti::engine::{
    frontier_summary_word, source_bucket, EngineConfig, RunMode, CHUNK_BITS,
};
use graphyti::graph::csr::Csr;
use graphyti::graph::source::MemGraph;
use graphyti::prop_assert;
use graphyti::util::prop::{for_random_cases, Size};
use graphyti::util::{AtomicBitmap, XorShift};
use graphyti::VertexId;

/// Random edge list over `size` vertices with ~4x edges.
fn random_edges(rng: &mut XorShift, n: usize) -> Vec<(VertexId, VertexId)> {
    let m = n * 4;
    (0..m)
        .map(|_| (rng.next_below(n as u64) as VertexId, rng.next_below(n as u64) as VertexId))
        .collect()
}

fn cfg() -> EngineConfig {
    EngineConfig { workers: 4, batch: 64, ..Default::default() }
}

#[test]
fn prop_pagerank_mass_conserved_and_positive() {
    for_random_cases(12, 256, 0xA1, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let r = pagerank_push(&g, 0.85, 1e-12, &cfg());
        let total: f64 = r.rank.iter().sum();
        prop_assert!(r.rank.iter().all(|&x| x >= 0.0), "negative rank");
        prop_assert!(total <= 1.0 + 1e-9, "mass {total} exceeds 1");
        prop_assert!(total > 0.1, "mass {total} vanished");
        Ok(())
    });
}

#[test]
fn prop_bfs_levels_respect_edges() {
    // triangle inequality on levels: an edge (u, v) implies
    // level(v) <= level(u) + 1 when u is reachable
    for_random_cases(12, 256, 0xB2, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (lv, _) = bfs(&g, 0, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        for u in 0..n as VertexId {
            if lv[u as usize] < 0 {
                continue;
            }
            for &v in csr.out(u) {
                prop_assert!(
                    lv[v as usize] >= 0 && lv[v as usize] <= lv[u as usize] + 1,
                    "edge ({u},{v}) violates BFS levels {} -> {}",
                    lv[u as usize],
                    lv[v as usize]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ms_bfs_equals_repeated_uni_bfs() {
    for_random_cases(10, 200, 0xC3, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let k = 1 + rng.next_below(16) as usize;
        let sources: Vec<VertexId> =
            (0..k).map(|_| rng.next_below(n as u64) as VertexId).collect();
        let mut distinct = sources.clone();
        distinct.sort_unstable();
        distinct.dedup();
        let (ecc, _) = ms_bfs(&g, &distinct, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        for (lane, &s) in distinct.iter().enumerate() {
            let want = oracle::eccentricity(&csr, s);
            prop_assert!(ecc[lane] == want, "lane {lane} src {s}: {} != {want}", ecc[lane]);
        }
        Ok(())
    });
}

#[test]
fn prop_coreness_invariants() {
    // every vertex's coreness <= its degree; the k-max core is non-empty;
    // all three variants agree
    for_random_cases(10, 200, 0xD4, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, false);
        let a = coreness(&g, CorenessOptions::unoptimized(), &cfg());
        let b = coreness(&g, CorenessOptions::graphyti(), &cfg());
        prop_assert!(a.core == b.core, "variants disagree");
        let csr = Csr::from_edges(n, &edges, false);
        for v in 0..n as VertexId {
            prop_assert!(
                a.core[v as usize] <= csr.out_deg(v),
                "core[{v}]={} > deg={}",
                a.core[v as usize],
                csr.out_deg(v)
            );
        }
        // maximality: in the subgraph of vertices with core >= kmax, every
        // vertex has degree >= kmax
        let kmax = *a.core.iter().max().unwrap();
        for v in 0..n as VertexId {
            if a.core[v as usize] == kmax {
                let d = csr
                    .out(v)
                    .iter()
                    .filter(|&&u| a.core[u as usize] >= kmax)
                    .count() as u32;
                prop_assert!(d >= kmax, "v{v} in kmax-core has only {d} core-neighbors");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_triangle_strategies_agree() {
    for_random_cases(8, 128, 0xE5, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let csr = Csr::from_edges(n, &edges, false);
        let want = oracle::triangle_count(&csr);
        for strategy in [
            IntersectStrategy::Scan,
            IntersectStrategy::RestartBinary,
            IntersectStrategy::Hash { threshold: 8 },
        ] {
            for order in [OrderMode::LowId, OrderMode::HighDegree] {
                let g = MemGraph::from_edges(n, &edges, false);
                let got = triangles(
                    &g,
                    TriangleOptions { strategy, order, prefetch: false, prefilter: true },
                    &cfg(),
                );
                prop_assert!(
                    got.triangles == want,
                    "{strategy:?}/{order:?}: {} != {want}",
                    got.triangles
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wcc_is_equivalence_over_edges() {
    for_random_cases(12, 256, 0xF6, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (labels, _) = wcc(&g, &cfg());
        // every edge endpoint pair shares a label; labels are canonical
        // (the minimum vertex id of the component)
        for &(u, v) in &edges {
            if u != v {
                prop_assert!(
                    labels[u as usize] == labels[v as usize],
                    "edge ({u},{v}) crosses components"
                );
            }
        }
        for v in 0..n as VertexId {
            prop_assert!(labels[v as usize] <= v, "label above own id at {v}");
            let l = labels[v as usize];
            prop_assert!(labels[l as usize] == l, "label {l} not canonical");
        }
        Ok(())
    });
}

#[test]
fn prop_sssp_triangle_inequality() {
    for_random_cases(10, 200, 0x17, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (dist, _) = sssp(&g, 0, &cfg());
        let csr = Csr::from_edges(n, &edges, true);
        prop_assert!(dist[0] == 0, "source distance nonzero");
        for u in 0..n as VertexId {
            if dist[u as usize] == u64::MAX {
                continue;
            }
            for &v in csr.out(u) {
                let w = oracle::edge_weight(u, v);
                prop_assert!(
                    dist[v as usize] <= dist[u as usize] + w,
                    "edge ({u},{v}) relaxable: {} > {} + {w}",
                    dist[v as usize],
                    dist[u as usize]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bc_variants_agree_and_nonnegative() {
    for_random_cases(6, 128, 0x28, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let sources: Vec<VertexId> = vec![
            rng.next_below(n as u64) as VertexId,
            rng.next_below(n as u64) as VertexId,
            rng.next_below(n as u64) as VertexId,
        ];
        let mut distinct = sources;
        distinct.sort_unstable();
        distinct.dedup();
        let g = MemGraph::from_edges(n, &edges, true);
        let a = betweenness(&g, &distinct, BcVariant::MultiSourceAsync, &cfg());
        let b = betweenness(&g, &distinct, BcVariant::UniSource, &cfg());
        for (i, (x, y)) in a.bc.iter().zip(&b.bc).enumerate() {
            prop_assert!((x - y).abs() < 1e-9 * (1.0 + y.abs()), "bc[{i}]: {x} vs {y}");
            prop_assert!(*x >= -1e-12, "negative centrality at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_block_filter_skip_is_always_safe() {
    // The pull-round block filter skips an edge block when the block's
    // source-bucket summary is disjoint from the frontier's summary
    // word. Safety invariant, replayed here over random graphs and
    // random frontiers exactly as the engine computes it: a block
    // declared skippable must contain NO vertex with an active
    // in-neighbor — otherwise the skip would drop a message.
    for_random_cases(16, 512, 0x4B, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let csr = Csr::from_edges(n, &edges, true);
        // random frontier, deliberately including empty and near-full
        let density = 1 + rng.next_below(8);
        let active = AtomicBitmap::new(n);
        for v in 0..n {
            if rng.next_below(8) < density {
                active.set(v);
            }
        }
        let fsummary = frontier_summary_word(&active, n);
        // per-vertex bucket membership must be covered by the summary
        for v in 0..n as VertexId {
            if active.get(v as usize) {
                prop_assert!(
                    fsummary & (1 << source_bucket(v, n)) != 0,
                    "active v{v} (bucket {}) missing from summary {fsummary:#x}",
                    source_bucket(v, n)
                );
            }
        }
        // per-block summaries, built the way a pull round's first full
        // scan builds them: union of in-neighbor buckets over the chunk
        for c in 0..n.div_ceil(CHUNK_BITS) {
            let start = c * CHUNK_BITS;
            let end = ((c + 1) * CHUNK_BITS).min(n);
            let mut block = 0u64;
            for dst in start..end {
                for &src in csr.inn(dst as VertexId) {
                    block |= 1 << source_bucket(src, n);
                }
            }
            if block & fsummary != 0 {
                continue; // not skippable; nothing to prove
            }
            for dst in start..end {
                for &src in csr.inn(dst as VertexId) {
                    prop_assert!(
                        !active.get(src as usize),
                        "block {c} skipped but dst {dst} has active in-src {src}"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_block_filter_skips_on_sparse_sources() {
    // the filter must not just be safe but *useful*: on banded graphs
    // (u → u + band, so each destination chunk's sources sit in a
    // narrow bucket range) a pull BFS whose per-round frontier is a
    // handful of vertices must actually skip blocks — and still match
    // push exactly
    for_random_cases(8, 8, 0x6D, |rng, Size(chunks)| {
        let chunks = chunks.max(3);
        let n = chunks * CHUNK_BITS;
        let band = CHUNK_BITS * (1 + rng.next_below(chunks as u64 - 1) as usize);
        let edges: Vec<(VertexId, VertexId)> =
            (0..n).map(|u| (u as VertexId, ((u + band) % n) as VertexId)).collect();
        let run = |mode: RunMode| {
            let g = MemGraph::from_edges(n, &edges, true);
            let c = EngineConfig { workers: 2, batch: 64, mode, ..Default::default() };
            bfs(&g, 0, &c)
        };
        let (push, _) = run(RunMode::Push);
        let (pull, rp) = run(RunMode::Pull);
        prop_assert!(pull == push, "banded pull diverged (band {band}, n {n})");
        prop_assert!(
            rp.engine.blocks_skipped > 0,
            "sparse-source pull rounds skipped nothing (band {band}, n {n}): {:?}",
            rp.engine
        );
        Ok(())
    });
}

#[test]
fn prop_pull_and_auto_modes_match_push() {
    // direction choice is an optimization, never an answer change: BFS
    // levels under forced pull and auto must equal forced push on
    // random graphs, at several worker counts
    for_random_cases(10, 256, 0x5C, |rng, Size(n)| {
        let n = n.max(8);
        let edges = random_edges(rng, n);
        let run = |mode: RunMode, workers: usize| {
            let g = MemGraph::from_edges(n, &edges, true);
            let c = EngineConfig { workers, batch: 64, mode, ..Default::default() };
            bfs(&g, 0, &c)
        };
        let (push, _) = run(RunMode::Push, 4);
        for workers in [1, 4] {
            let (pull, rp) = run(RunMode::Pull, workers);
            prop_assert!(pull == push, "pull(w={workers}) diverged from push");
            prop_assert!(
                rp.engine.pull_rounds == rp.engine.rounds,
                "forced pull ran {} of {} rounds as pull",
                rp.engine.pull_rounds,
                rp.engine.rounds
            );
            let (auto, _) = run(RunMode::Auto, workers);
            prop_assert!(auto == push, "auto(w={workers}) diverged from push");
        }
        Ok(())
    });
}

#[test]
fn prop_engine_deterministic_across_workers() {
    for_random_cases(8, 200, 0x39, |rng, Size(n)| {
        let n = n.max(4);
        let edges = random_edges(rng, n);
        let g = MemGraph::from_edges(n, &edges, true);
        let (lv1, _) = bfs(&g, 0, &EngineConfig { workers: 1, ..Default::default() });
        let (lv8, _) = bfs(&g, 0, &EngineConfig { workers: 8, batch: 16, ..Default::default() });
        prop_assert!(lv1 == lv8, "BFS differs across worker counts");
        Ok(())
    });
}
