//! Service-mode integration tests: the multi-tenant daemon end to end.
//!
//! Covers the acceptance contract of the service subsystem:
//! * ≥ 3 concurrent jobs with mixed algorithms against ONE shared graph
//!   image, results matching the in-memory oracle path;
//! * per-job IoStats deltas disjointly attributed (they sum exactly to
//!   the shared substrate's counters);
//! * a job exceeding the admission budget is rejected, over-headroom
//!   jobs queue and serialize under the budget;
//! * cooperative cancellation at engine round boundaries;
//! * the JSON-lines TCP protocol round trip.

use std::path::PathBuf;
use std::time::Duration;

use graphyti::coordinator::{open_graph, run_alg, AlgSpec, GraphMode, RunConfig};
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::gen;
use graphyti::service::protocol::Json;
use graphyti::service::{
    call, GraphService, JobRequest, JobState, ServiceConfig, ServiceServer,
};

fn build_image(tag: &str, directed: bool, scale: u32, m: usize) -> PathBuf {
    let n = 1usize << scale;
    let base = std::env::temp_dir().join(format!(
        "graphyti-svcmode-{}-{tag}",
        std::process::id()
    ));
    let edges = gen::rmat(scale, m, 99);
    let mut b = GraphBuilder::new(n, directed);
    b.add_edges(&edges);
    b.build_files(&base).unwrap();
    base
}

fn cleanup(base: &PathBuf) {
    let _ = std::fs::remove_file(base.with_extension("gy-idx"));
    let _ = std::fs::remove_file(base.with_extension("gy-adj"));
}

/// Oracle: the same algorithm through the fully in-memory path.
fn mem_summary(base: &PathBuf, alg: &str, variant: &str, num: usize) -> String {
    let cfg = RunConfig::default();
    let spec = AlgSpec::parse(alg, variant, num).unwrap();
    let mem = open_graph(base, GraphMode::Mem, &cfg).unwrap();
    run_alg(mem.as_ref(), &spec, &cfg).summary
}

#[test]
fn concurrent_mixed_jobs_share_one_image_with_disjoint_io() {
    // undirected so coreness/triangles are well-defined alongside
    // pagerank/wcc/bfs — five algorithms, one shared image
    let base = build_image("mixed", false, 10, 12_000);
    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1, // one small shared cache for all five jobs
        exec_threads: 3,
        budget_bytes: 64 << 20,
        default_workers: 2,
        ..Default::default()
    });
    let before = svc.substrate_stats();
    let specs = [
        ("pagerank", ""),
        ("wcc", ""),
        ("bfs", ""),
        ("coreness", ""),
        ("triangles", ""),
    ];
    let mut ids = Vec::new();
    for (alg, variant) in specs {
        let mut req = JobRequest::new(base.clone(), alg);
        req.variant = variant.to_string();
        req.num = 0; // bfs source 0; ignored by the others
        ids.push(svc.submit(req).unwrap());
    }
    let mut statuses = Vec::new();
    for &id in &ids {
        let st = svc.wait(id, Duration::from_secs(300)).expect("job exists");
        assert_eq!(st.state, JobState::Done, "{st:?}");
        statuses.push(st);
    }
    // one shared graph image, opened once
    assert_eq!(svc.registry().num_graphs(), 1);

    // results match the in-memory oracle path exactly
    for (st, (alg, variant)) in statuses.iter().zip(specs) {
        let want = mem_summary(&base, alg, variant, 0);
        assert_eq!(st.summary.as_deref(), Some(want.as_str()), "{alg} diverged");
    }

    // per-job I/O is disjointly attributed: each job saw traffic, and
    // the per-job deltas sum exactly to the shared substrate's counters.
    // The engine now fetches through the zero-copy arena path
    // (JobGraph::fetch_batch_into → SemGraph::fetch_batch_tracked_into),
    // so these equalities prove the arena preserved exact attribution.
    let global = svc.substrate_stats().delta(&before);
    let sum_reqs: u64 = statuses.iter().map(|s| s.io.read_requests).sum();
    let sum_logical: u64 = statuses.iter().map(|s| s.io.logical_bytes).sum();
    let sum_hits: u64 = statuses.iter().map(|s| s.io.cache_hits).sum();
    let sum_misses: u64 = statuses.iter().map(|s| s.io.cache_misses).sum();
    let sum_preads: u64 = statuses.iter().map(|s| s.io.physical_reads).sum();
    let sum_disk: u64 = statuses.iter().map(|s| s.io.bytes_read).sum();
    for st in &statuses {
        assert!(st.io.read_requests > 0, "job did no I/O: {st:?}");
        assert!(st.io.logical_bytes > 0, "job read no bytes: {st:?}");
    }
    assert_eq!(sum_reqs, global.read_requests, "read requests not disjoint");
    assert_eq!(sum_logical, global.logical_bytes, "logical bytes not disjoint");
    // demand lookups all flow through tracked gets: hit/miss counters
    // are fully attributed (prefetch peeks don't touch them)
    assert_eq!(sum_hits, global.cache_hits, "cache hits not disjoint");
    assert_eq!(sum_misses, global.cache_misses, "cache misses not disjoint");
    // physical reads/bytes include *unattributed speculative prefetch*
    // in the global counters, so per-job sums are a lower bound that
    // must never exceed the substrate totals
    assert!(sum_preads <= global.physical_reads, "{sum_preads} > {}", global.physical_reads);
    assert!(sum_disk <= global.bytes_read, "{sum_disk} > {}", global.bytes_read);
    assert!(sum_preads > 0, "tiny shared cache must force physical reads");

    svc.shutdown();
    cleanup(&base);
}

#[test]
fn admission_budget_rejects_and_serializes() {
    let base = build_image("adm", true, 11, 20_000); // n = 2048
    // pagerank footprint at 2 workers, fetch_window 2: program state
    // 2048 * 32 + combiner lanes 2 * 2 * 2048 * 9 + fetch slots
    // 2 * 3 * 65,536 + 2048/4 + 4096 = 537,088 bytes. budget fits
    // exactly one such job at a time.
    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1,
        exec_threads: 2,
        budget_bytes: 600_000,
        default_workers: 2,
        ..Default::default()
    });

    // a job that could never fit is rejected at submit time
    let mut big = JobRequest::new(base.clone(), "bc");
    big.num = 64; // per-source state blows the budget
    let big_id = svc.submit(big).unwrap();
    let st = svc.status(big_id).unwrap();
    assert_eq!(st.state, JobState::Rejected, "{st:?}");
    assert!(st.error.as_deref().unwrap_or("").contains("budget"), "{st:?}");

    // three jobs that fit one-at-a-time: all must finish, and the
    // admission high-water mark must never exceed the budget
    let ids: Vec<u64> = (0..3)
        .map(|_| svc.submit(JobRequest::new(base.clone(), "pagerank")).unwrap())
        .collect();
    for id in ids {
        let st = svc.wait(id, Duration::from_secs(300)).unwrap();
        assert_eq!(st.state, JobState::Done, "{st:?}");
    }
    assert!(svc.admission().peak() <= 600_000, "peak {}", svc.admission().peak());
    assert!(svc.admission().peak() > 0);
    assert_eq!(svc.admission().in_use(), 0, "all footprints released");

    svc.shutdown();
    cleanup(&base);
}

#[test]
fn running_job_cancels_at_round_boundary() {
    let base = build_image("cancel", true, 10, 10_000);
    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1,
        exec_threads: 1,
        ..Default::default()
    });
    // negative threshold: residual push never converges, so the job
    // runs until cancelled — deterministic, no timing races
    let mut req = JobRequest::new(base.clone(), "pagerank");
    req.overrides.push(("threshold".to_string(), "-1".to_string()));
    let id = svc.submit(req).unwrap();
    // give it a moment to be picked up, then cancel
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let st = svc.status(id).unwrap();
        if st.state == JobState::Running || std::time::Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(svc.cancel(id), "cancel must be accepted");
    let st = svc.wait(id, Duration::from_secs(120)).unwrap();
    assert_eq!(st.state, JobState::Cancelled, "{st:?}");
    assert!(st.rounds > 0, "ran at least one round: {st:?}");
    assert_eq!(svc.admission().in_use(), 0, "cancelled job released its footprint");

    // a queued job cancels immediately without running
    let mut blocker = JobRequest::new(base.clone(), "pagerank");
    blocker.overrides.push(("threshold".to_string(), "-1".to_string()));
    let blocker_id = svc.submit(blocker).unwrap();
    let queued_id = svc.submit(JobRequest::new(base.clone(), "wcc")).unwrap();
    assert!(svc.cancel(queued_id));
    let st = svc.wait(queued_id, Duration::from_secs(60)).unwrap();
    assert_eq!(st.state, JobState::Cancelled, "{st:?}");
    assert_eq!(st.rounds, 0, "queued-cancelled job never ran");
    assert!(svc.cancel(blocker_id));
    let st = svc.wait(blocker_id, Duration::from_secs(120)).unwrap();
    assert_eq!(st.state, JobState::Cancelled);

    svc.shutdown();
    cleanup(&base);
}

#[test]
fn tcp_protocol_round_trip() {
    let base = build_image("tcp", false, 9, 5_000);
    let svc = GraphService::start(ServiceConfig {
        cache_mb: 1,
        exec_threads: 2,
        ..Default::default()
    });
    let server = ServiceServer::start(svc, "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();
    let t = Duration::from_secs(120);

    // submit over the wire
    let submit = Json::obj(vec![
        ("op", Json::s("submit")),
        ("graph", Json::s(base.display().to_string())),
        ("alg", Json::s("wcc")),
        ("priority", Json::u(7)),
    ]);
    let resp = call(&addr, &submit, t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{}", resp.encode());
    let id = resp.get("job").and_then(Json::as_u64).unwrap();

    // wait for completion and check the result against the oracle
    let wait = Json::obj(vec![
        ("op", Json::s("wait")),
        ("job", Json::u(id)),
        ("timeout_ms", Json::u(60_000)),
    ]);
    let resp = call(&addr, &wait, t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{}", resp.encode());
    let job = resp.get("job").unwrap();
    assert_eq!(job.get("state").and_then(Json::as_str), Some("done"), "{}", resp.encode());
    let want = mem_summary(&base, "wcc", "", 0);
    assert_eq!(job.get("summary").and_then(Json::as_str), Some(want.as_str()));
    assert!(
        job.get("io").and_then(|io| io.get("read_requests")).and_then(Json::as_u64)
            > Some(0),
        "{}",
        resp.encode()
    );

    // malformed + unknown requests answer with errors, not hangups
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("status"))]), t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("nope"))]), t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));

    // stats op reflects the shared substrate
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("stats"))]), t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("graphs").and_then(Json::as_u64), Some(1));
    assert!(
        resp.get("io").and_then(|io| io.get("read_requests")).and_then(Json::as_u64)
            > Some(0)
    );

    // metrics op: unified registry with I/O latency quantiles and the
    // engine counter aggregates, in both renderings
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("metrics"))]), t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "{}", resp.encode());
    let m = resp.get("metrics").unwrap();
    let counters = m.get("counters").unwrap();
    assert!(
        counters.get("io_read_requests").and_then(Json::as_u64) > Some(0),
        "{}",
        m.encode()
    );
    for key in ["engine_vertex_runs", "engine_deliveries", "engine_rounds", "engine_steals"] {
        assert!(counters.get(key).and_then(Json::as_u64).is_some(), "missing {key}");
    }
    let hists = m.get("histograms").unwrap();
    let fetch = hists.get("io_fetch_latency_us").unwrap();
    assert!(fetch.get("count").and_then(Json::as_u64) > Some(0), "{}", m.encode());
    assert!(fetch.get("p50").and_then(Json::as_u64).is_some());
    assert!(fetch.get("p99").and_then(Json::as_u64).is_some());
    assert!(
        fetch.get("p99").and_then(Json::as_u64) >= fetch.get("p50").and_then(Json::as_u64)
    );

    let text_req =
        Json::obj(vec![("op", Json::s("metrics")), ("format", Json::s("text"))]);
    let resp = call(&addr, &text_req, t).unwrap();
    let text = resp.get("text").and_then(Json::as_str).unwrap();
    assert!(text.contains("# TYPE graphyti_io_read_requests counter"), "{text}");
    assert!(text.contains("graphyti_io_fetch_latency_us{quantile=\"0.99\"}"), "{text}");

    // shutdown op stops the service and the accept loop
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("shutdown"))]), t).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    server.wait();
    cleanup(&base);
}
