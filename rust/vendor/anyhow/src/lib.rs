//! Minimal, source-compatible subset of the `anyhow` crate for offline
//! builds.
//!
//! The real crate is not vendorable in this image (no registry access),
//! and graphyti only relies on a small surface: [`Error`], [`Result`],
//! the [`anyhow!`], [`bail!`] and [`ensure!`] macros, and the
//! [`Context`] extension trait. This shim implements exactly that
//! surface with the same semantics:
//!
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   [`Error`], preserving it as the source chain;
//! * `Display` shows the outermost message, `{:#}` joins the whole
//!   context/cause chain with `": "`, and `Debug` renders the chain in
//!   the familiar `Caused by:` layout;
//! * `.context(..)` / `.with_context(..)` wrap an error with an outer
//!   message.
//!
//! Swap this path dependency for the crates.io release when a networked
//! toolchain is available; no call sites need to change.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` alias, defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

enum Repr {
    /// Ad-hoc message (from `anyhow!` / `Error::msg`).
    Msg(String),
    /// Wrapped standard error, kept alive for its source chain.
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
}

/// A dynamic error with optional context frames and a cause chain.
pub struct Error {
    repr: Repr,
    /// Context frames, innermost first (most recently added last is the
    /// *outermost* message, matching anyhow).
    context: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { repr: Repr::Msg(message.to_string()), context: Vec::new() }
    }

    /// Build from a standard error, preserving its source chain.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Error { repr: Repr::Boxed(Box::new(error)), context: Vec::new() }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.push(context.to_string());
        self
    }

    /// Borrow a typed error from the wrapped error's cause chain, like
    /// anyhow's `downcast_ref`. Ad-hoc message errors hold no typed
    /// payload and always return `None`.
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        match &self.repr {
            Repr::Msg(_) => None,
            Repr::Boxed(boxed) => {
                let mut cur: Option<&(dyn StdError + 'static)> = Some(&**boxed);
                while let Some(e) = cur {
                    if let Some(typed) = e.downcast_ref::<E>() {
                        return Some(typed);
                    }
                    cur = e.source();
                }
                None
            }
        }
    }

    /// The full message chain, outermost first.
    fn chain_strings(&self) -> Vec<String> {
        let mut out: Vec<String> = self.context.iter().rev().cloned().collect();
        match &self.repr {
            Repr::Msg(m) => out.push(m.clone()),
            Repr::Boxed(e) => {
                out.push(e.to_string());
                let mut src = e.source();
                while let Some(s) = src {
                    out.push(s.to_string());
                    src = s.source();
                }
            }
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        if f.alternate() {
            write!(f, "{}", chain.join(": "))
        } else {
            write!(f, "{}", chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let chain = self.chain_strings();
        write!(f, "{}", chain[0])?;
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result`s whose error is a standard error.
pub trait Context<T, E> {
    /// Wrap the error with an outer message.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error with a lazily-built outer message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: StdError + Send + Sync + 'static,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tokens:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tokens)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($tokens:tt)*) => {
        if !($cond) {
            $crate::bail!($($tokens)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Error::new(io_err()).context("read config").context("startup");
        assert_eq!(format!("{e}"), "startup");
        assert_eq!(format!("{e:#}"), "startup: read config: missing thing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("missing thing"), "{dbg}");
    }

    #[test]
    fn result_context_trait() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening image").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening image: missing thing");
        let o: Option<u32> = None;
        let e = o.with_context(|| "empty slot").unwrap_err();
        assert_eq!(format!("{e}"), "empty slot");
    }

    #[test]
    fn downcast_ref_recovers_typed_errors() {
        let e = Error::new(io_err()).context("opening image");
        let io = e.downcast_ref::<std::io::Error>().expect("typed error in chain");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        let msg = anyhow!("plain message");
        assert!(msg.downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(0).unwrap_err()), "x too small: 0");
        assert_eq!(format!("{}", f(11).unwrap_err()), "x too big: 11");
        let e = anyhow!("plain {} message", 7);
        assert_eq!(format!("{e}"), "plain 7 message");
        let s = String::from("from expr");
        assert_eq!(format!("{}", anyhow!(s)), "from expr");
    }
}
