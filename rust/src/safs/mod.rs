//! SAFS-substitute: the semi-external-memory storage substrate.
//!
//! FlashGraph sits on SAFS (Zheng et al., "Toward Millions of File System
//! IOPS on Low-Cost, Commodity Hardware"), a userspace filesystem that
//! drives SSD arrays with asynchronous parallel I/O behind a configurable
//! page cache. This module is our laptop-scale stand-in with the same
//! interface obligations:
//!
//! * a **sharded clock page cache** of configurable capacity
//!   ([`page_cache::PageCache`]) — the paper's "2 GB page cache" knob;
//! * an **asynchronous parallel I/O pool** ([`io::IoPool`]) that services
//!   page reads on dedicated threads and **merges adjacent requests**,
//!   as SAFS does before dispatching to SSDs;
//! * global **I/O statistics** ([`stats::IoStats`]) — read bytes, request
//!   counts, cache hit/miss, merge counts — the quantities plotted in the
//!   paper's figures;
//! * optional **per-request latency injection** to emulate SSD access
//!   cost on machines whose OS page cache would otherwise absorb
//!   everything (see DESIGN.md §5).
//!
//! [`SemFile`] ties the three together: a file handle whose reads go
//! through the cache and pool.

pub mod file;
pub mod io;
pub mod page_cache;
pub mod stats;

pub use file::{PageChecksums, PendingRead, RangeBuf, RangeScratch, SemFile};
pub use io::{FaultPlan, IoConfig, IoError, IoErrorClass, IoPool};
pub use page_cache::{PageCache, PageRef, PAGE_SIZE};
pub use stats::{IoLatency, IoStats, IoStatsSnapshot};
