//! Sharded clock page cache.
//!
//! FlashGraph's configurable page cache is the central SEM knob: the paper
//! runs the 14 GB Twitter graph with a 2 GB cache. We implement a
//! second-chance (clock) cache sharded by page number to keep lock
//! contention off the hot lookup path. Pages are immutable once inserted
//! (graph images are read-only at run time), handed out as [`PageRef`]
//! views into shared run buffers so eviction never invalidates readers
//! and a coalesced multi-page read costs one allocation, not one per
//! page.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::stats::IoStats;

/// Cache / I/O page size in bytes (FlashGraph uses 4 KiB pages).
pub const PAGE_SIZE: usize = 4096;

/// Number of shards (power of two).
const SHARDS: usize = 64;

/// A zero-copy view of one page inside a shared run buffer.
///
/// The I/O pool services a coalesced run of pages as **one** allocation
/// (`Arc<[u8]>` of `npages * PAGE_SIZE` bytes); every page of the run is
/// a `PageRef` — the buffer handle plus the page's byte offset. Cloning
/// is two words and a refcount bump; no page bytes are ever copied
/// between the pool, the cache and readers.
///
/// Memory note: the run buffer stays alive until the last of its page
/// views drops, so evicting *some* pages of a run does not free bytes
/// until all of them go. Per partially evicted run the overshoot is
/// bounded by `max_run_pages × PAGE_SIZE`; in the worst case — an
/// access pattern that keeps exactly one page of every large run hot —
/// resident frames can pin up to `max_run_pages ×` the configured
/// cache bytes, and `resident_bytes()` does not see the difference.
/// Sequential SEM scans insert and evict whole runs together, so real
/// workloads sit near the per-run bound; deployments that mix a huge
/// `max_run_pages` with a small cache should shrink `max_run_pages`
/// (the knob that caps the amplification) rather than rely on it.
#[derive(Clone)]
pub struct PageRef {
    buf: Arc<[u8]>,
    offset: usize,
}

impl PageRef {
    /// View the `PAGE_SIZE` bytes of `buf` starting at `offset`.
    pub fn new(buf: Arc<[u8]>, offset: usize) -> Self {
        debug_assert!(offset + PAGE_SIZE <= buf.len());
        PageRef { buf, offset }
    }

    /// The page bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.offset..self.offset + PAGE_SIZE]
    }
}

impl std::ops::Deref for PageRef {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// One cached page.
struct Frame {
    page_no: u64,
    data: PageRef,
    ref_bit: bool,
}

/// What [`Shard::insert`] did — drives the exact residency/eviction
/// accounting in [`PageCache::insert`].
enum Inserted {
    /// A new frame was occupied (cache grew by one resident page).
    Fresh,
    /// A victim frame was replaced (resident count unchanged).
    Evicted,
    /// The page was already cached (raced duplicate insert; no change).
    Duplicate,
    /// The page is quarantined; the insert was refused (no change).
    Quarantined,
}

/// One shard: a clock over up to `cap` frames.
struct Shard {
    map: HashMap<u64, usize>,
    frames: Vec<Frame>,
    hand: usize,
    cap: usize,
    /// Pages with sticky corruption: never served, never re-admitted,
    /// never counted resident. Empty in every healthy process, so the
    /// hot-path check is one branch on `is_empty`.
    quarantined: HashSet<u64>,
}

impl Shard {
    fn get(&mut self, page_no: u64) -> Option<PageRef> {
        if !self.quarantined.is_empty() && self.quarantined.contains(&page_no) {
            return None;
        }
        let &idx = self.map.get(&page_no)?;
        self.frames[idx].ref_bit = true;
        Some(self.frames[idx].data.clone())
    }

    /// Quarantine a page, dropping its resident frame if present.
    /// Returns `(newly_quarantined, frame_dropped)`.
    fn quarantine(&mut self, page_no: u64) -> (bool, bool) {
        let newly = self.quarantined.insert(page_no);
        let mut dropped = false;
        if let Some(idx) = self.map.remove(&page_no) {
            self.frames.swap_remove(idx);
            if idx < self.frames.len() {
                let moved = self.frames[idx].page_no;
                self.map.insert(moved, idx);
            }
            // the clock hand may now point past the shrunk frame list
            if self.hand >= self.frames.len() {
                self.hand = 0;
            }
            dropped = true;
        }
        (newly, dropped)
    }

    /// Insert a page, evicting with second-chance if at capacity.
    fn insert(&mut self, page_no: u64, data: PageRef) -> Inserted {
        if !self.quarantined.is_empty() && self.quarantined.contains(&page_no) {
            return Inserted::Quarantined;
        }
        if let Some(&idx) = self.map.get(&page_no) {
            // raced: someone else inserted; keep theirs (identical bytes)
            self.frames[idx].ref_bit = true;
            return Inserted::Duplicate;
        }
        if self.frames.len() < self.cap {
            self.map.insert(page_no, self.frames.len());
            self.frames.push(Frame { page_no, data, ref_bit: true });
            return Inserted::Fresh;
        }
        // clock sweep for a victim
        loop {
            let f = &mut self.frames[self.hand];
            if f.ref_bit {
                f.ref_bit = false;
                self.hand = (self.hand + 1) % self.frames.len();
            } else {
                let victim = self.hand;
                self.map.remove(&self.frames[victim].page_no);
                self.map.insert(page_no, victim);
                self.frames[victim] = Frame { page_no, data, ref_bit: true };
                self.hand = (self.hand + 1) % self.frames.len();
                return Inserted::Evicted;
            }
        }
    }
}

/// Sharded clock page cache of `capacity_pages` total frames.
pub struct PageCache {
    shards: Vec<Mutex<Shard>>,
    capacity_pages: usize,
    resident: AtomicU64,
    stats: Arc<IoStats>,
}

impl PageCache {
    /// Build a cache holding at most `capacity_bytes` (rounded down to
    /// whole pages, min 1 page per shard). The effective capacity is
    /// rounded up to a whole number of frames per shard, and
    /// [`Self::capacity_pages`] reports that true frame bound, so
    /// `resident_pages() <= capacity_pages()` holds exactly.
    pub fn new(capacity_bytes: usize, stats: Arc<IoStats>) -> Self {
        let requested = (capacity_bytes / PAGE_SIZE).max(SHARDS);
        let per_shard = requested.div_ceil(SHARDS);
        let capacity_pages = per_shard * SHARDS;
        let shards = (0..SHARDS)
            .map(|_| {
                Mutex::new(Shard {
                    map: HashMap::with_capacity(per_shard * 2),
                    frames: Vec::with_capacity(per_shard),
                    hand: 0,
                    cap: per_shard,
                    quarantined: HashSet::new(),
                })
            })
            .collect();
        PageCache { shards, capacity_pages, resident: AtomicU64::new(0), stats }
    }

    #[inline]
    fn shard_of(&self, page_no: u64) -> &Mutex<Shard> {
        // multiplicative hash so consecutive pages land in different shards
        let h = (page_no.wrapping_mul(0x9E3779B97F4A7C15) >> 58) as usize;
        &self.shards[h % SHARDS]
    }

    /// Look up a page; counts hit/miss in stats.
    pub fn get(&self, page_no: u64) -> Option<PageRef> {
        self.get_tracked(page_no, None)
    }

    /// Look up a page, counting the hit/miss into the cache's own stats
    /// *and* into `extra` when given. `extra` is the per-job attribution
    /// channel for service mode: concurrent jobs sharing one cache each
    /// pass their own [`IoStats`], so every access lands in exactly one
    /// job's counters while the global ones still aggregate everything.
    pub fn get_tracked(&self, page_no: u64, extra: Option<&IoStats>) -> Option<PageRef> {
        let got = self.shard_of(page_no).lock().unwrap().get(page_no);
        if got.is_some() {
            self.stats.add_cache_hit(1);
            if let Some(s) = extra {
                s.add_cache_hit(1);
            }
        } else {
            self.stats.add_cache_miss(1);
            if let Some(s) = extra {
                s.add_cache_miss(1);
            }
        }
        got
    }

    /// Look up without touching hit/miss counters (used by prefetch).
    pub fn peek(&self, page_no: u64) -> Option<PageRef> {
        self.shard_of(page_no).lock().unwrap().get(page_no)
    }

    /// Insert a page read from disk. Only genuinely new frames bump the
    /// residency count: a raced duplicate insert (two batches missing on
    /// the same page) leaves residency untouched, and an eviction swaps
    /// a frame without changing it.
    pub fn insert(&self, page_no: u64, data: PageRef) {
        debug_assert_eq!(data.as_slice().len(), PAGE_SIZE);
        match self.shard_of(page_no).lock().unwrap().insert(page_no, data) {
            Inserted::Fresh => {
                self.resident.fetch_add(1, Ordering::Relaxed);
            }
            Inserted::Evicted => self.stats.add_eviction(1),
            Inserted::Duplicate | Inserted::Quarantined => {}
        }
    }

    /// Quarantine a page after sticky corruption (a checksum failure
    /// that survived its bounded re-read): the page is dropped from the
    /// cache if resident, will never be served or re-admitted for the
    /// life of this process, and stops counting toward residency. The
    /// `quarantined_pages` counter moves once per distinct page.
    pub fn quarantine(&self, page_no: u64) {
        let (newly, dropped) = self.shard_of(page_no).lock().unwrap().quarantine(page_no);
        if dropped {
            self.resident.fetch_sub(1, Ordering::Relaxed);
        }
        if newly {
            self.stats.add_quarantined(1);
        }
    }

    /// Is this page quarantined? The read path fast-fails these before
    /// probing or issuing I/O, so a quarantined page costs no disk
    /// traffic — only its owning job's typed failure.
    pub fn is_quarantined(&self, page_no: u64) -> bool {
        let sh = self.shard_of(page_no).lock().unwrap();
        !sh.quarantined.is_empty() && sh.quarantined.contains(&page_no)
    }

    /// Total pages currently quarantined across all shards.
    pub fn quarantined_pages(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().quarantined.len() as u64)
            .sum()
    }

    /// Total frame capacity in pages.
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }

    /// Currently resident pages. Exact: only [`Inserted::Fresh`] frames
    /// count, so no clamp is needed — the count can never exceed
    /// [`Self::capacity_pages`] (frames are only ever added up to each
    /// shard's cap, then recycled in place).
    pub fn resident_pages(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Resident bytes (approximate).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_pages() * PAGE_SIZE as u64
    }

    /// Fraction of frame capacity in use, in [0, 1] — the metrics
    /// export's cache fill gauge.
    pub fn occupancy(&self) -> f64 {
        self.resident_pages() as f64 / self.capacity_pages as f64
    }

    /// Shared stats handle.
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page(fill: u8) -> PageRef {
        PageRef::new(Arc::from(vec![fill; PAGE_SIZE].into_boxed_slice()), 0)
    }

    fn cache(pages: usize) -> PageCache {
        PageCache::new(pages * PAGE_SIZE, Arc::new(IoStats::new()))
    }

    #[test]
    fn hit_after_insert() {
        let c = cache(128);
        assert!(c.get(7).is_none());
        c.insert(7, page(7));
        let p = c.get(7).expect("hit");
        assert_eq!(p[0], 7);
        let s = c.stats().snapshot();
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
    }

    #[test]
    fn eviction_under_pressure() {
        let c = cache(SHARDS); // 1 frame per shard
        for i in 0..(SHARDS as u64 * 4) {
            c.insert(i, page(i as u8));
        }
        let s = c.stats().snapshot();
        assert!(s.evictions > 0, "expected evictions, got {s:?}");
        // capacity respected
        assert!(c.resident_pages() <= c.capacity_pages() as u64);
    }

    #[test]
    fn second_chance_prefers_referenced() {
        // single-shard-sized behaviour is hard to isolate through sharding;
        // exercise the Shard directly.
        let mut sh = Shard {
            map: HashMap::new(),
            frames: vec![],
            hand: 0,
            cap: 2,
            quarantined: HashSet::new(),
        };
        sh.insert(1, page(1));
        sh.insert(2, page(2));
        // touch page 1 so its ref bit survives the sweep
        assert!(sh.get(1).is_some());
        // force ref bits: page 2 untouched after insert sweep rounds
        sh.frames.iter_mut().for_each(|f| {
            if f.page_no == 2 {
                f.ref_bit = false;
            }
        });
        sh.insert(3, page(3));
        assert!(sh.get(1).is_some(), "referenced page must survive");
        assert!(sh.get(2).is_none(), "unreferenced page evicted");
        assert!(sh.get(3).is_some());
    }

    #[test]
    fn readers_survive_eviction() {
        let c = cache(SHARDS);
        c.insert(0, page(42));
        let held = c.get(0).unwrap();
        for i in 1..(SHARDS as u64 * 8) {
            c.insert(i, page(i as u8));
        }
        // page 0 may be evicted, but our Arc is still valid
        assert_eq!(held[100], 42);
    }

    #[test]
    fn concurrent_hammering_single_shard() {
        // every thread hits the SAME page number, so all traffic funnels
        // through one shard's lock and one frame: the get/insert race is
        // maximally contended and must stay coherent
        let c = Arc::new(cache(SHARDS));
        let mut hs = vec![];
        for _ in 0..8 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..20_000 {
                    match c.get(7) {
                        Some(d) => assert_eq!(d[0], 42, "corrupt frame"),
                        None => c.insert(7, page(42)),
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get(7).expect("page resident")[0], 42);
    }

    #[test]
    fn concurrent_eviction_pressure_readback() {
        // 1 frame per shard + 8 writers over 512 distinct pages: constant
        // eviction; whatever get() returns must carry the right bytes
        let c = Arc::new(cache(SHARDS));
        let mut hs = vec![];
        for t in 0..8u64 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                let mut rng = crate::util::XorShift::new(0x5EED + t);
                for _ in 0..10_000 {
                    let p = rng.next_below(512);
                    match c.get(p) {
                        Some(d) => assert_eq!(d[0], p as u8, "page {p} corrupt"),
                        None => c.insert(p, page(p as u8)),
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        let s = c.stats().snapshot();
        assert!(s.evictions > 0, "512 pages through 64 frames must evict: {s:?}");
        assert!(c.resident_pages() <= c.capacity_pages() as u64);
    }

    #[test]
    fn repeated_scan_hits_after_warmup() {
        // 128 pages into a 256-page cache: the multiplicative shard hash
        // spreads them at most 3 deep per 4-deep shard (verified offline),
        // so nothing is evicted and rescans must hit 100%
        let c = cache(256);
        for p in 0..128u64 {
            assert!(c.get(p).is_none(), "cold cache");
            c.insert(p, page(p as u8));
        }
        let before = c.stats().snapshot();
        for _ in 0..4 {
            for p in 0..128u64 {
                assert_eq!(c.get(p).expect("warm page")[0], p as u8);
            }
        }
        let d = c.stats().snapshot().delta(&before);
        assert_eq!(d.cache_misses, 0, "warm rescan must not miss: {d:?}");
        assert_eq!(d.cache_hits, 4 * 128);
        assert!(d.hit_ratio() > 0.999);
    }

    #[test]
    fn duplicate_insert_does_not_overcount_residency() {
        // two batches can miss on the same page and both insert it; only
        // the first occupies a frame, so residency must count once
        let c = cache(128);
        c.insert(5, page(5));
        c.insert(5, page(5));
        c.insert(5, page(5));
        assert_eq!(c.resident_pages(), 1, "duplicate inserts must not count");
        c.insert(9, page(9));
        assert_eq!(c.resident_pages(), 2);
        assert_eq!(c.stats().snapshot().evictions, 0);
    }

    #[test]
    fn residency_is_exact_without_clamping() {
        // hammer one frame per shard with duplicates + distinct pages:
        // the unclamped count must stay within the true frame capacity
        let c = cache(SHARDS);
        for round in 0..4u64 {
            for i in 0..(SHARDS as u64 * 2) {
                c.insert(i, page((i + round) as u8));
            }
        }
        assert!(
            c.resident_pages() <= c.capacity_pages() as u64,
            "exact residency {} exceeds capacity {}",
            c.resident_pages(),
            c.capacity_pages()
        );
        assert!(c.resident_pages() > 0);
    }

    #[test]
    fn page_ref_views_share_one_run_buffer() {
        // a 4-page run buffer serves 4 cache frames with zero copies
        let run: Arc<[u8]> = (0..4 * PAGE_SIZE).map(|i| (i / PAGE_SIZE) as u8).collect();
        let c = cache(128);
        for i in 0..4 {
            c.insert(100 + i as u64, PageRef::new(run.clone(), i * PAGE_SIZE));
        }
        for i in 0..4u64 {
            let p = c.get(100 + i).expect("inserted view");
            assert_eq!(p.len(), PAGE_SIZE);
            assert!(p.iter().all(|&b| b == i as u8), "view {i} bytes wrong");
        }
        assert_eq!(c.resident_pages(), 4);
    }

    #[test]
    fn tracked_get_attributes_to_extra_stats() {
        let c = cache(128);
        let job = IoStats::new();
        assert!(c.get_tracked(3, Some(&job)).is_none());
        c.insert(3, page(3));
        assert!(c.get_tracked(3, Some(&job)).is_some());
        assert!(c.get(3).is_some()); // untracked: global only
        let j = job.snapshot();
        assert_eq!((j.cache_hits, j.cache_misses), (1, 1));
        let g = c.stats().snapshot();
        assert_eq!((g.cache_hits, g.cache_misses), (2, 1), "global aggregates all");
    }

    #[test]
    fn quarantine_drops_refuses_and_uncounts() {
        let c = cache(128);
        c.insert(5, page(5));
        c.insert(6, page(6));
        assert_eq!(c.resident_pages(), 2);
        c.quarantine(5);
        assert!(c.is_quarantined(5));
        assert!(!c.is_quarantined(6));
        assert_eq!(c.resident_pages(), 1, "quarantined page stops counting resident");
        assert!(c.get(5).is_none(), "quarantined page is never served");
        assert!(c.get(6).is_some(), "co-resident pages untouched");
        c.insert(5, page(5));
        assert!(c.get(5).is_none(), "re-insert of a quarantined page is refused");
        assert_eq!(c.resident_pages(), 1);
        // quarantining again is idempotent for the counter
        c.quarantine(5);
        assert_eq!(c.stats().snapshot().quarantined_pages, 1);
        assert_eq!(c.quarantined_pages(), 1);
        // quarantining a never-cached page works too
        c.quarantine(999);
        assert!(c.is_quarantined(999));
        assert_eq!(c.stats().snapshot().quarantined_pages, 2);
        assert_eq!(c.resident_pages(), 1);
    }

    #[test]
    fn quarantine_mid_shard_keeps_clock_coherent() {
        // exercise the swap_remove fixup: quarantine a page whose frame
        // sits in the middle of a multi-frame shard, then keep using it
        let mut sh = Shard {
            map: HashMap::new(),
            frames: vec![],
            hand: 0,
            cap: 4,
        quarantined: HashSet::new(),
        };
        // one shard, four frames
        for p in [10u64, 11, 12, 13] {
            sh.insert(p, page(p as u8));
        }
        sh.hand = 3;
        let (newly, dropped) = sh.quarantine(11);
        assert!(newly && dropped);
        assert!(sh.get(11).is_none());
        // the swapped-in frame (13) is still findable with correct bytes
        for p in [10u64, 12, 13] {
            assert_eq!(sh.get(p).expect("survivor")[0], p as u8, "page {p}");
        }
        assert!(sh.hand < sh.frames.len(), "hand clamped into the shrunk list");
        // refill to capacity and sweep: clock still terminates
        assert!(matches!(sh.insert(14, page(14)), Inserted::Fresh));
        sh.frames.iter_mut().for_each(|f| f.ref_bit = false);
        assert!(matches!(sh.insert(15, page(15)), Inserted::Evicted));
    }

    #[test]
    fn concurrent_mixed_workload() {
        let c = Arc::new(cache(256));
        let mut hs = vec![];
        for t in 0..8u64 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                let mut rng = crate::util::XorShift::new(t);
                for _ in 0..5_000 {
                    let p = rng.next_below(512);
                    if c.get(p).is_none() {
                        c.insert(p, page(p as u8));
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        // no panic + capacity bound
        assert!(c.resident_pages() <= c.capacity_pages() as u64);
    }
}
