//! I/O statistics — the measured quantities behind the paper's figures.
//!
//! Figure 2 plots *runtime, read I/O (bytes), I/O requests and thread
//! context switches*; Figures 5/6 plot *data read from disk* and *cache
//! hits per accessed page*. All of those counters live here and are
//! sampled per algorithm run via [`IoStats::snapshot`] deltas.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::hist::{HistSummary, Histogram};

/// Global, concurrently-updated I/O counters.
#[derive(Debug, Default)]
pub struct IoStats {
    /// Logical read requests issued by callers (one per edge-list fetch).
    pub read_requests: AtomicU64,
    /// Pages served from the page cache.
    pub cache_hits: AtomicU64,
    /// Pages that missed and went to disk.
    pub cache_misses: AtomicU64,
    /// Physical `pread` calls after merging.
    pub physical_reads: AtomicU64,
    /// Bytes physically read from the underlying file.
    pub bytes_read: AtomicU64,
    /// Requests eliminated by merging (adjacent pages coalesced).
    pub merged_requests: AtomicU64,
    /// Logical bytes requested by callers (what the algorithm demanded,
    /// independent of cache hits) — the Fig. 2 "read I/O" axis.
    pub logical_bytes: AtomicU64,
    /// Times a caller thread blocked waiting (I/O completion, messages,
    /// barriers) — our proxy for the paper's context-switch counts.
    pub thread_waits: AtomicU64,
    /// Pages evicted from the cache.
    pub evictions: AtomicU64,
    /// Transient read errors retried inside the I/O pool under bounded
    /// exponential backoff (each retry is one increment).
    pub retries: AtomicU64,
    /// Transient-class read errors observed (whether or not a retry
    /// later cleared them) — `retries` counts the re-issues, this counts
    /// the failures.
    pub transient_errors: AtomicU64,
    /// Requests that failed permanently: permanent-class errors plus
    /// transient errors that exhausted the retry budget. Each one
    /// surfaces as a typed error reply, never a panic.
    pub permanent_errors: AtomicU64,
    /// Backoff sleeps taken between transient-error retries.
    pub backoff_waits: AtomicU64,
    /// Total microseconds spent in backoff sleeps.
    pub backoff_us: AtomicU64,
    /// Pages whose crc32c footer entry disagreed with the bytes read —
    /// verify-on-read mismatches plus scrub-detected flips. Counted per
    /// mismatching verification, so a page that fails both the first
    /// read and its single bounded re-read counts twice.
    pub checksum_failures: AtomicU64,
    /// Pages swept and verified by the scrubber (CLI or background).
    pub pages_scrubbed: AtomicU64,
    /// Pages quarantined in the page cache after sticky corruption
    /// (a verify failure that survived the one bounded re-read).
    /// Monotonic: quarantine is never lifted within a process lifetime.
    pub quarantined_pages: AtomicU64,
    /// Per-batch edge-fetch latency (`SemFile::read_ranges_into`), in
    /// microseconds — the caller-visible end-to-end cost of one fetch.
    pub fetch_latency_us: Histogram,
    /// Time a caller thread spent blocked on I/O completions, in
    /// microseconds (recorded alongside `thread_waits`).
    pub wait_latency_us: Histogram,
    /// Per-`pread` service latency inside the I/O pool, in microseconds
    /// (includes the injected `io_delay_us`, so figure runs show it).
    pub pread_latency_us: Histogram,
    /// Coalesced run sizes in pages — how well adjacent requests merge.
    pub run_pages: Histogram,
}

impl IoStats {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn add_read_request(&self, n: u64) {
        self.read_requests.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_cache_hit(&self, n: u64) {
        self.cache_hits.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_cache_miss(&self, n: u64) {
        self.cache_misses.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_physical_read(&self, n: u64) {
        self.physical_reads.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_bytes_read(&self, n: u64) {
        self.bytes_read.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_merged(&self, n: u64) {
        self.merged_requests.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_logical_bytes(&self, n: u64) {
        self.logical_bytes.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_thread_wait(&self, n: u64) {
        self.thread_waits.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_eviction(&self, n: u64) {
        self.evictions.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_retry(&self, n: u64) {
        self.retries.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_transient_error(&self, n: u64) {
        self.transient_errors.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_permanent_error(&self, n: u64) {
        self.permanent_errors.fetch_add(n, Ordering::Relaxed);
    }
    /// One backoff sleep of `us` microseconds.
    #[inline]
    pub fn add_backoff(&self, us: u64) {
        self.backoff_waits.fetch_add(1, Ordering::Relaxed);
        self.backoff_us.fetch_add(us, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_checksum_failure(&self, n: u64) {
        self.checksum_failures.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_pages_scrubbed(&self, n: u64) {
        self.pages_scrubbed.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn add_quarantined(&self, n: u64) {
        self.quarantined_pages.fetch_add(n, Ordering::Relaxed);
    }

    /// Point-in-time copy of all counters (histograms summarized).
    pub fn snapshot(&self) -> IoStatsSnapshot {
        IoStatsSnapshot {
            read_requests: self.read_requests.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            physical_reads: self.physical_reads.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            merged_requests: self.merged_requests.load(Ordering::Relaxed),
            logical_bytes: self.logical_bytes.load(Ordering::Relaxed),
            thread_waits: self.thread_waits.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            transient_errors: self.transient_errors.load(Ordering::Relaxed),
            permanent_errors: self.permanent_errors.load(Ordering::Relaxed),
            backoff_waits: self.backoff_waits.load(Ordering::Relaxed),
            backoff_us: self.backoff_us.load(Ordering::Relaxed),
            checksum_failures: self.checksum_failures.load(Ordering::Relaxed),
            pages_scrubbed: self.pages_scrubbed.load(Ordering::Relaxed),
            quarantined_pages: self.quarantined_pages.load(Ordering::Relaxed),
            latency: IoLatency {
                fetch: self.fetch_latency_us.summary(),
                wait: self.wait_latency_us.summary(),
                pread: self.pread_latency_us.summary(),
                run_pages: self.run_pages.summary(),
            },
        }
    }

    /// Reset all counters to zero.
    pub fn reset(&self) {
        self.read_requests.store(0, Ordering::Relaxed);
        self.cache_hits.store(0, Ordering::Relaxed);
        self.cache_misses.store(0, Ordering::Relaxed);
        self.physical_reads.store(0, Ordering::Relaxed);
        self.bytes_read.store(0, Ordering::Relaxed);
        self.merged_requests.store(0, Ordering::Relaxed);
        self.logical_bytes.store(0, Ordering::Relaxed);
        self.thread_waits.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
        self.retries.store(0, Ordering::Relaxed);
        self.transient_errors.store(0, Ordering::Relaxed);
        self.permanent_errors.store(0, Ordering::Relaxed);
        self.backoff_waits.store(0, Ordering::Relaxed);
        self.backoff_us.store(0, Ordering::Relaxed);
        self.checksum_failures.store(0, Ordering::Relaxed);
        self.pages_scrubbed.store(0, Ordering::Relaxed);
        self.quarantined_pages.store(0, Ordering::Relaxed);
        self.fetch_latency_us.reset();
        self.wait_latency_us.reset();
        self.pread_latency_us.reset();
        self.run_pages.reset();
    }
}

/// Summaries of the four hot-path histograms at snapshot time. All
/// fields are integer summaries so the snapshot stays `Copy + Eq`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoLatency {
    /// End-to-end edge-fetch batch latency (us).
    pub fetch: HistSummary,
    /// Time blocked behind I/O completions (us).
    pub wait: HistSummary,
    /// Per-`pread` service latency in the pool (us).
    pub pread: HistSummary,
    /// Coalesced run sizes (pages).
    pub run_pages: HistSummary,
}

/// Immutable copy of [`IoStats`] at a point in time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct IoStatsSnapshot {
    pub read_requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub physical_reads: u64,
    pub bytes_read: u64,
    pub merged_requests: u64,
    pub logical_bytes: u64,
    pub thread_waits: u64,
    pub evictions: u64,
    pub retries: u64,
    pub transient_errors: u64,
    pub permanent_errors: u64,
    pub backoff_waits: u64,
    pub backoff_us: u64,
    pub checksum_failures: u64,
    pub pages_scrubbed: u64,
    pub quarantined_pages: u64,
    /// Histogram summaries (cumulative at snapshot time; see `delta`).
    pub latency: IoLatency,
}

impl IoStatsSnapshot {
    /// Component-wise saturating `self - earlier`. Counters are
    /// monotonic, so underflow only happens when a reset raced the
    /// earlier snapshot — in that case the delta reports zeros instead
    /// of panicking in debug builds. Latency distributions do not
    /// difference meaningfully; the delta carries `self`'s (later)
    /// cumulative summaries unchanged.
    pub fn delta(&self, earlier: &IoStatsSnapshot) -> IoStatsSnapshot {
        IoStatsSnapshot {
            read_requests: self.read_requests.saturating_sub(earlier.read_requests),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            cache_misses: self.cache_misses.saturating_sub(earlier.cache_misses),
            physical_reads: self.physical_reads.saturating_sub(earlier.physical_reads),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            merged_requests: self.merged_requests.saturating_sub(earlier.merged_requests),
            logical_bytes: self.logical_bytes.saturating_sub(earlier.logical_bytes),
            thread_waits: self.thread_waits.saturating_sub(earlier.thread_waits),
            evictions: self.evictions.saturating_sub(earlier.evictions),
            retries: self.retries.saturating_sub(earlier.retries),
            transient_errors: self.transient_errors.saturating_sub(earlier.transient_errors),
            permanent_errors: self.permanent_errors.saturating_sub(earlier.permanent_errors),
            backoff_waits: self.backoff_waits.saturating_sub(earlier.backoff_waits),
            backoff_us: self.backoff_us.saturating_sub(earlier.backoff_us),
            checksum_failures: self
                .checksum_failures
                .saturating_sub(earlier.checksum_failures),
            pages_scrubbed: self.pages_scrubbed.saturating_sub(earlier.pages_scrubbed),
            quarantined_pages: self
                .quarantined_pages
                .saturating_sub(earlier.quarantined_pages),
            latency: self.latency,
        }
    }

    /// Cache hit ratio over accessed pages (0 when nothing accessed).
    pub fn hit_ratio(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Terse single-line report (fetch latency appended when present).
    pub fn report(&self) -> String {
        let mut s = format!(
            "reqs={} hits={} misses={} hit%={:.1} preads={} bytes={} merged={} waits={}",
            self.read_requests,
            self.cache_hits,
            self.cache_misses,
            100.0 * self.hit_ratio(),
            self.physical_reads,
            crate::util::fmt_bytes(self.bytes_read),
            self.merged_requests,
            self.thread_waits,
        );
        if self.retries > 0 {
            s.push_str(&format!(" retries={}", self.retries));
        }
        if self.transient_errors > 0 || self.permanent_errors > 0 {
            s.push_str(&format!(
                " io_err[transient={} permanent={} backoff={} backoff_us={}]",
                self.transient_errors, self.permanent_errors, self.backoff_waits, self.backoff_us,
            ));
        }
        if self.checksum_failures > 0 || self.quarantined_pages > 0 {
            s.push_str(&format!(
                " integrity[crc_fail={} quarantined={}]",
                self.checksum_failures, self.quarantined_pages,
            ));
        }
        if self.pages_scrubbed > 0 {
            s.push_str(&format!(" scrubbed={}", self.pages_scrubbed));
        }
        if self.latency.fetch.count > 0 {
            s.push_str(&format!(
                " fetch_us[p50={} p99={} mean={}]",
                self.latency.fetch.p50, self.latency.fetch.p99, self.latency.fetch.mean,
            ));
        }
        if self.latency.pread.count > 0 {
            s.push_str(&format!(
                " pread_us[p50={} p99={}]",
                self.latency.pread.p50, self.latency.pread.p99,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_delta() {
        let s = IoStats::new();
        s.add_read_request(5);
        s.add_bytes_read(100);
        let a = s.snapshot();
        s.add_read_request(3);
        s.add_bytes_read(50);
        s.add_cache_hit(7);
        let b = s.snapshot();
        let d = b.delta(&a);
        assert_eq!(d.read_requests, 3);
        assert_eq!(d.bytes_read, 50);
        assert_eq!(d.cache_hits, 7);
    }

    #[test]
    fn hit_ratio_edges() {
        let z = IoStatsSnapshot::default();
        assert_eq!(z.hit_ratio(), 0.0);
        let s = IoStats::new();
        s.add_cache_hit(3);
        s.add_cache_miss(1);
        assert!((s.snapshot().hit_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reset_zeroes() {
        let s = IoStats::new();
        s.add_eviction(2);
        s.add_thread_wait(9);
        s.fetch_latency_us.record(120);
        s.reset();
        assert_eq!(s.snapshot(), IoStatsSnapshot::default());
    }

    #[test]
    fn delta_saturates_after_reset_race() {
        // a reset between the two snapshots makes `later < earlier`;
        // the delta must report zeros, not underflow
        let s = IoStats::new();
        s.add_bytes_read(1000);
        s.add_read_request(10);
        let earlier = s.snapshot();
        s.reset();
        s.add_bytes_read(5);
        let later = s.snapshot();
        let d = later.delta(&earlier);
        assert_eq!(d.bytes_read, 0);
        assert_eq!(d.read_requests, 0);
    }

    #[test]
    fn snapshot_embeds_latency_summaries() {
        let s = IoStats::new();
        s.fetch_latency_us.record(100);
        s.fetch_latency_us.record(200);
        s.pread_latency_us.record(50);
        s.run_pages.record(8);
        let snap = s.snapshot();
        assert_eq!(snap.latency.fetch.count, 2);
        assert_eq!(snap.latency.fetch.mean, 150);
        assert_eq!(snap.latency.pread.count, 1);
        assert_eq!(snap.latency.run_pages.count, 1);
        let r = snap.report();
        assert!(r.contains("fetch_us["), "report should show latency: {r}");
        // delta carries the later snapshot's cumulative summaries
        let d = snap.delta(&IoStatsSnapshot::default());
        assert_eq!(d.latency, snap.latency);
    }
}
