//! [`SemFile`]: a read-only file whose reads flow through the page cache
//! and the async I/O pool — the SEM data plane.
//!
//! The engine fetches *batches* of byte ranges (one per active vertex in a
//! processing batch) via [`SemFile::read_ranges`]; misses across the whole
//! batch are deduplicated, coalesced into runs, and serviced concurrently
//! by the pool — this is where FlashGraph's overlap of computation with
//! asynchronous I/O comes from.

use std::fs::File;
use std::path::Path;
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{bail, Context};

use super::io::{coalesce, IoError, IoPool, RunReply, RunRequest};
use super::page_cache::{PageCache, PageRef, PAGE_SIZE};
use super::stats::IoStats;

/// A byte range in the file.
pub type ByteRange = (u64, usize); // (offset, len)

/// One fetched byte range, as produced by [`SemFile::read_ranges_into`].
///
/// The common case — a range contained in a single page, which is what
/// per-vertex adjacency records overwhelmingly are — is a **zero-copy
/// slice** into the cached page. Only ranges spanning a page boundary
/// are assembled, into a buffer drawn from the caller's
/// [`RangeScratch`] so steady-state batches allocate nothing.
pub enum RangeBuf {
    /// The range lies within one cached page: a borrowed view.
    Page {
        /// The cached page (shared run buffer + offset).
        page: PageRef,
        /// Start of the range within the page.
        start: usize,
        /// Range length in bytes.
        len: usize,
    },
    /// Page-spanning range assembled into a scratch buffer.
    Owned(Vec<u8>),
}

impl RangeBuf {
    /// The range bytes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        match self {
            RangeBuf::Page { page, start, len } => &page.as_slice()[*start..*start + *len],
            RangeBuf::Owned(v) => v,
        }
    }
}

impl std::ops::Deref for RangeBuf {
    type Target = [u8];

    #[inline]
    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// Reusable per-caller scratch for [`SemFile::read_ranges_into`]: the
/// batch's page-set bookkeeping plus a free list of assembly buffers
/// for page-spanning ranges. Keep one per worker thread and pass it to
/// every batch — after warm-up no call allocates (tracked by
/// [`Self::allocs`], which the engine surfaces through
/// [`crate::graph::source::FetchArena`]).
#[derive(Default)]
pub struct RangeScratch {
    /// Distinct pages the current batch needs (sorted, deduped).
    needed: Vec<u64>,
    /// Pages found in (or inserted into) the cache this batch.
    have: Vec<(u64, PageRef)>,
    /// Pages that missed, awaiting coalesced dispatch.
    misses: Vec<u64>,
    /// Recycled assembly buffers for page-spanning ranges.
    free: Vec<Vec<u8>>,
    /// Cumulative heap allocations this scratch performed.
    allocs: u64,
}

impl RangeScratch {
    /// Fresh scratch with no retained buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cumulative count of heap allocations performed through this
    /// scratch (buffer creation and growth). Flat across batches once
    /// warm — the steady-state-zero-allocation acceptance metric.
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Drain `bufs`, returning `Owned` assembly buffers to the free
    /// list (and dropping page views). Called automatically at the top
    /// of [`SemFile::read_ranges_into`], so callers that reuse one
    /// output vector never need to call it themselves.
    pub fn recycle(&mut self, bufs: &mut Vec<RangeBuf>) {
        for b in bufs.drain(..) {
            if let RangeBuf::Owned(v) = b {
                self.free.push(v);
            }
        }
    }
}

/// An in-flight batch read started by [`SemFile::submit_ranges`].
///
/// Holds the page views already secured (cache hits at submit time plus
/// absorbed completions) and the count of coalesced runs still inside
/// the pool. Dropping a `PendingRead` abandons the batch: outstanding
/// runs still complete and land in the cache (the pool ignores a closed
/// reply channel), they just aren't assembled.
pub struct PendingRead {
    rx: std::sync::mpsc::Receiver<RunReply>,
    outstanding: usize,
    /// Pages secured so far, `(file-local page number, view)`.
    have: Vec<(u64, PageRef)>,
    /// Submit time — end-to-end fetch latency is measured from here.
    t0: std::time::Instant,
    /// First failed run of the batch, if any. The batch keeps draining
    /// its remaining completions (so the outstanding count stays exact)
    /// but [`SemFile::finish_ranges`] returns this error instead of
    /// assembling — errored runs contribute no pages and are never
    /// cache-inserted.
    failure: Option<IoError>,
}

impl PendingRead {
    /// Coalesced runs still being serviced by the pool.
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }
}

/// Per-page crc32c table installed by the open path when the image
/// carries a checksum footer. Pages are verified against it **once, on
/// the way into the cache** — cache hits serve pre-verified bytes, so
/// the steady-state cost of verification is zero.
pub struct PageChecksums {
    /// Length of the covered data region (the footer itself excluded).
    data_len: u64,
    /// crc32c of each page's covered bytes (the last page covers only
    /// `data_len % PAGE_SIZE` bytes when the data isn't page-aligned).
    crcs: Vec<u32>,
}

impl PageChecksums {
    /// Build a table for `data_len` bytes with the given per-page crcs.
    pub fn new(data_len: u64, crcs: Vec<u32>) -> Self {
        PageChecksums { data_len, crcs }
    }

    /// Verify page `p` (file-local). Only the covered prefix is checked:
    /// EOF zero padding in a run buffer is outside the checksum domain,
    /// and a page wholly past the data region is vacuously fine.
    fn page_ok(&self, p: u64, bytes: &[u8]) -> bool {
        let start = p * PAGE_SIZE as u64;
        if start >= self.data_len {
            return true;
        }
        let covered = ((self.data_len - start) as usize).min(PAGE_SIZE);
        if bytes.len() < covered {
            return false;
        }
        match self.crcs.get(p as usize) {
            Some(&want) => crate::util::crc32c(&bytes[..covered]) == want,
            None => false,
        }
    }
}

/// Read-only SEM file handle.
pub struct SemFile {
    file: Arc<File>,
    len: u64,
    cache: Arc<PageCache>,
    pool: Arc<IoPool>,
    stats: Arc<IoStats>,
    /// Offset added to this file's page numbers when keying the cache.
    /// Several `SemFile`s sharing one [`PageCache`] (service mode) get
    /// disjoint key namespaces so their pages never alias.
    key_base: u64,
    /// The file's path, carried on every [`RunRequest`] so pool errors
    /// name their file and fault plans can target it.
    tag: Arc<str>,
    /// Verify-on-read table, installed from the image's checksum footer
    /// by the open path. `None` for plain (unfooted) images.
    checks: Option<Arc<PageChecksums>>,
}

impl SemFile {
    /// Open `path` through the given cache and pool.
    pub fn open(
        path: &Path,
        cache: Arc<PageCache>,
        pool: Arc<IoPool>,
    ) -> crate::Result<Self> {
        Self::open_keyed(path, cache, pool, 0)
    }

    /// Open with an explicit cache-key namespace. `key_base` must leave
    /// the file's page range `[key_base, key_base + len/PAGE_SIZE]`
    /// disjoint from every other file sharing `cache` (the service
    /// registry hands out bases spaced far wider than any file).
    pub fn open_keyed(
        path: &Path,
        cache: Arc<PageCache>,
        pool: Arc<IoPool>,
        key_base: u64,
    ) -> crate::Result<Self> {
        let file = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let len = file.metadata()?.len();
        let stats = cache.stats().clone();
        let tag: Arc<str> = Arc::from(path.to_string_lossy().as_ref());
        Ok(SemFile { file: Arc::new(file), len, cache, pool, stats, key_base, tag, checks: None })
    }

    /// Install the image's per-page checksum table. The visible file
    /// length shrinks to `data_len` — the footer region becomes
    /// unreadable through this handle — so reads, EOF clamping and
    /// `bytes_read` accounting stay byte-identical to a plain image.
    /// From here on every page entering the cache is verified first; a
    /// mismatch gets exactly one corrective re-read (through the pool's
    /// backoff ladder), and a persistent mismatch quarantines the page
    /// and fails the owning batch with [`super::IoErrorClass::Corrupt`].
    pub fn install_checksums(&mut self, checks: PageChecksums) {
        debug_assert!(checks.data_len <= self.len, "checksum table covers more than the file");
        self.len = checks.data_len;
        self.checks = Some(Arc::new(checks));
    }

    /// True when verify-on-read is active (a checksum table is installed).
    pub fn verified(&self) -> bool {
        self.checks.is_some()
    }

    /// File length in bytes.
    pub fn len(&self) -> u64 {
        self.len
    }

    /// True if the file is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read a single byte range.
    pub fn read(&self, offset: u64, len: usize) -> crate::Result<Vec<u8>> {
        Ok(self.read_ranges(&[(offset, len)])?.pop().unwrap())
    }

    /// Read many byte ranges as one batch: cache lookups first, then all
    /// misses deduped + coalesced + serviced in parallel, then assembly.
    ///
    /// Convenience wrapper over [`Self::read_ranges_into`] returning
    /// owned buffers; the engine's hot path uses the `_into` form with a
    /// per-worker [`RangeScratch`] instead.
    pub fn read_ranges(&self, ranges: &[ByteRange]) -> crate::Result<Vec<Vec<u8>>> {
        self.read_ranges_tracked(ranges, None)
    }

    /// [`Self::read_ranges`] with per-job attribution (see
    /// [`Self::read_ranges_into`] for the counter contract).
    pub fn read_ranges_tracked(
        &self,
        ranges: &[ByteRange],
        job: Option<&IoStats>,
    ) -> crate::Result<Vec<Vec<u8>>> {
        let mut scratch = RangeScratch::new();
        let mut bufs = Vec::with_capacity(ranges.len());
        self.read_ranges_into(ranges, job, &mut scratch, &mut bufs)?;
        // move assembled buffers out; only page views need a copy
        Ok(bufs
            .into_iter()
            .map(|b| match b {
                RangeBuf::Owned(v) => v,
                ref p => p.as_slice().to_vec(),
            })
            .collect())
    }

    /// The zero-copy batch read. Results land in `out` (cleared first;
    /// its previous `Owned` buffers are recycled into `scratch`): one
    /// [`RangeBuf`] per requested range, single-page ranges as borrowed
    /// page views, page-spanning ranges assembled into scratch buffers.
    /// With a warm cache and a warm scratch the call performs **zero
    /// heap allocations**.
    ///
    /// Per-job attribution: every counter this batch moves (requests,
    /// hits/misses, merges, waits, physical reads, bytes) is also
    /// recorded into `job` when given. The substrate's own stats keep
    /// aggregating everything, so under concurrent jobs each event is
    /// attributed to exactly one job and the per-job snapshots sum to
    /// the global ones (eviction counts stay global: they belong to the
    /// shared cache, not to whichever job triggered them).
    pub fn read_ranges_into(
        &self,
        ranges: &[ByteRange],
        job: Option<&IoStats>,
        scratch: &mut RangeScratch,
        out: &mut Vec<RangeBuf>,
    ) -> crate::Result<()> {
        scratch.recycle(out);
        let fetch_t0 = std::time::Instant::now();
        self.stats.add_read_request(ranges.len() as u64);
        if let Some(j) = job {
            j.add_read_request(ranges.len() as u64);
        }
        // split-borrow the scratch so the free list stays usable while
        // the page-set vectors are live
        let RangeScratch { needed, have, misses, free, allocs } = scratch;
        needed.clear();
        have.clear();
        misses.clear();

        // 1. collect the distinct pages each range needs
        for &(off, len) in ranges {
            if off + len as u64 > self.len {
                bail!(
                    "read past EOF: offset {off} + len {len} > file len {}",
                    self.len
                );
            }
            if len == 0 {
                continue;
            }
            let first = off / PAGE_SIZE as u64;
            let last = (off + len as u64 - 1) / PAGE_SIZE as u64;
            needed.extend(first..=last);
        }
        needed.sort_unstable();
        needed.dedup();

        // 2. cache pass — split hits from misses (`have`/`misses` carry
        //    file-local page numbers; only cache calls add the key base).
        //    A quarantined page fails the batch before any I/O: its
        //    storage already proved it returns wrong bytes.
        for &p in needed.iter() {
            if self.cache.is_quarantined(self.key_base + p) {
                return Err(anyhow::Error::new(self.quarantined_error(p))
                    .context(format!("batch read of {} failed", self.tag)));
            }
            match self.cache.get_tracked(self.key_base + p, job) {
                Some(d) => have.push((p, d)),
                None => misses.push(p),
            }
        }

        // 3. dispatch misses as coalesced runs, serviced concurrently
        if !misses.is_empty() {
            let runs = coalesce(misses, self.pool.config().max_run_pages);
            self.stats.add_merged((misses.len() - runs.len()) as u64);
            if let Some(j) = job {
                j.add_merged((misses.len() - runs.len()) as u64);
            }
            let (tx, rx) = channel();
            let nruns = runs.len();
            for (start, n) in runs {
                self.pool.submit(RunRequest {
                    file: self.file.clone(),
                    file_len: self.len,
                    start_page: start,
                    npages: n,
                    reply: tx.clone(),
                    tag: self.tag.clone(),
                });
            }
            drop(tx);
            // block for completions — counted as a thread wait; the
            // wait-latency histogram times the whole completion drain
            self.stats.add_thread_wait(1);
            if let Some(j) = job {
                j.add_thread_wait(1);
            }
            let wait_t0 = std::time::Instant::now();
            let mut failed: Option<IoError> = None;
            for _ in 0..nruns {
                let reply = rx.recv().context("io pool reply channel closed")?;
                if let Some(err) = reply.error {
                    // a failed run delivered no pages: never cache-insert
                    // it, keep draining so every run is accounted, and
                    // surface the first failure after the drain
                    if failed.is_none() {
                        failed = Some(err);
                    }
                    continue;
                }
                if let Some(j) = job {
                    // the pool already counted this run into the global
                    // stats; mirror its actual cost into the job's
                    if reply.bytes_read > 0 {
                        j.add_physical_read(1);
                        j.add_bytes_read(reply.bytes_read);
                    }
                }
                for i in 0..reply.npages {
                    let p = reply.start_page + i as u64;
                    match self.verified_page(p, reply.page(i), job) {
                        Ok(view) => {
                            self.cache.insert(self.key_base + p, view.clone());
                            have.push((p, view));
                        }
                        Err(err) => {
                            if failed.is_none() {
                                failed = Some(err);
                            }
                        }
                    }
                }
            }
            let wait_us = wait_t0.elapsed().as_micros() as u64;
            self.stats.wait_latency_us.record(wait_us);
            if let Some(j) = job {
                j.wait_latency_us.record(wait_us);
            }
            if let Some(err) = failed {
                return Err(anyhow::Error::new(err)
                    .context(format!("batch read of {} failed", self.tag)));
            }
        }
        have.sort_unstable_by_key(|&(p, _)| p);

        // 4. assemble the requested ranges from the page set
        assemble(ranges, have, free, allocs, out);
        // drop the batch's page refs so evicted pages' run buffers can
        // free between batches
        have.clear();
        let fetch_us = fetch_t0.elapsed().as_micros() as u64;
        self.stats.fetch_latency_us.record(fetch_us);
        if let Some(j) = job {
            j.fetch_latency_us.record(fetch_us);
        }
        Ok(())
    }

    /// Start a batch read without blocking: cache probes happen now
    /// (hits and misses are counted at submit time), misses are
    /// deduplicated, coalesced and handed to the pool, and the returned
    /// [`PendingRead`] tracks the outstanding runs. Drive it with
    /// [`Self::poll_ranges`] while doing other work, then call
    /// [`Self::finish_ranges`] with the *same* `ranges` to assemble the
    /// results.
    ///
    /// This is the engine's overlap primitive: several `PendingRead`s
    /// from one worker can be in flight at once, and compute on a
    /// completed batch proceeds while later batches' pages are still in
    /// the pool. Stats attribution mirrors [`Self::read_ranges_into`]:
    /// requests/hits/misses/merges at submit, physical reads and bytes
    /// as completions are absorbed, a thread wait only if
    /// `finish_ranges` actually blocks.
    pub fn submit_ranges(
        &self,
        ranges: &[ByteRange],
        job: Option<&IoStats>,
    ) -> crate::Result<PendingRead> {
        let t0 = std::time::Instant::now();
        self.stats.add_read_request(ranges.len() as u64);
        if let Some(j) = job {
            j.add_read_request(ranges.len() as u64);
        }
        let mut needed: Vec<u64> = Vec::new();
        for &(off, len) in ranges {
            if off + len as u64 > self.len {
                bail!(
                    "read past EOF: offset {off} + len {len} > file len {}",
                    self.len
                );
            }
            if len == 0 {
                continue;
            }
            let first = off / PAGE_SIZE as u64;
            let last = (off + len as u64 - 1) / PAGE_SIZE as u64;
            needed.extend(first..=last);
        }
        needed.sort_unstable();
        needed.dedup();
        let mut have = Vec::with_capacity(needed.len());
        let mut misses = Vec::new();
        for &p in &needed {
            if self.cache.is_quarantined(self.key_base + p) {
                return Err(anyhow::Error::new(self.quarantined_error(p))
                    .context(format!("batch read of {} failed", self.tag)));
            }
            match self.cache.get_tracked(self.key_base + p, job) {
                Some(d) => have.push((p, d)),
                None => misses.push(p),
            }
        }
        let (tx, rx) = channel();
        let mut outstanding = 0;
        if !misses.is_empty() {
            let runs = coalesce(&misses, self.pool.config().max_run_pages);
            self.stats.add_merged((misses.len() - runs.len()) as u64);
            if let Some(j) = job {
                j.add_merged((misses.len() - runs.len()) as u64);
            }
            outstanding = runs.len();
            for (start, n) in runs {
                self.pool.submit(RunRequest {
                    file: self.file.clone(),
                    file_len: self.len,
                    start_page: start,
                    npages: n,
                    reply: tx.clone(),
                    tag: self.tag.clone(),
                });
            }
        }
        drop(tx);
        Ok(PendingRead { rx, outstanding, have, t0, failure: None })
    }

    /// Absorb any completions that have already landed, without
    /// blocking. Returns `true` once every run of the batch is in (at
    /// which point [`Self::finish_ranges`] will not block).
    pub fn poll_ranges(&self, pending: &mut PendingRead, job: Option<&IoStats>) -> bool {
        while pending.outstanding > 0 {
            match pending.rx.try_recv() {
                Ok(reply) => self.absorb_reply(reply, pending, job),
                Err(_) => break,
            }
        }
        pending.outstanding == 0
    }

    /// Complete a batch started by [`Self::submit_ranges`]: block for
    /// any still-outstanding runs (counted as one thread wait, timed
    /// into the wait histogram — zero-cost if polling already drained
    /// them), then assemble `ranges` into `out` exactly as
    /// [`Self::read_ranges_into`] does. `ranges` must be the same slice
    /// contents the batch was submitted with.
    pub fn finish_ranges(
        &self,
        ranges: &[ByteRange],
        mut pending: PendingRead,
        job: Option<&IoStats>,
        scratch: &mut RangeScratch,
        out: &mut Vec<RangeBuf>,
    ) -> crate::Result<()> {
        scratch.recycle(out);
        if pending.outstanding > 0 {
            self.stats.add_thread_wait(1);
            if let Some(j) = job {
                j.add_thread_wait(1);
            }
            let wait_t0 = std::time::Instant::now();
            while pending.outstanding > 0 {
                let reply = pending.rx.recv().context("io pool reply channel closed")?;
                self.absorb_reply(reply, &mut pending, job);
            }
            let wait_us = wait_t0.elapsed().as_micros() as u64;
            self.stats.wait_latency_us.record(wait_us);
            if let Some(j) = job {
                j.wait_latency_us.record(wait_us);
            }
        }
        if let Some(err) = pending.failure.take() {
            return Err(anyhow::Error::new(err)
                .context(format!("batch read of {} failed", self.tag)));
        }
        pending.have.sort_unstable_by_key(|&(p, _)| p);
        let RangeScratch { free, allocs, .. } = scratch;
        assemble(ranges, &pending.have, free, allocs, out);
        let fetch_us = pending.t0.elapsed().as_micros() as u64;
        self.stats.fetch_latency_us.record(fetch_us);
        if let Some(j) = job {
            j.fetch_latency_us.record(fetch_us);
        }
        Ok(())
    }

    /// Cache-insert one completed run and credit its cost. The pool
    /// already counted the run into the global stats; only the per-job
    /// mirror happens here. A failed run contributes no pages and must
    /// never reach the cache (its buffer is empty); the first failure is
    /// parked on the batch for [`Self::finish_ranges`] to surface.
    fn absorb_reply(&self, reply: RunReply, pending: &mut PendingRead, job: Option<&IoStats>) {
        pending.outstanding -= 1;
        if let Some(err) = reply.error {
            if pending.failure.is_none() {
                pending.failure = Some(err);
            }
            return;
        }
        if let Some(j) = job {
            if reply.bytes_read > 0 {
                j.add_physical_read(1);
                j.add_bytes_read(reply.bytes_read);
            }
        }
        for i in 0..reply.npages {
            let p = reply.start_page + i as u64;
            match self.verified_page(p, reply.page(i), job) {
                Ok(view) => {
                    self.cache.insert(self.key_base + p, view.clone());
                    pending.have.push((p, view));
                }
                Err(err) => {
                    if pending.failure.is_none() {
                        pending.failure = Some(err);
                    }
                }
            }
        }
    }

    /// The error for a read that touched an already-quarantined page.
    fn quarantined_error(&self, p: u64) -> IoError {
        IoError::corrupt(
            p,
            format!("page {p} of {} is quarantined after a checksum failure", self.tag),
        )
    }

    /// Gate a pool-delivered page through the installed checksum table.
    ///
    /// Clean images (`checks == None`) pass straight through at zero
    /// cost. On a mismatch the page gets exactly **one** corrective
    /// re-read — a fresh single-page run through the pool, which applies
    /// its own bounded backoff to transient errors — because the first
    /// read may have been corrupted in flight rather than at rest. If
    /// the re-read verifies, the good copy is used as if nothing
    /// happened. If not, the page is quarantined in the shared cache
    /// (never served, never re-cached, never counted resident) and the
    /// batch fails with [`super::IoErrorClass::Corrupt`] — the blast
    /// radius is the owning job only.
    fn verified_page(
        &self,
        p: u64,
        view: PageRef,
        job: Option<&IoStats>,
    ) -> Result<PageRef, IoError> {
        let Some(checks) = &self.checks else { return Ok(view) };
        if checks.page_ok(p, &view) {
            return Ok(view);
        }
        self.stats.add_checksum_failure(1);
        if let Some(j) = job {
            j.add_checksum_failure(1);
        }
        // one corrective re-read; its transient errors still get the
        // pool's backoff ladder
        let (tx, rx) = channel();
        self.pool.submit(RunRequest {
            file: self.file.clone(),
            file_len: self.len,
            start_page: p,
            npages: 1,
            reply: tx,
            tag: self.tag.clone(),
        });
        if let Ok(reply) = rx.recv() {
            if reply.error.is_none() {
                if let Some(j) = job {
                    if reply.bytes_read > 0 {
                        j.add_physical_read(1);
                        j.add_bytes_read(reply.bytes_read);
                    }
                }
                let fresh = reply.page(0);
                if checks.page_ok(p, &fresh) {
                    return Ok(fresh);
                }
                self.stats.add_checksum_failure(1);
                if let Some(j) = job {
                    j.add_checksum_failure(1);
                }
            }
        }
        self.cache.quarantine(self.key_base + p);
        Err(IoError::corrupt(
            p,
            format!(
                "checksum mismatch on page {p} of {} persisted across a re-read: \
                 page quarantined",
                self.tag
            ),
        ))
    }

    /// Prefetch hint: asynchronously warm the cache for the byte ranges
    /// without blocking (used by algorithms that know their next accesses).
    pub fn prefetch(&self, ranges: &[ByteRange]) {
        let mut pages: Vec<u64> = Vec::new();
        for &(off, len) in ranges {
            if len == 0 || off >= self.len {
                continue;
            }
            let first = off / PAGE_SIZE as u64;
            let last = (off + len as u64 - 1).min(self.len - 1) / PAGE_SIZE as u64;
            for p in first..=last {
                if self.cache.peek(self.key_base + p).is_none() {
                    pages.push(p);
                }
            }
        }
        pages.sort_unstable();
        pages.dedup();
        if pages.is_empty() {
            return;
        }
        let (tx, rx) = channel();
        let runs = coalesce(&pages, self.pool.config().max_run_pages);
        let nruns = runs.len();
        for (start, n) in runs {
            self.pool.submit(RunRequest {
                file: self.file.clone(),
                file_len: self.len,
                start_page: start,
                npages: n,
                reply: tx.clone(),
                tag: self.tag.clone(),
            });
        }
        drop(tx);
        // fire-and-forget insertion on a helper thread so callers don't
        // block; failed runs are dropped (a prefetch is only a hint),
        // and so are pages that fail verification — the demand read
        // re-fetches and owns the recovery/quarantine decision
        let cache = self.cache.clone();
        let key_base = self.key_base;
        let checks = self.checks.clone();
        let stats = self.stats.clone();
        std::thread::spawn(move || {
            for _ in 0..nruns {
                if let Ok(reply) = rx.recv() {
                    if reply.error.is_some() {
                        continue;
                    }
                    for i in 0..reply.npages {
                        let p = reply.start_page + i as u64;
                        if let Some(ck) = &checks {
                            if !ck.page_ok(p, &reply.page(i)) {
                                stats.add_checksum_failure(1);
                                continue;
                            }
                        }
                        cache.insert(key_base + p, reply.page(i));
                    }
                }
            }
        });
    }

    /// Stats handle (shared with cache + pool).
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }
}

/// Pop a recycled assembly buffer with at least `len` capacity,
/// counting any fresh allocation or growth into `allocs`. Fit-aware:
/// a recycled buffer that is already big enough is preferred over
/// growing a smaller one, so repeated batches with the same range mix
/// converge to zero growth (the free list is a handful of entries —
/// one per page-spanning range of a batch — so the scan is trivial).
/// Step 4 of the batch read, shared by the sync and async paths:
/// assemble each requested range from the sorted page set `have`.
/// Single-page ranges become zero-copy views; page-spanning ranges are
/// built in recycled scratch buffers.
fn assemble(
    ranges: &[ByteRange],
    have: &[(u64, PageRef)],
    free: &mut Vec<Vec<u8>>,
    allocs: &mut u64,
    out: &mut Vec<RangeBuf>,
) {
    let lookup = |p: u64| -> &PageRef {
        let idx = have.binary_search_by_key(&p, |&(q, _)| q).expect("page present");
        &have[idx].1
    };
    for &(off, len) in ranges {
        let first = off / PAGE_SIZE as u64;
        let in_page = (off % PAGE_SIZE as u64) as usize;
        if len == 0 || in_page + len <= PAGE_SIZE {
            // common case: the whole range lives in one page — hand
            // out a view, copy nothing. (Empty ranges view page 0 of
            // the range's nominal position iff it exists; use an
            // empty owned buffer instead to avoid a fake lookup.)
            if len == 0 {
                out.push(RangeBuf::Owned(take_buf(free, allocs, 0)));
            } else {
                out.push(RangeBuf::Page {
                    page: lookup(first).clone(),
                    start: in_page,
                    len,
                });
            }
            continue;
        }
        // page-spanning: assemble into a recycled scratch buffer
        let mut buf = take_buf(free, allocs, len);
        let mut pos = off;
        let end = off + len as u64;
        while pos < end {
            let p = pos / PAGE_SIZE as u64;
            let in_page = (pos % PAGE_SIZE as u64) as usize;
            let take = ((end - pos) as usize).min(PAGE_SIZE - in_page);
            buf.extend_from_slice(&lookup(p)[in_page..in_page + take]);
            pos += take as u64;
        }
        out.push(RangeBuf::Owned(buf));
    }
}

fn take_buf(free: &mut Vec<Vec<u8>>, allocs: &mut u64, len: usize) -> Vec<u8> {
    if let Some(i) = free.iter().position(|v| v.capacity() >= len) {
        let mut v = free.swap_remove(i);
        v.clear();
        return v;
    }
    match free.pop() {
        Some(mut v) => {
            v.clear();
            *allocs += 1;
            v.reserve(len);
            v
        }
        None => {
            if len > 0 {
                *allocs += 1;
            }
            Vec::with_capacity(len)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::safs::io::{FaultPlan, IoConfig, IoErrorClass};
    use std::io::Write;

    fn setup(data: &[u8], cache_pages: usize) -> (std::path::PathBuf, SemFile) {
        let path = std::env::temp_dir().join(format!(
            "graphyti-semfile-{}-{:x}-{}",
            std::process::id(),
            data.as_ptr() as usize,
            data.len()
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(data).unwrap();
        f.sync_all().unwrap();
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(cache_pages * PAGE_SIZE, stats.clone()));
        let pool = Arc::new(IoPool::new(IoConfig { threads: 3, ..Default::default() }, stats));
        let sem = SemFile::open(&path, cache, pool).unwrap();
        (path, sem)
    }

    fn pattern(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i % 241) as u8).collect()
    }

    #[test]
    fn read_roundtrip_unaligned() {
        let data = pattern(PAGE_SIZE * 5 + 1234);
        let (path, f) = setup(&data, 128);
        for &(off, len) in &[
            (0u64, 10usize),
            (PAGE_SIZE as u64 - 1, 2),                  // page straddle
            (PAGE_SIZE as u64 * 2 + 100, PAGE_SIZE * 2), // multi-page
            (data.len() as u64 - 5, 5),                  // tail
            (77, 0),                                     // empty
        ] {
            let got = f.read(off, len).unwrap();
            assert_eq!(&got[..], &data[off as usize..off as usize + len], "range ({off},{len})");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn read_past_eof_errors() {
        let data = pattern(100);
        let (path, f) = setup(&data, 64);
        assert!(f.read(90, 20).is_err());
        assert!(f.read(0, 100).is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn second_read_hits_cache() {
        let data = pattern(PAGE_SIZE * 4);
        let (path, f) = setup(&data, 128);
        f.read(0, PAGE_SIZE * 2).unwrap();
        let before = f.stats().snapshot();
        f.read(0, PAGE_SIZE * 2).unwrap();
        let d = f.stats().snapshot().delta(&before);
        assert_eq!(d.cache_misses, 0, "all pages should hit: {d:?}");
        assert_eq!(d.physical_reads, 0);
        assert_eq!(d.cache_hits, 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn batch_misses_are_merged() {
        let data = pattern(PAGE_SIZE * 32);
        let (path, f) = setup(&data, 128);
        // 8 contiguous page-sized ranges => one merged physical read
        let ranges: Vec<ByteRange> =
            (0..8).map(|i| (i as u64 * PAGE_SIZE as u64, PAGE_SIZE)).collect();
        let before = f.stats().snapshot();
        let out = f.read_ranges(&ranges).unwrap();
        for (i, buf) in out.iter().enumerate() {
            assert_eq!(&buf[..], &data[i * PAGE_SIZE..(i + 1) * PAGE_SIZE]);
        }
        let d = f.stats().snapshot().delta(&before);
        assert_eq!(d.read_requests, 8);
        assert_eq!(d.physical_reads, 1, "adjacent misses must coalesce: {d:?}");
        assert_eq!(d.merged_requests, 7);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn overlapping_ranges_share_pages() {
        let data = pattern(PAGE_SIZE * 2);
        let (path, f) = setup(&data, 64);
        let before = f.stats().snapshot();
        let out = f
            .read_ranges(&[(0, PAGE_SIZE), (100, 200), (PAGE_SIZE as u64 / 2, 10)])
            .unwrap();
        assert_eq!(&out[1][..], &data[100..300]);
        assert_eq!(&out[2][..], &data[PAGE_SIZE / 2..PAGE_SIZE / 2 + 10]);
        let d = f.stats().snapshot().delta(&before);
        // all three ranges live in page 0 => exactly one miss
        assert_eq!(d.cache_misses, 1, "{d:?}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn eviction_pressure_still_correct() {
        let data = pattern(PAGE_SIZE * 512);
        // tiny cache: 64 pages (1 per shard), constant eviction
        let (path, f) = setup(&data, 64);
        let mut rng = crate::util::XorShift::new(11);
        for _ in 0..200 {
            let off = rng.next_below((data.len() - 100) as u64);
            let len = 1 + rng.next_below(99) as usize;
            let got = f.read(off, len).unwrap();
            assert_eq!(&got[..], &data[off as usize..off as usize + len]);
        }
        let s = f.stats().snapshot();
        assert!(s.evictions > 0, "cache must be under pressure: {s:?}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn keyed_files_share_one_cache_without_aliasing() {
        let a = pattern(PAGE_SIZE * 2);
        let b: Vec<u8> = a.iter().map(|x| x ^ 0xFF).collect();
        let pa = std::env::temp_dir()
            .join(format!("graphyti-keyed-a-{}", std::process::id()));
        let pb = std::env::temp_dir()
            .join(format!("graphyti-keyed-b-{}", std::process::id()));
        std::fs::write(&pa, &a).unwrap();
        std::fs::write(&pb, &b).unwrap();
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(128 * PAGE_SIZE, stats.clone()));
        let pool =
            Arc::new(IoPool::new(IoConfig { threads: 2, ..Default::default() }, stats));
        let fa = SemFile::open_keyed(&pa, cache.clone(), pool.clone(), 0).unwrap();
        let fb = SemFile::open_keyed(&pb, cache, pool, 1 << 44).unwrap();
        // both files' page 0 live in the same cache under disjoint keys
        for _ in 0..2 {
            assert_eq!(fa.read(0, PAGE_SIZE).unwrap(), a[..PAGE_SIZE]);
            assert_eq!(fb.read(0, PAGE_SIZE).unwrap(), b[..PAGE_SIZE]);
        }
        let s = fa.stats().snapshot();
        assert_eq!(s.cache_misses, 2, "one cold miss per file: {s:?}");
        assert_eq!(s.cache_hits, 2, "second round must hit both: {s:?}");
        let _ = std::fs::remove_file(pa);
        let _ = std::fs::remove_file(pb);
    }

    #[test]
    fn tracked_reads_attribute_to_job_stats() {
        let data = pattern(PAGE_SIZE * 8);
        let (path, f) = setup(&data, 128);
        let job = IoStats::new();
        let out = f.read_ranges_tracked(&[(0, PAGE_SIZE * 2)], Some(&job)).unwrap();
        assert_eq!(&out[0][..], &data[..PAGE_SIZE * 2]);
        let j = job.snapshot();
        assert_eq!(j.read_requests, 1);
        assert_eq!(j.cache_misses, 2);
        assert_eq!(j.physical_reads, 1, "one coalesced run: {j:?}");
        assert_eq!(j.bytes_read, 2 * PAGE_SIZE as u64);
        // warm re-read: attributed as hits, no new physical I/O
        f.read_ranges_tracked(&[(0, PAGE_SIZE * 2)], Some(&job)).unwrap();
        let j = job.snapshot();
        assert_eq!(j.cache_hits, 2);
        assert_eq!(j.physical_reads, 1);
        // the global stats aggregate at least everything the job saw
        let g = f.stats().snapshot();
        assert_eq!(g.read_requests, j.read_requests);
        assert_eq!(g.bytes_read, j.bytes_read);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn single_page_ranges_are_zero_copy_views() {
        let data = pattern(PAGE_SIZE * 4);
        let (path, f) = setup(&data, 128);
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        f.read_ranges_into(
            &[
                (10, 100),                                   // within page 0
                (PAGE_SIZE as u64 - 50, 100),                // spans 0|1
                (PAGE_SIZE as u64 * 2, PAGE_SIZE),           // exactly page 2
                (7, 0),                                      // empty
            ],
            None,
            &mut scratch,
            &mut out,
        )
        .unwrap();
        assert!(matches!(out[0], RangeBuf::Page { .. }), "in-page range must be a view");
        assert!(matches!(out[1], RangeBuf::Owned(_)), "spanning range must assemble");
        assert!(matches!(out[2], RangeBuf::Page { .. }), "page-aligned full page is a view");
        assert_eq!(&out[0][..], &data[10..110]);
        assert_eq!(&out[1][..], &data[PAGE_SIZE - 50..PAGE_SIZE + 50]);
        assert_eq!(&out[2][..], &data[PAGE_SIZE * 2..PAGE_SIZE * 3]);
        assert!(out[3].is_empty());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn warm_batches_allocate_nothing_through_the_scratch() {
        let data = pattern(PAGE_SIZE * 8);
        let (path, f) = setup(&data, 128);
        let ranges: Vec<ByteRange> = vec![
            (100, 200),
            (PAGE_SIZE as u64 - 10, 20), // spanning: exercises the free list
            (PAGE_SIZE as u64 * 3 + 7, 64),
        ];
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        // cold call: pages read, buffers allocated
        f.read_ranges_into(&ranges, None, &mut scratch, &mut out).unwrap();
        for (got, &(off, len)) in out.iter().zip(&ranges) {
            assert_eq!(&got[..], &data[off as usize..off as usize + len]);
        }
        // warm calls: same batch must be allocation-free via the scratch
        let warm = scratch.allocs();
        for _ in 0..10 {
            f.read_ranges_into(&ranges, None, &mut scratch, &mut out).unwrap();
            for (got, &(off, len)) in out.iter().zip(&ranges) {
                assert_eq!(&got[..], &data[off as usize..off as usize + len]);
            }
        }
        assert_eq!(scratch.allocs(), warm, "warm batches must not allocate");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn range_views_survive_eviction_of_their_pages() {
        let data = pattern(PAGE_SIZE * 256);
        let (path, f) = setup(&data, 64); // 1 frame per shard
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        f.read_ranges_into(&[(5, 50)], None, &mut scratch, &mut out).unwrap();
        let held = out.pop().unwrap();
        // thrash the cache so page 0 is long evicted
        for i in 0..255u64 {
            f.read(i * PAGE_SIZE as u64, PAGE_SIZE).unwrap();
        }
        assert_eq!(&held[..], &data[5..55], "view must outlive eviction");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn submit_poll_finish_matches_sync_read() {
        let data = pattern(PAGE_SIZE * 16);
        let (path, f) = setup(&data, 128);
        let ranges: Vec<ByteRange> = vec![
            (10, 100),
            (PAGE_SIZE as u64 - 50, 100), // page-spanning
            (PAGE_SIZE as u64 * 7, PAGE_SIZE),
            (3, 0), // empty
        ];
        let mut pending = f.submit_ranges(&ranges, None).unwrap();
        assert!(pending.outstanding() > 0, "cold batch must go to the pool");
        // drive to completion without blocking the caller thread; the
        // backoff ladder parks between polls instead of burning a core
        // with bare yields while the I/O pool works. Deadline-bounded,
        // and poll_ranges is safe to repeat, so parking cannot miss a
        // wakeup.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut backoff = crate::util::Backoff::new();
        while !f.poll_ranges(&mut pending, None) {
            assert!(std::time::Instant::now() < deadline, "batch never completed");
            backoff.snooze();
        }
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        let waits_before = f.stats().snapshot().thread_waits;
        f.finish_ranges(&ranges, pending, None, &mut scratch, &mut out).unwrap();
        for (got, &(off, len)) in out.iter().zip(&ranges) {
            assert_eq!(&got[..], &data[off as usize..off as usize + len]);
        }
        assert_eq!(
            f.stats().snapshot().thread_waits,
            waits_before,
            "fully-polled finish must not block"
        );
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn finish_without_poll_blocks_and_counts_a_wait() {
        let data = pattern(PAGE_SIZE * 8);
        let (path, f) = setup(&data, 128);
        let ranges = [(0u64, PAGE_SIZE * 3)];
        let job = IoStats::new();
        let pending = f.submit_ranges(&ranges, Some(&job)).unwrap();
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        f.finish_ranges(&ranges, pending, Some(&job), &mut scratch, &mut out).unwrap();
        assert_eq!(&out[0][..], &data[..PAGE_SIZE * 3]);
        let j = job.snapshot();
        assert_eq!(j.read_requests, 1);
        assert_eq!(j.cache_misses, 3);
        assert_eq!(j.physical_reads, 1, "one coalesced run: {j:?}");
        assert_eq!(j.bytes_read, 3 * PAGE_SIZE as u64);
        assert_eq!(j.thread_waits, 1, "unpolled finish blocks once");
        assert_eq!(j.latency.fetch.count, 1, "async fetch records latency");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn warm_submit_is_complete_immediately() {
        let data = pattern(PAGE_SIZE * 4);
        let (path, f) = setup(&data, 128);
        let ranges = [(0u64, PAGE_SIZE * 2)];
        f.read_ranges(&ranges).unwrap(); // warm the cache
        let mut pending = f.submit_ranges(&ranges, None).unwrap();
        assert_eq!(pending.outstanding(), 0, "warm batch needs no I/O");
        assert!(f.poll_ranges(&mut pending, None));
        let waits_before = f.stats().snapshot().thread_waits;
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        f.finish_ranges(&ranges, pending, None, &mut scratch, &mut out).unwrap();
        assert_eq!(&out[0][..], &data[..PAGE_SIZE * 2]);
        assert_eq!(f.stats().snapshot().thread_waits, waits_before);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn overlapping_pending_reads_coexist() {
        let data = pattern(PAGE_SIZE * 32);
        let (path, f) = setup(&data, 128);
        // four disjoint in-flight batches, finished out of submit order
        let batches: Vec<[ByteRange; 1]> =
            (0..4).map(|i| [(i as u64 * 8 * PAGE_SIZE as u64, PAGE_SIZE * 2)]).collect();
        let mut pendings: Vec<PendingRead> = batches
            .iter()
            .map(|r| f.submit_ranges(&r[..], None).unwrap())
            .collect();
        let mut scratch = RangeScratch::new();
        let mut out = Vec::new();
        for i in (0..4).rev() {
            let p = pendings.pop().unwrap();
            f.finish_ranges(&batches[i][..], p, None, &mut scratch, &mut out).unwrap();
            let off = batches[i][0].0 as usize;
            assert_eq!(&out[0][..], &data[off..off + PAGE_SIZE * 2], "batch {i}");
        }
        let _ = std::fs::remove_file(path);
    }

    /// Like `setup`, but installs a checksum table computed from `data`
    /// and runs the pool under `fault` (single-threaded, so request ids
    /// follow submission order deterministically).
    fn setup_verified(
        data: &[u8],
        cache_pages: usize,
        fault: Option<FaultPlan>,
    ) -> (std::path::PathBuf, SemFile) {
        let path = std::env::temp_dir().join(format!(
            "graphyti-semfile-vrf-{}-{:x}-{}",
            std::process::id(),
            data.as_ptr() as usize,
            data.len()
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(data).unwrap();
        f.sync_all().unwrap();
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(cache_pages * PAGE_SIZE, stats.clone()));
        let pool =
            Arc::new(IoPool::new(IoConfig { threads: 1, fault, ..Default::default() }, stats));
        let mut sem = SemFile::open(&path, cache, pool).unwrap();
        let crcs = data.chunks(PAGE_SIZE).map(crate::util::crc32c).collect();
        sem.install_checksums(PageChecksums::new(data.len() as u64, crcs));
        (path, sem)
    }

    fn flip_plan(period: u64) -> Option<FaultPlan> {
        Some(FaultPlan {
            seed: 0xBAD,
            jitter_us: 0,
            reorder: false,
            eio_period: 0,
            fail_path: None,
            flip_period: period,
            flip_path: None,
        })
    }

    #[test]
    fn verified_clean_reads_are_free_and_correct() {
        let data = pattern(PAGE_SIZE * 4 + 777); // unaligned tail page
        let (path, f) = setup_verified(&data, 128, None);
        assert!(f.verified());
        assert_eq!(f.len(), data.len() as u64, "visible length is the data length");
        for &(off, len) in
            &[(0u64, PAGE_SIZE + 5), (PAGE_SIZE as u64 * 3 + 9, PAGE_SIZE), (data.len() as u64 - 3, 3)]
        {
            let got = f.read(off, len).unwrap();
            assert_eq!(&got[..], &data[off as usize..off as usize + len], "range ({off},{len})");
        }
        let s = f.stats().snapshot();
        assert_eq!(s.checksum_failures, 0, "clean image must not trip verification: {s:?}");
        assert_eq!(s.quarantined_pages, 0);
        // warm re-read: hits serve pre-verified bytes, no new I/O
        let before = f.stats().snapshot();
        f.read(0, PAGE_SIZE).unwrap();
        assert_eq!(f.stats().snapshot().delta(&before).physical_reads, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn transient_flip_is_healed_by_one_corrective_reread() {
        let data = pattern(PAGE_SIZE * 4);
        // flip_period 2: request ids 1, 3, ... are corrupted. The first
        // read (id 0) is clean; the second (id 1) flips, and its
        // corrective re-read (id 2) comes back clean.
        let (path, f) = setup_verified(&data, 128, flip_plan(2));
        assert_eq!(f.read(0, PAGE_SIZE).unwrap()[..], data[..PAGE_SIZE]);
        let got = f.read(PAGE_SIZE as u64, PAGE_SIZE).unwrap();
        assert_eq!(got[..], data[PAGE_SIZE..2 * PAGE_SIZE], "healed read returns true bytes");
        let s = f.stats().snapshot();
        assert_eq!(s.checksum_failures, 1, "one detection, cleared on re-read: {s:?}");
        assert_eq!(s.quarantined_pages, 0, "a healed page is not quarantined: {s:?}");
        assert_eq!(s.physical_reads, 3, "two demand reads + one corrective: {s:?}");
        // the healed copy is cached: no further I/O to read it again
        let before = f.stats().snapshot();
        assert_eq!(f.read(PAGE_SIZE as u64, PAGE_SIZE).unwrap()[..], data[PAGE_SIZE..2 * PAGE_SIZE]);
        assert_eq!(f.stats().snapshot().delta(&before).physical_reads, 0);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn persistent_flip_quarantines_and_fast_fails_thereafter() {
        let data = pattern(PAGE_SIZE * 2);
        // flip_period 1: every read of this file is corrupted, so the
        // corrective re-read cannot clear the mismatch
        let (path, f) = setup_verified(&data, 128, flip_plan(1));
        let err = f.read(0, PAGE_SIZE).unwrap_err();
        let io = err.downcast_ref::<IoError>().expect("typed IoError in the chain");
        assert_eq!(io.class, IoErrorClass::Corrupt);
        assert_eq!(io.page, Some(0));
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum mismatch") && msg.contains("quarantined"), "{msg}");
        let s = f.stats().snapshot();
        assert_eq!(s.checksum_failures, 2, "detected on read and on re-read: {s:?}");
        assert_eq!(s.quarantined_pages, 1, "{s:?}");
        assert_eq!(s.physical_reads, 2, "demand read + one corrective, no more: {s:?}");
        // subsequent touches fail fast: no I/O, same typed error
        let before = f.stats().snapshot();
        let err2 = f.read(100, 8).unwrap_err();
        assert_eq!(
            err2.downcast_ref::<IoError>().unwrap().class,
            IoErrorClass::Corrupt,
            "{err2:#}"
        );
        assert!(format!("{err2:#}").contains("quarantined"), "{err2:#}");
        let d = f.stats().snapshot().delta(&before);
        assert_eq!(d.physical_reads, 0, "quarantined pages are never re-read: {d:?}");
        // the async path refuses the page at submit time too
        assert!(f.submit_ranges(&[(0, 8)], None).is_err());
        // other pages of the same file still work... except flips hit
        // them too here (period 1), so just assert the error names the
        // right page for a different page number
        let err3 = f.read(PAGE_SIZE as u64, 8).unwrap_err();
        assert_eq!(err3.downcast_ref::<IoError>().unwrap().page, Some(1));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn prefetch_drops_unverifiable_pages_silently() {
        let data = pattern(PAGE_SIZE * 2);
        let path = std::env::temp_dir()
            .join(format!("graphyti-semfile-pfv-{}", std::process::id()));
        std::fs::write(&path, &data).unwrap();
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(128 * PAGE_SIZE, stats.clone()));
        let pool = Arc::new(IoPool::new(
            IoConfig { threads: 1, fault: flip_plan(1), ..Default::default() },
            stats.clone(),
        ));
        let mut f = SemFile::open(&path, cache.clone(), pool).unwrap();
        let crcs = data.chunks(PAGE_SIZE).map(crate::util::crc32c).collect();
        f.install_checksums(PageChecksums::new(data.len() as u64, crcs));
        // both pages coalesce into one run, which the plan corrupts by a
        // single bit — so exactly one of the two pages fails
        // verification and is dropped; the other lands normally
        f.prefetch(&[(0, PAGE_SIZE * 2)]);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while stats.snapshot().checksum_failures + cache.resident_pages() < 2 {
            assert!(std::time::Instant::now() < deadline, "prefetch never finished");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let s = stats.snapshot();
        assert_eq!(s.checksum_failures, 1, "{s:?}");
        assert_eq!(cache.resident_pages(), 1, "the bad page never lands");
        assert_eq!(s.quarantined_pages, 0, "a hint never quarantines: {s:?}");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn prefetch_warms_cache() {
        let data = pattern(PAGE_SIZE * 16);
        let (path, f) = setup(&data, 128);
        f.prefetch(&[(0, PAGE_SIZE * 8)]);
        // wait for the prefetch helper to land pages
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        loop {
            let s = f.stats().snapshot();
            if s.bytes_read >= (8 * PAGE_SIZE) as u64 || std::time::Instant::now() > deadline {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let before = f.stats().snapshot();
        f.read(0, PAGE_SIZE * 8).unwrap();
        let d = f.stats().snapshot().delta(&before);
        assert_eq!(d.cache_misses, 0, "prefetched pages should all hit: {d:?}");
        let _ = std::fs::remove_file(path);
    }
}
