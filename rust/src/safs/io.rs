//! Asynchronous parallel I/O pool with request merging.
//!
//! SAFS's core trick is keeping many outstanding requests against the SSD
//! array and coalescing adjacent ones before dispatch. Our substitute is a
//! thread pool draining a shared queue of *page runs* (already coalesced
//! by the submitter, [`super::SemFile`]); each run becomes one `pread`.
//! Runs from a single caller batch are serviced concurrently by all pool
//! threads, which is what overlaps computation with I/O in the engine.
//!
//! **Latency injection**: the paper's testbed is an SSD array whose access
//! latency dominates; on a dev box the OS page cache would hide file
//! reads entirely and collapse the SEM-vs-in-memory distinction. An
//! optional per-`pread` delay (`io_delay_us`) restores an SSD-like cost
//! model (default off; benches enable it — see DESIGN.md §5).

use std::collections::VecDeque;
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::page_cache::{PageRef, PAGE_SIZE};
use super::stats::IoStats;

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct IoConfig {
    /// Number of I/O service threads.
    pub threads: usize,
    /// Injected latency per physical read, microseconds (0 = off).
    pub io_delay_us: u64,
    /// Maximum pages per merged run (bounds single-pread size).
    pub max_run_pages: usize,
}

impl Default for IoConfig {
    fn default() -> Self {
        IoConfig { threads: 4, io_delay_us: 0, max_run_pages: 256 }
    }
}

/// One coalesced read: pages `[start_page, start_page + npages)`.
pub(crate) struct RunRequest {
    pub file: Arc<File>,
    pub file_len: u64,
    pub start_page: u64,
    pub npages: usize,
    pub reply: Sender<RunReply>,
}

/// Completed run: one shared buffer holding every page contiguously.
///
/// This is the zero-copy pivot of the fetch path: the pool allocates
/// **once** per coalesced run (up to `max_run_pages` pages), and the
/// cache, the range assembler and the decoder all work through
/// [`PageRef`] views into this buffer — a 256-page run that used to cost
/// 256 page allocations plus copies now costs one allocation and zero
/// copies.
pub(crate) struct RunReply {
    pub start_page: u64,
    /// Pages in the run; `buf.len() == npages * PAGE_SIZE`.
    pub npages: usize,
    /// The run buffer. The tail past `bytes_read` is EOF zero padding.
    pub buf: Arc<[u8]>,
    /// Bytes actually read from disk (0 for a fully-past-EOF run).
    pub bytes_read: u64,
}

impl RunReply {
    /// Zero-copy view of page `i` of the run.
    #[inline]
    pub fn page(&self, i: usize) -> PageRef {
        PageRef::new(self.buf.clone(), i * PAGE_SIZE)
    }
}

struct Queue {
    q: Mutex<VecDeque<RunRequest>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Asynchronous I/O thread pool.
pub struct IoPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    cfg: IoConfig,
    stats: Arc<IoStats>,
}

impl IoPool {
    /// Spawn the pool.
    pub fn new(cfg: IoConfig, stats: Arc<IoStats>) -> Self {
        let queue = Arc::new(Queue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (0..cfg.threads.max(1))
            .map(|i| {
                let queue = queue.clone();
                let stats = stats.clone();
                let delay = cfg.io_delay_us;
                std::thread::Builder::new()
                    .name(format!("safs-io-{i}"))
                    .spawn(move || Self::worker_loop(queue, stats, delay))
                    .expect("spawn io worker")
            })
            .collect();
        IoPool { queue, workers, cfg, stats }
    }

    /// Submit one coalesced run. The reply arrives on `req.reply`.
    pub(crate) fn submit(&self, req: RunRequest) {
        let mut q = self.queue.q.lock().unwrap();
        q.push_back(req);
        drop(q);
        self.queue.cv.notify_one();
    }

    /// Pool configuration.
    pub fn config(&self) -> &IoConfig {
        &self.cfg
    }

    /// Stats handle.
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    fn worker_loop(queue: Arc<Queue>, stats: Arc<IoStats>, delay_us: u64) {
        loop {
            let req = {
                let mut q = queue.q.lock().unwrap();
                loop {
                    if let Some(r) = q.pop_front() {
                        break r;
                    }
                    if queue.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    q = queue.cv.wait(q).unwrap();
                }
            };
            let reply = Self::service(&req, &stats, delay_us);
            // receiver may have gone away (caller panicked); ignore.
            let _ = req.reply.send(reply);
        }
    }

    /// Execute one run: a single pread into one shared buffer covering
    /// all pages, zero-padded at EOF.
    ///
    /// Stats count what actually happened: `bytes_read` is the byte
    /// count the pread returned (not the padded run size), and a run
    /// lying entirely past EOF performs no pread, pays no injected
    /// latency and moves no counters.
    fn service(req: &RunRequest, stats: &IoStats, delay_us: u64) -> RunReply {
        let offset = req.start_page * PAGE_SIZE as u64;
        let want = req.npages * PAGE_SIZE;
        // single run buffer; the TrustedLen collect writes it in place
        let mut buf: Arc<[u8]> = (0..want).map(|_| 0u8).collect();
        let avail = (req.file_len.saturating_sub(offset) as usize).min(want);
        let mut done = 0;
        if avail > 0 {
            let t0 = std::time::Instant::now();
            let dst = Arc::get_mut(&mut buf).expect("fresh run buffer is uniquely owned");
            while done < avail {
                match req.file.read_at(&mut dst[done..avail], offset + done as u64) {
                    Ok(0) => break,
                    Ok(n) => done += n,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(e) => panic!("safs pread failed: {e}"),
                }
            }
            if delay_us > 0 {
                // emulate SSD access latency per physical request
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            stats.add_physical_read(1);
            stats.add_bytes_read(done as u64);
            // latency includes the injected delay so figure runs show
            // the emulated SSD cost; EOF-only runs record nothing
            stats.pread_latency_us.record(t0.elapsed().as_micros() as u64);
            stats.run_pages.record(req.npages as u64);
        }
        RunReply {
            start_page: req.start_page,
            npages: req.npages,
            buf,
            bytes_read: done as u64,
        }
    }
}

impl Drop for IoPool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::Release);
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Coalesce a sorted, deduped page list into runs of at most
/// `max_run_pages` consecutive pages. Returns `(start_page, npages)` runs.
pub fn coalesce(pages: &[u64], max_run_pages: usize) -> Vec<(u64, usize)> {
    let mut runs = Vec::new();
    let mut i = 0;
    while i < pages.len() {
        let start = pages[i];
        let mut n = 1usize;
        while i + n < pages.len() && pages[i + n] == start + n as u64 && n < max_run_pages {
            n += 1;
        }
        runs.push((start, n));
        i += n;
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::sync::mpsc::channel;

    #[test]
    fn coalesce_runs() {
        assert_eq!(coalesce(&[], 16), vec![]);
        assert_eq!(coalesce(&[5], 16), vec![(5, 1)]);
        assert_eq!(coalesce(&[1, 2, 3, 7, 8, 20], 16), vec![(1, 3), (7, 2), (20, 1)]);
        // run splitting at max_run_pages
        assert_eq!(coalesce(&[0, 1, 2, 3], 2), vec![(0, 2), (2, 2)]);
    }

    fn temp_file(bytes: &[u8]) -> (std::path::PathBuf, Arc<File>) {
        let path = std::env::temp_dir().join(format!(
            "graphyti-io-test-{}-{:x}",
            std::process::id(),
            bytes.as_ptr() as usize
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        (path.clone(), Arc::new(File::open(&path).unwrap()))
    }

    #[test]
    fn pool_reads_pages_and_pads_eof() {
        // 1.5 pages of data
        let mut data = vec![0u8; PAGE_SIZE + PAGE_SIZE / 2];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(IoConfig { threads: 2, ..Default::default() }, stats.clone());
        let (tx, rx) = channel();
        pool.submit(RunRequest {
            file: file.clone(),
            file_len: data.len() as u64,
            start_page: 0,
            npages: 2,
            reply: tx,
        });
        let reply = rx.recv().unwrap();
        assert_eq!(reply.npages, 2);
        assert_eq!(reply.buf.len(), 2 * PAGE_SIZE);
        assert_eq!(&reply.page(0)[..], &data[..PAGE_SIZE]);
        assert_eq!(&reply.page(1)[..PAGE_SIZE / 2], &data[PAGE_SIZE..]);
        assert!(reply.page(1)[PAGE_SIZE / 2..].iter().all(|&b| b == 0), "EOF padding");
        let s = stats.snapshot();
        assert_eq!(s.physical_reads, 1);
        // stats count the bytes the disk produced, not the padded run
        assert_eq!(s.bytes_read, data.len() as u64);
        assert_eq!(reply.bytes_read, data.len() as u64);
        assert_eq!(s.latency.pread.count, 1, "one pread, one latency sample");
        assert_eq!(s.latency.run_pages.count, 1);
        assert!(s.latency.run_pages.p50 >= 2, "2-page run: {:?}", s.latency.run_pages);
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fully_past_eof_run_skips_the_read_and_the_stats() {
        // 1 page of data; request pages [8, 10): nothing to read
        let data = vec![3u8; PAGE_SIZE];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        // huge delay would show up in the test's runtime if the skipped
        // pread still paid it
        let pool = IoPool::new(
            IoConfig { threads: 1, io_delay_us: 200_000, ..Default::default() },
            stats.clone(),
        );
        let (tx, rx) = channel();
        let t = std::time::Instant::now();
        pool.submit(RunRequest {
            file,
            file_len: data.len() as u64,
            start_page: 8,
            npages: 2,
            reply: tx,
        });
        let reply = rx.recv().unwrap();
        assert!(t.elapsed() < std::time::Duration::from_millis(150), "no delay for no read");
        assert_eq!(reply.bytes_read, 0);
        assert!(reply.buf.iter().all(|&b| b == 0), "pure padding");
        let s = stats.snapshot();
        assert_eq!(s.physical_reads, 0, "no pread happened: {s:?}");
        assert_eq!(s.bytes_read, 0, "no bytes moved: {s:?}");
        assert_eq!(s.latency.pread.count, 0, "EOF-only runs record no latency");
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn pool_services_many_runs_concurrently() {
        let data = vec![7u8; PAGE_SIZE * 64];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(IoConfig { threads: 4, ..Default::default() }, stats.clone());
        let (tx, rx) = channel();
        for p in 0..64u64 {
            pool.submit(RunRequest {
                file: file.clone(),
                file_len: data.len() as u64,
                start_page: p,
                npages: 1,
                reply: tx.clone(),
            });
        }
        drop(tx);
        let mut got = 0;
        while let Ok(r) = rx.recv() {
            assert_eq!(r.npages, 1);
            assert!(r.page(0).iter().all(|&b| b == 7));
            got += 1;
        }
        assert_eq!(got, 64);
        assert_eq!(stats.snapshot().physical_reads, 64);
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn latency_injection_slows_reads() {
        let data = vec![1u8; PAGE_SIZE * 8];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(
            IoConfig { threads: 1, io_delay_us: 2000, ..Default::default() },
            stats,
        );
        let (tx, rx) = channel();
        let t = std::time::Instant::now();
        for p in 0..4u64 {
            pool.submit(RunRequest {
                file: file.clone(),
                file_len: data.len() as u64,
                start_page: p,
                npages: 1,
                reply: tx.clone(),
            });
        }
        drop(tx);
        while rx.recv().is_ok() {}
        assert!(
            t.elapsed() >= std::time::Duration::from_millis(8),
            "4 serial reads at 2ms injected latency must take >= 8ms"
        );
        drop(pool);
        let _ = std::fs::remove_file(path);
    }
}
