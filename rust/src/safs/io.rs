//! Asynchronous parallel I/O pool with request merging.
//!
//! SAFS's core trick is keeping many outstanding requests against the SSD
//! array and coalescing adjacent ones before dispatch. Our substitute is a
//! thread pool draining a shared queue of *page runs* (already coalesced
//! by the submitter, [`super::SemFile`]); each run becomes one `pread`.
//! Runs from a single caller batch are serviced concurrently by all pool
//! threads, which is what overlaps computation with I/O in the engine.
//!
//! **Latency injection**: the paper's testbed is an SSD array whose access
//! latency dominates; on a dev box the OS page cache would hide file
//! reads entirely and collapse the SEM-vs-in-memory distinction. An
//! optional per-`pread` delay (`io_delay_us`) restores an SSD-like cost
//! model (default off; benches enable it — see DESIGN.md §5).

use std::collections::VecDeque;
use std::fs::File;
use std::os::unix::fs::FileExt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::page_cache::{PageRef, PAGE_SIZE};
use super::stats::IoStats;

/// Deterministic fault injection for tests: everything keys off
/// `seed` and the pool-assigned request id through splitmix64, so two
/// runs submitting the same request sequence observe the same jitter,
/// the same reorderings, the same transient errors and the same backoff
/// waits.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for every derived decision.
    pub seed: u64,
    /// Extra per-request latency in `0..=jitter_us` microseconds, on top
    /// of `io_delay_us` (0 = no jitter).
    pub jitter_us: u64,
    /// Service queued runs out of submission order (seeded front/back
    /// pops), so completions arrive shuffled relative to submits.
    pub reorder: bool,
    /// Every `eio_period`-th request suffers a transient read error on
    /// its **first** service attempt; the pool's bounded backoff retries
    /// it (deterministically successful on the second attempt, counted
    /// in [`IoStats::retries`]). 0 = no transient errors.
    pub eio_period: u64,
    /// Inject a **permanent** failure on every request whose file tag
    /// contains this substring: the request fails immediately with a
    /// [`IoErrorClass::Permanent`] error reply — no retries, no backoff
    /// — which the fetch path must surface as a clean per-job failure.
    /// `None` = no permanent injection.
    pub fail_path: Option<Arc<str>>,
    /// Every `flip_period`-th request has exactly **one bit** of its
    /// successfully-read payload flipped before the reply is sent — a
    /// silent-corruption model (misdirected write, bit rot, cable hit)
    /// that only checksum verification can catch. The flipped bit is
    /// chosen by splitmix64 off `(seed, req_id)`, so a fixed submit
    /// sequence corrupts the same bit of the same request every run.
    /// 0 = no flips.
    pub flip_period: u64,
    /// Restrict bit-flips to requests whose file tag contains this
    /// substring (e.g. `"gy-adj"` to corrupt only edge pages). `None` =
    /// flips apply to every `flip_period`-th request.
    pub flip_path: Option<Arc<str>>,
}

impl FaultPlan {
    /// A plan exercising jitter, reordering and transient errors at once
    /// (no permanent failures or corruption: chaos runs must still
    /// complete with correct results).
    pub fn chaos(seed: u64) -> Self {
        FaultPlan {
            seed,
            jitter_us: 200,
            reorder: true,
            eio_period: 7,
            fail_path: None,
            flip_period: 0,
            flip_path: None,
        }
    }
}

/// How a failed substrate read should be treated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoErrorClass {
    /// Worth retrying: the pool already did, with bounded exponential
    /// backoff — a reply carrying this class means retries were
    /// exhausted without the error clearing.
    Transient,
    /// Not worth retrying (unreadable device, bad descriptor, injected
    /// permanent fault): fail the owning job cleanly.
    Permanent,
    /// The read completed but the page's checksum did not match its
    /// recorded crc32c, and one bounded re-read did not clear it: the
    /// storage is returning wrong bytes. The page is quarantined and the
    /// owning job fails; co-tenants are untouched.
    Corrupt,
}

/// A typed substrate read failure, delivered inside [`RunReply`] instead
/// of panicking the pool thread. The fetch path propagates it up to the
/// engine, which fails the owning job at the next round boundary while
/// concurrent healthy jobs keep running.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IoError {
    /// Transient-exhausted vs immediately-permanent vs checksum-corrupt.
    pub class: IoErrorClass,
    /// Human-readable cause, including the file tag.
    pub message: String,
    /// For [`IoErrorClass::Corrupt`]: the file-local page number that
    /// failed verification (also named in `message`).
    pub page: Option<u64>,
}

impl IoError {
    fn permanent(message: String) -> Self {
        IoError { class: IoErrorClass::Permanent, message, page: None }
    }

    /// A verified-corruption failure on `page` (file-local page number).
    pub fn corrupt(page: u64, message: String) -> Self {
        IoError { class: IoErrorClass::Corrupt, message, page: Some(page) }
    }
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for IoError {}

/// Retry budget for transient read errors (first attempt + 4 retries).
const MAX_ATTEMPTS: u64 = 5;
/// First backoff wait; doubles per retry.
const BACKOFF_BASE_US: u64 = 100;
/// Backoff ceiling.
const BACKOFF_CAP_US: u64 = 10_000;

/// Classify an OS read error. `Interrupted` never reaches this (it is a
/// free in-place retry, as before); `WouldBlock`/`TimedOut` and raw
/// `EIO` are worth backing off and retrying, anything else is permanent.
fn classify(e: &std::io::Error) -> IoErrorClass {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => IoErrorClass::Transient,
        _ if e.raw_os_error() == Some(5) => IoErrorClass::Transient,
        _ => IoErrorClass::Permanent,
    }
}

/// splitmix64 finalizer — the deterministic decision function behind
/// [`FaultPlan`].
#[inline]
fn mix(seed: u64, x: u64) -> u64 {
    let mut z = seed.wrapping_add(x.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct IoConfig {
    /// Number of I/O service threads.
    pub threads: usize,
    /// Injected latency per physical read, microseconds (0 = off).
    pub io_delay_us: u64,
    /// Maximum pages per merged run (bounds single-pread size).
    pub max_run_pages: usize,
    /// Seeded fault injection (latency jitter, completion reordering,
    /// transient errors) — test harness only, `None` in production.
    pub fault: Option<FaultPlan>,
}

impl Default for IoConfig {
    fn default() -> Self {
        IoConfig { threads: 4, io_delay_us: 0, max_run_pages: 256, fault: None }
    }
}

/// One coalesced read: pages `[start_page, start_page + npages)`.
pub(crate) struct RunRequest {
    pub file: Arc<File>,
    pub file_len: u64,
    pub start_page: u64,
    pub npages: usize,
    /// The owning file's path tag — error messages name it, and the
    /// fault plan's permanent injection matches on it.
    pub tag: Arc<str>,
    pub reply: Sender<RunReply>,
}

/// Completed run: one shared buffer holding every page contiguously.
///
/// This is the zero-copy pivot of the fetch path: the pool allocates
/// **once** per coalesced run (up to `max_run_pages` pages), and the
/// cache, the range assembler and the decoder all work through
/// [`PageRef`] views into this buffer — a 256-page run that used to cost
/// 256 page allocations plus copies now costs one allocation and zero
/// copies.
pub(crate) struct RunReply {
    pub start_page: u64,
    /// Pages in the run; `buf.len() == npages * PAGE_SIZE`.
    pub npages: usize,
    /// The run buffer. The tail past `bytes_read` is EOF zero padding.
    /// Empty (not page-sized) when `error` is set — an errored reply's
    /// pages must never be used or cached.
    pub buf: Arc<[u8]>,
    /// Bytes actually read from disk (0 for a fully-past-EOF run).
    pub bytes_read: u64,
    /// Set when the run failed after the pool's retry policy was
    /// exhausted (or immediately, for permanent errors). The pool never
    /// panics on a read failure: the caller decides the blast radius.
    pub error: Option<IoError>,
}

impl RunReply {
    /// Zero-copy view of page `i` of the run.
    #[inline]
    pub fn page(&self, i: usize) -> PageRef {
        PageRef::new(self.buf.clone(), i * PAGE_SIZE)
    }
}

struct Queue {
    q: Mutex<VecDeque<(u64, RunRequest)>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Monotonic request ids, assigned at submit (fault-plan decisions
    /// key off these).
    next_id: AtomicU64,
    /// Seeded pop counter for reordered servicing.
    pops: AtomicU64,
    /// Pages submitted but not yet serviced — the overlap gauge.
    in_flight_pages: AtomicU64,
    /// High-water mark of `in_flight_pages`.
    peak_in_flight: AtomicU64,
}

/// Asynchronous I/O thread pool.
pub struct IoPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
    cfg: IoConfig,
    stats: Arc<IoStats>,
}

impl IoPool {
    /// Spawn the pool.
    pub fn new(cfg: IoConfig, stats: Arc<IoStats>) -> Self {
        let queue = Arc::new(Queue {
            q: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            pops: AtomicU64::new(0),
            in_flight_pages: AtomicU64::new(0),
            peak_in_flight: AtomicU64::new(0),
        });
        let workers = (0..cfg.threads.max(1))
            .map(|i| {
                let queue = queue.clone();
                let stats = stats.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("safs-io-{i}"))
                    .spawn(move || Self::worker_loop(queue, stats, cfg))
                    .expect("spawn io worker")
            })
            .collect();
        IoPool { queue, workers, cfg, stats }
    }

    /// Submit one coalesced run. The reply arrives on `req.reply`.
    pub(crate) fn submit(&self, req: RunRequest) {
        let pages = req.npages as u64;
        let now =
            self.queue.in_flight_pages.fetch_add(pages, Ordering::Relaxed) + pages;
        self.queue.peak_in_flight.fetch_max(now, Ordering::Relaxed);
        let id = self.queue.next_id.fetch_add(1, Ordering::Relaxed);
        let mut q = self.queue.q.lock().unwrap();
        q.push_back((id, req));
        drop(q);
        self.queue.cv.notify_one();
    }

    /// Pool configuration.
    pub fn config(&self) -> &IoConfig {
        &self.cfg
    }

    /// Stats handle.
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    /// Pages currently submitted but not yet serviced.
    pub fn in_flight_pages(&self) -> u64 {
        self.queue.in_flight_pages.load(Ordering::Relaxed)
    }

    /// High-water mark of [`Self::in_flight_pages`] over the pool's life
    /// — what the admission in-flight window charge bounds.
    pub fn peak_in_flight_pages(&self) -> u64 {
        self.queue.peak_in_flight.load(Ordering::Relaxed)
    }

    fn worker_loop(queue: Arc<Queue>, stats: Arc<IoStats>, cfg: IoConfig) {
        let reorder = cfg.fault.as_ref().filter(|p| p.reorder).map(|p| p.seed);
        loop {
            let (id, req) = {
                let mut q = queue.q.lock().unwrap();
                loop {
                    // reordered completions: a seeded coin per pop picks
                    // the queue's front or back, so runs complete out of
                    // submission order deterministically for a fixed
                    // sequence of pops
                    let next = match reorder {
                        Some(seed) if q.len() > 1 => {
                            let k = queue.pops.fetch_add(1, Ordering::Relaxed);
                            if mix(seed, k) & 1 == 0 {
                                q.pop_front()
                            } else {
                                q.pop_back()
                            }
                        }
                        _ => q.pop_front(),
                    };
                    if let Some(r) = next {
                        break r;
                    }
                    if queue.shutdown.load(Ordering::Acquire) {
                        return;
                    }
                    q = queue.cv.wait(q).unwrap();
                }
            };
            let reply = Self::service(&req, id, &stats, &cfg);
            // receiver may have gone away (caller panicked); ignore.
            let _ = req.reply.send(reply);
            queue.in_flight_pages.fetch_sub(req.npages as u64, Ordering::Relaxed);
        }
    }

    /// Execute one run: a single pread into one shared buffer covering
    /// all pages, zero-padded at EOF.
    ///
    /// Stats count what actually happened: `bytes_read` is the byte
    /// count the pread returned (not the padded run size), and a run
    /// lying entirely past EOF performs no pread, pays no injected
    /// latency and moves no counters.
    ///
    /// Read errors never panic the pool thread. `Interrupted` is a free
    /// in-place retry (uncounted, as always). Transient errors —
    /// `WouldBlock`, `TimedOut`, raw `EIO` — are retried up to
    /// [`MAX_ATTEMPTS`] times under exponential backoff
    /// ([`BACKOFF_BASE_US`] doubling to [`BACKOFF_CAP_US`]) with
    /// deterministic jitter keyed off the fault-plan seed and
    /// `(req_id, attempt)`, so chaos runs replay bit-identically.
    /// Everything else — and transient exhaustion — produces an error
    /// reply the fetch path turns into a clean per-job failure.
    fn service(req: &RunRequest, req_id: u64, stats: &IoStats, cfg: &IoConfig) -> RunReply {
        let offset = req.start_page * PAGE_SIZE as u64;
        let want = req.npages * PAGE_SIZE;
        let avail = (req.file_len.saturating_sub(offset) as usize).min(want);
        let mut inject_eio = false;
        let mut inject_flip = false;
        let mut delay_us = cfg.io_delay_us;
        let mut seed = 0u64;
        if let Some(plan) = &cfg.fault {
            seed = plan.seed;
            if avail > 0 {
                if let Some(fp) = &plan.fail_path {
                    if req.tag.contains(&**fp) {
                        // injected permanent fault: fail immediately,
                        // no retries, no backoff
                        stats.add_permanent_error(1);
                        return Self::error_reply(
                            req,
                            IoError::permanent(format!(
                                "injected permanent I/O failure on {}",
                                req.tag
                            )),
                        );
                    }
                }
                if plan.jitter_us > 0 {
                    // per-request latency jitter in 0..=jitter_us
                    delay_us += mix(plan.seed, req_id) % (plan.jitter_us + 1);
                }
                // transient EIO consuming exactly the first attempt: the
                // backoff policy re-issues the pread, which succeeds
                // deterministically on attempt 1
                inject_eio =
                    plan.eio_period > 0 && req_id % plan.eio_period == plan.eio_period - 1;
                // silent single-bit corruption of the read payload: the
                // pread succeeds, the reply carries wrong bytes, and only
                // checksum verification downstream can tell
                inject_flip = plan.flip_period > 0
                    && req_id % plan.flip_period == plan.flip_period - 1
                    && plan.flip_path.as_ref().map_or(true, |p| req.tag.contains(&**p));
            }
        }
        // single run buffer; the TrustedLen collect writes it in place
        let mut buf: Arc<[u8]> = (0..want).map(|_| 0u8).collect();
        let mut done = 0;
        if avail > 0 {
            let t0 = std::time::Instant::now();
            let dst = Arc::get_mut(&mut buf).expect("fresh run buffer is uniquely owned");
            let mut attempt = 0u64;
            loop {
                let res: std::io::Result<()> = if inject_eio && attempt == 0 {
                    Err(std::io::Error::from_raw_os_error(5))
                } else {
                    loop {
                        if done >= avail {
                            break Ok(());
                        }
                        match req.file.read_at(&mut dst[done..avail], offset + done as u64) {
                            Ok(0) => break Ok(()),
                            Ok(n) => done += n,
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                            Err(e) => break Err(e),
                        }
                    }
                };
                match res {
                    Ok(()) => break,
                    Err(e) => {
                        if classify(&e) == IoErrorClass::Transient {
                            stats.add_transient_error(1);
                            if attempt + 1 < MAX_ATTEMPTS {
                                stats.add_retry(1);
                                // exponential backoff with deterministic
                                // jitter in 0..=base/2 (partial progress
                                // from the failed attempt is kept)
                                let base =
                                    (BACKOFF_BASE_US << attempt.min(16)).min(BACKOFF_CAP_US);
                                let wait =
                                    base + mix(seed, req_id * 8 + attempt) % (base / 2 + 1);
                                std::thread::sleep(std::time::Duration::from_micros(wait));
                                stats.add_backoff(wait);
                                attempt += 1;
                                continue;
                            }
                            stats.add_permanent_error(1);
                            return Self::error_reply(
                                req,
                                IoError {
                                    class: IoErrorClass::Transient,
                                    message: format!(
                                        "transient I/O error persisted after {MAX_ATTEMPTS} \
                                         attempts on {}: {e}",
                                        req.tag
                                    ),
                                    page: None,
                                },
                            );
                        }
                        stats.add_permanent_error(1);
                        return Self::error_reply(
                            req,
                            IoError::permanent(format!(
                                "permanent I/O error on {}: {e}",
                                req.tag
                            )),
                        );
                    }
                }
            }
            if inject_flip && done > 0 {
                // flip exactly one seeded bit of what was actually read;
                // the salt keeps the choice independent of the jitter and
                // backoff draws for the same request
                let bit = mix(seed, req_id * 8 + 7) % (done as u64 * 8);
                dst[(bit / 8) as usize] ^= 1 << (bit % 8);
            }
            if delay_us > 0 {
                // emulate SSD access latency per physical request
                std::thread::sleep(std::time::Duration::from_micros(delay_us));
            }
            stats.add_physical_read(1);
            stats.add_bytes_read(done as u64);
            // latency includes the injected delay so figure runs show
            // the emulated SSD cost; EOF-only runs record nothing
            stats.pread_latency_us.record(t0.elapsed().as_micros() as u64);
            stats.run_pages.record(req.npages as u64);
        }
        RunReply {
            start_page: req.start_page,
            npages: req.npages,
            buf,
            bytes_read: done as u64,
            error: None,
        }
    }

    /// Reply for a failed run: empty buffer (never cacheable), zero
    /// bytes, and the typed error for the fetch path to propagate.
    fn error_reply(req: &RunRequest, error: IoError) -> RunReply {
        RunReply {
            start_page: req.start_page,
            npages: req.npages,
            buf: Arc::from(Vec::new().into_boxed_slice()),
            bytes_read: 0,
            error: Some(error),
        }
    }
}

impl Drop for IoPool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::Release);
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Coalesce a sorted, deduped page list into runs of at most
/// `max_run_pages` consecutive pages. Returns `(start_page, npages)` runs.
pub fn coalesce(pages: &[u64], max_run_pages: usize) -> Vec<(u64, usize)> {
    let mut runs = Vec::new();
    let mut i = 0;
    while i < pages.len() {
        let start = pages[i];
        let mut n = 1usize;
        while i + n < pages.len() && pages[i + n] == start + n as u64 && n < max_run_pages {
            n += 1;
        }
        runs.push((start, n));
        i += n;
    }
    runs
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::sync::mpsc::channel;

    #[test]
    fn coalesce_runs() {
        assert_eq!(coalesce(&[], 16), vec![]);
        assert_eq!(coalesce(&[5], 16), vec![(5, 1)]);
        assert_eq!(coalesce(&[1, 2, 3, 7, 8, 20], 16), vec![(1, 3), (7, 2), (20, 1)]);
        // run splitting at max_run_pages
        assert_eq!(coalesce(&[0, 1, 2, 3], 2), vec![(0, 2), (2, 2)]);
    }

    fn temp_file(bytes: &[u8]) -> (std::path::PathBuf, Arc<File>) {
        let path = std::env::temp_dir().join(format!(
            "graphyti-io-test-{}-{:x}",
            std::process::id(),
            bytes.as_ptr() as usize
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(bytes).unwrap();
        f.sync_all().unwrap();
        (path.clone(), Arc::new(File::open(&path).unwrap()))
    }

    #[test]
    fn pool_reads_pages_and_pads_eof() {
        // 1.5 pages of data
        let mut data = vec![0u8; PAGE_SIZE + PAGE_SIZE / 2];
        for (i, b) in data.iter_mut().enumerate() {
            *b = (i % 251) as u8;
        }
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(IoConfig { threads: 2, ..Default::default() }, stats.clone());
        let (tx, rx) = channel();
        pool.submit(RunRequest {
            file: file.clone(),
            file_len: data.len() as u64,
            start_page: 0,
            npages: 2,
            tag: Arc::from("io-test"),
            reply: tx,
        });
        let reply = rx.recv().unwrap();
        assert_eq!(reply.npages, 2);
        assert_eq!(reply.buf.len(), 2 * PAGE_SIZE);
        assert_eq!(&reply.page(0)[..], &data[..PAGE_SIZE]);
        assert_eq!(&reply.page(1)[..PAGE_SIZE / 2], &data[PAGE_SIZE..]);
        assert!(reply.page(1)[PAGE_SIZE / 2..].iter().all(|&b| b == 0), "EOF padding");
        let s = stats.snapshot();
        assert_eq!(s.physical_reads, 1);
        // stats count the bytes the disk produced, not the padded run
        assert_eq!(s.bytes_read, data.len() as u64);
        assert_eq!(reply.bytes_read, data.len() as u64);
        assert_eq!(s.latency.pread.count, 1, "one pread, one latency sample");
        assert_eq!(s.latency.run_pages.count, 1);
        assert!(s.latency.run_pages.p50 >= 2, "2-page run: {:?}", s.latency.run_pages);
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fully_past_eof_run_skips_the_read_and_the_stats() {
        // 1 page of data; request pages [8, 10): nothing to read
        let data = vec![3u8; PAGE_SIZE];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        // huge delay would show up in the test's runtime if the skipped
        // pread still paid it
        let pool = IoPool::new(
            IoConfig { threads: 1, io_delay_us: 200_000, ..Default::default() },
            stats.clone(),
        );
        let (tx, rx) = channel();
        let t = std::time::Instant::now();
        pool.submit(RunRequest {
            file,
            file_len: data.len() as u64,
            start_page: 8,
            npages: 2,
            tag: Arc::from("io-test"),
            reply: tx,
        });
        let reply = rx.recv().unwrap();
        assert!(t.elapsed() < std::time::Duration::from_millis(150), "no delay for no read");
        assert_eq!(reply.bytes_read, 0);
        assert!(reply.buf.iter().all(|&b| b == 0), "pure padding");
        let s = stats.snapshot();
        assert_eq!(s.physical_reads, 0, "no pread happened: {s:?}");
        assert_eq!(s.bytes_read, 0, "no bytes moved: {s:?}");
        assert_eq!(s.latency.pread.count, 0, "EOF-only runs record no latency");
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn pool_services_many_runs_concurrently() {
        let data = vec![7u8; PAGE_SIZE * 64];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(IoConfig { threads: 4, ..Default::default() }, stats.clone());
        let (tx, rx) = channel();
        for p in 0..64u64 {
            pool.submit(RunRequest {
                file: file.clone(),
                file_len: data.len() as u64,
                start_page: p,
                npages: 1,
                tag: Arc::from("io-test"),
                reply: tx.clone(),
            });
        }
        drop(tx);
        let mut got = 0;
        while let Ok(r) = rx.recv() {
            assert_eq!(r.npages, 1);
            assert!(r.page(0).iter().all(|&b| b == 7));
            got += 1;
        }
        assert_eq!(got, 64);
        assert_eq!(stats.snapshot().physical_reads, 64);
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn latency_injection_slows_reads() {
        let data = vec![1u8; PAGE_SIZE * 8];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(
            IoConfig { threads: 1, io_delay_us: 2000, ..Default::default() },
            stats,
        );
        let (tx, rx) = channel();
        let t = std::time::Instant::now();
        for p in 0..4u64 {
            pool.submit(RunRequest {
                file: file.clone(),
                file_len: data.len() as u64,
                start_page: p,
                npages: 1,
                tag: Arc::from("io-test"),
                reply: tx.clone(),
            });
        }
        drop(tx);
        while rx.recv().is_ok() {}
        assert!(
            t.elapsed() >= std::time::Duration::from_millis(8),
            "4 serial reads at 2ms injected latency must take >= 8ms"
        );
        drop(pool);
        let _ = std::fs::remove_file(path);
    }

    /// Submit `n` single-page runs through a pool with `cfg`, drain all
    /// replies, and return `(page_checksums_in_completion_order, stats)`.
    fn run_faulted(
        n: u64,
        cfg: IoConfig,
        data: &[u8],
        file: &Arc<File>,
    ) -> (Vec<u64>, IoStatsSnapshotPair) {
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(cfg, stats.clone());
        let (tx, rx) = channel();
        for p in 0..n {
            pool.submit(RunRequest {
                file: file.clone(),
                file_len: data.len() as u64,
                start_page: p,
                npages: 1,
                tag: Arc::from("io-test"),
                reply: tx.clone(),
            });
        }
        drop(tx);
        let mut order = Vec::new();
        while let Ok(r) = rx.recv() {
            order.push(r.start_page);
        }
        let peak = pool.peak_in_flight_pages();
        let gauge = pool.in_flight_pages();
        drop(pool);
        (order, IoStatsSnapshotPair { snap: stats.snapshot(), peak, gauge })
    }

    struct IoStatsSnapshotPair {
        snap: crate::safs::IoStatsSnapshot,
        peak: u64,
        gauge: u64,
    }

    #[test]
    fn fault_plan_is_deterministic_and_counts_retries() {
        let data = vec![9u8; PAGE_SIZE * 32];
        let (path, file) = temp_file(&data);
        let cfg = IoConfig {
            threads: 1,
            fault: Some(FaultPlan {
                seed: 0xFEED,
                jitter_us: 50,
                reorder: true,
                eio_period: 5,
                fail_path: None,
                flip_period: 0,
                flip_path: None,
            }),
            ..Default::default()
        };
        let (order_a, a) = run_faulted(32, cfg.clone(), &data, &file);
        let (order_b, b) = run_faulted(32, cfg, &data, &file);
        // every run completes exactly once despite reordering
        let mut sorted = order_a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..32u64).collect::<Vec<_>>());
        assert_eq!(order_a.len(), order_b.len());
        // fault decisions key off the submit-assigned request id, so the
        // counters replay identically even though completion order may
        // shift with how submits race the pool thread's pops
        assert_eq!(a.snap.physical_reads, b.snap.physical_reads);
        assert_eq!(a.snap.bytes_read, b.snap.bytes_read);
        assert_eq!(a.snap.retries, b.snap.retries);
        // request ids 4, 9, 14, 19, 24, 29 hit the eio_period=5 fault
        assert_eq!(a.snap.retries, 6, "{:?}", a.snap);
        // each injected fault is one transient error and one backoff
        // wait; none escalates to permanent (the retry clears it)
        assert_eq!(a.snap.transient_errors, 6, "{:?}", a.snap);
        assert_eq!(a.snap.backoff_waits, 6, "{:?}", a.snap);
        assert_eq!(a.snap.backoff_us, b.snap.backoff_us, "backoff jitter is seeded");
        assert!(a.snap.backoff_us >= 6 * 100, "base wait is 100us per retry");
        assert_eq!(a.snap.permanent_errors, 0, "{:?}", a.snap);
        assert!(a.peak >= 1 && a.peak <= 32, "peak gauge {}", a.peak);
        assert_eq!(a.gauge, 0, "all in-flight pages drained");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn reordered_completions_shuffle_submission_order() {
        let data = vec![4u8; PAGE_SIZE * 64];
        let (path, file) = temp_file(&data);
        // the 2ms injected delay keeps the single pool thread busy long
        // enough that all 64 submits land before the queue drains, so the
        // seeded front/back coin sees a deep queue and must deviate from
        // submission order somewhere in ~62 flips
        let cfg = IoConfig {
            threads: 1,
            io_delay_us: 2000,
            fault: Some(FaultPlan {
                seed: 1,
                jitter_us: 0,
                reorder: true,
                eio_period: 0,
                fail_path: None,
                flip_period: 0,
                flip_path: None,
            }),
            ..Default::default()
        };
        let (order, s) = run_faulted(64, cfg, &data, &file);
        assert_ne!(order, (0..64u64).collect::<Vec<_>>(), "plan never reordered");
        assert_eq!(s.snap.retries, 0, "no errors in a reorder-only plan");
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bit_flip_injection_corrupts_exactly_one_seeded_bit() {
        let data = vec![0x5Au8; PAGE_SIZE * 6];
        let (path, file) = temp_file(&data);
        let flip_cfg = |flip_path: Option<Arc<str>>| IoConfig {
            threads: 1,
            fault: Some(FaultPlan {
                seed: 0xC0FFEE,
                jitter_us: 0,
                reorder: false,
                eio_period: 0,
                fail_path: None,
                flip_period: 3,
                flip_path,
            }),
            ..Default::default()
        };
        let collect = |cfg: IoConfig, tag: &str| {
            let stats = Arc::new(IoStats::new());
            let pool = IoPool::new(cfg, stats);
            let (tx, rx) = channel();
            for p in 0..6u64 {
                pool.submit(RunRequest {
                    file: file.clone(),
                    file_len: data.len() as u64,
                    start_page: p,
                    npages: 1,
                    tag: Arc::from(tag),
                    reply: tx.clone(),
                });
            }
            drop(tx);
            let mut pages = vec![Vec::new(); 6];
            while let Ok(r) = rx.recv() {
                assert!(r.error.is_none(), "flips are silent, never error replies");
                pages[r.start_page as usize] = r.page(0).to_vec();
            }
            pages
        };
        let a = collect(flip_cfg(None), "flip-test.gy-adj");
        let b = collect(flip_cfg(None), "flip-test.gy-adj");
        assert_eq!(a, b, "flip choice is seeded and replays bit-identically");
        for (p, got) in a.iter().enumerate() {
            let wrong: usize = got
                .iter()
                .zip(data[p * PAGE_SIZE..(p + 1) * PAGE_SIZE].iter())
                .map(|(x, y)| (x ^ y).count_ones() as usize)
                .sum();
            // request ids 2 and 5 hit flip_period=3
            let expect = usize::from(p == 2 || p == 5);
            assert_eq!(wrong, expect, "page {p}: {wrong} flipped bits");
        }
        // a non-matching path filter suppresses every flip
        let c = collect(flip_cfg(Some(Arc::from("gy-idx"))), "flip-test.gy-adj");
        for (p, got) in c.iter().enumerate() {
            assert_eq!(got[..], data[p * PAGE_SIZE..(p + 1) * PAGE_SIZE], "page {p}");
        }
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn permanent_injection_fails_matching_requests_cleanly() {
        let data = vec![8u8; PAGE_SIZE * 4];
        let (path, file) = temp_file(&data);
        let stats = Arc::new(IoStats::new());
        let pool = IoPool::new(
            IoConfig {
                threads: 1,
                fault: Some(FaultPlan {
                    seed: 2,
                    jitter_us: 0,
                    reorder: false,
                    eio_period: 0,
                    fail_path: Some(Arc::from("bad-image")),
                    flip_period: 0,
                    flip_path: None,
                }),
                ..Default::default()
            },
            stats.clone(),
        );
        let (tx, rx) = channel();
        pool.submit(RunRequest {
            file: file.clone(),
            file_len: data.len() as u64,
            start_page: 0,
            npages: 1,
            tag: Arc::from("/graphs/bad-image/edges"),
            reply: tx.clone(),
        });
        let bad = rx.recv().unwrap();
        let err = bad.error.expect("matching tag must fail");
        assert_eq!(err.class, IoErrorClass::Permanent);
        assert!(err.message.contains("bad-image"), "{}", err.message);
        assert_eq!(bad.bytes_read, 0);
        assert!(bad.buf.is_empty(), "errored replies carry no usable pages");
        // a non-matching tag on the same pool is untouched
        pool.submit(RunRequest {
            file,
            file_len: data.len() as u64,
            start_page: 0,
            npages: 1,
            tag: Arc::from("/graphs/good-image/edges"),
            reply: tx,
        });
        let good = rx.recv().unwrap();
        assert!(good.error.is_none());
        assert_eq!(good.bytes_read, PAGE_SIZE as u64);
        let s = stats.snapshot();
        assert_eq!(s.permanent_errors, 1, "{s:?}");
        assert_eq!(s.retries, 0, "permanent faults are not retried");
        assert_eq!(s.physical_reads, 1, "only the healthy request touched disk");
        drop(pool);
        let _ = std::fs::remove_file(path);
    }
}
