//! `benchcheck` — compare a current `BENCH_<fig>.json` against a
//! committed baseline and exit non-zero on a regression.
//!
//! Usage: `benchcheck BASELINE.json CURRENT.json [--wall-tolerance F]`
//!
//! Policy (see `docs/METRICS.md`): wall time may regress up to the
//! tolerance (default 15%, machine noise); `bytes_read` may not grow at
//! all (read volume is deterministic for a fixed image + cache size).
//! A baseline with no rows — the bootstrap placeholder committed before
//! any toolchain has produced real numbers — passes with a note.

use std::process::ExitCode;

use graphyti::coordinator::benchkit::bench_compare;
use graphyti::util::Json;

fn load(path: &str) -> graphyti::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("read {path}: {e}"))?;
    Json::parse(&text)
}

fn run() -> graphyti::Result<bool> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    anyhow::ensure!(
        args.len() >= 2,
        "usage: benchcheck BASELINE.json CURRENT.json [--wall-tolerance F]"
    );
    let mut tolerance = 0.15;
    if let Some(i) = args.iter().position(|a| a == "--wall-tolerance") {
        let v = args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--wall-tolerance needs a value"))?;
        tolerance = v.parse()?;
    }
    let baseline = load(&args[0])?;
    let current = load(&args[1])?;
    let fig = current.get("fig").and_then(Json::as_str).unwrap_or("?");
    let check = bench_compare(&baseline, &current, tolerance);
    println!("benchcheck {fig}: {} (wall tolerance {:.0}%)",
        if check.ok { "PASS" } else { "FAIL" },
        tolerance * 100.0
    );
    for note in &check.notes {
        println!("  {note}");
    }
    Ok(check.ok)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("benchcheck error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
