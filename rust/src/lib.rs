//! # Graphyti — a semi-external-memory graph library
//!
//! Reproduction of *"Graphyti: A Semi-External Memory Graph Library for
//! FlashGraph"* (Mhembere et al., 2019) as a three-layer Rust + JAX +
//! Pallas stack. See `DESIGN.md` for the full system inventory and the
//! experiment index.
//!
//! Layering:
//! * [`safs`] — userspace SEM storage substrate (page cache + async I/O),
//!   standing in for the paper's SAFS.
//! * [`graph`] — on-disk graph image format (v1 fixed-width / v2
//!   delta+varint compressed, see `docs/FORMAT.md`), converters,
//!   synthetic workload generators, and the in-memory CSR baseline.
//! * [`engine`] — the vertex-centric BSP engine (FlashGraph analogue):
//!   activation scheduling, multicast/point-to-point messaging over
//!   dense O(n) combiner lanes or recycled lock-free queue lanes,
//!   global barriers, asynchronous phase mode, per-iteration statistics.
//! * [`algs`] — the paper's six algorithms, each in its unoptimized and
//!   Graphyti-optimized variants, plus library extras.
//! * [`runtime`] — PJRT bridge executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) from Rust; Python never runs at
//!   request time.
//! * [`coordinator`] — config system, job runner, figure harnesses.
//! * [`service`] — the multi-tenant daemon: shared-substrate graph
//!   registry, admission control, concurrent job executor, JSON-lines
//!   TCP protocol.
//! * [`util`] — PRNG, bitmaps, shared vectors, mini bench/property-test
//!   harnesses (criterion/proptest are unavailable offline).
//!
//! ## Service mode
//!
//! Beyond one-shot CLI runs, the library hosts a **multi-tenant job
//! service** (`graphyti serve`): every on-disk graph image is opened
//! once and all jobs share a single page cache and I/O pool — the
//! scarce SEM resources — while an admission controller bounds the sum
//! of per-job O(n) vertex-state footprints by a memory budget. Jobs
//! carry priorities, can be cancelled cooperatively at engine round
//! boundaries, and report their own disjointly-attributed I/O counters.
//! Clients speak a JSON-lines TCP protocol (`graphyti submit` /
//! `status`, or any socket client). See [`service`] for the design and
//! a quickstart.

pub mod algs;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod runtime;
pub mod safs;
pub mod service;
pub mod util;

/// Vertex identifier. Graph images are limited to `u32::MAX` vertices,
/// matching FlashGraph's compact on-disk layout.
pub type VertexId = u32;

/// Library-wide result type.
pub type Result<T> = anyhow::Result<T>;
