//! # Graphyti — a semi-external-memory graph library
//!
//! Reproduction of *"Graphyti: A Semi-External Memory Graph Library for
//! FlashGraph"* (Mhembere et al., 2019) as a three-layer Rust + JAX +
//! Pallas stack. See `DESIGN.md` for the full system inventory and the
//! experiment index.
//!
//! Layering:
//! * [`safs`] — userspace SEM storage substrate (page cache + async I/O),
//!   standing in for the paper's SAFS.
//! * [`graph`] — on-disk graph image format, converters, synthetic
//!   workload generators, and the in-memory CSR baseline.
//! * [`engine`] — the vertex-centric BSP engine (FlashGraph analogue):
//!   activation scheduling, multicast/point-to-point messaging, global
//!   barriers, asynchronous phase mode, per-iteration statistics.
//! * [`algs`] — the paper's six algorithms, each in its unoptimized and
//!   Graphyti-optimized variants, plus library extras.
//! * [`runtime`] — PJRT bridge executing the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) from Rust; Python never runs at
//!   request time.
//! * [`coordinator`] — config system, job runner, figure harnesses.
//! * [`util`] — PRNG, bitmaps, shared vectors, mini bench/property-test
//!   harnesses (criterion/proptest are unavailable offline).

pub mod algs;
pub mod coordinator;
pub mod engine;
pub mod graph;
pub mod runtime;
pub mod safs;
pub mod util;

/// Vertex identifier. Graph images are limited to `u32::MAX` vertices,
/// matching FlashGraph's compact on-disk layout.
pub type VertexId = u32;

/// Library-wide result type.
pub type Result<T> = anyhow::Result<T>;
