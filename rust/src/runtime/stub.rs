//! API-compatible stand-in for [`super::executor`] when the `xla`
//! feature is disabled (the offline default).
//!
//! Every constructor returns an error explaining how to enable the real
//! runtime; the remaining methods exist only so downstream code
//! type-checks and are unreachable without a constructed runtime.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::bail;

use crate::graph::csr::Csr;
use crate::VertexId;

const UNAVAILABLE: &str = "the XLA/PJRT runtime is unavailable: graphyti was built without the \
     `xla` cargo feature (it requires the xla bindings crate and libxla_extension, \
     which are not vendored in the offline image)";

/// Locate the artifacts directory: `$GRAPHYTI_ARTIFACTS`, else
/// `./artifacts`, else `<exe>/../../artifacts` (target/release layout).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("GRAPHYTI_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let local = PathBuf::from("artifacts");
    if local.is_dir() {
        return local;
    }
    if let Ok(exe) = std::env::current_exe() {
        for anc in exe.ancestors().skip(1) {
            let cand = anc.join("artifacts");
            if cand.is_dir() {
                return cand;
            }
        }
    }
    local
}

/// Stub PJRT client; construction always fails.
pub struct XlaRuntime {
    _priv: (),
}

impl XlaRuntime {
    /// Always errors: the `xla` feature is disabled.
    pub fn new() -> crate::Result<Self> {
        bail!("{UNAVAILABLE}");
    }

    /// Always errors: the `xla` feature is disabled.
    pub fn with_dir(_dir: &Path) -> crate::Result<Self> {
        bail!("{UNAVAILABLE}");
    }

    /// Platform name placeholder.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }
}

/// Stub dense-block PageRank engine.
pub struct PageRankXla {
    _rt: Arc<XlaRuntime>,
}

impl PageRankXla {
    /// Wrap a runtime (unreachable without the `xla` feature).
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        PageRankXla { _rt: rt }
    }

    /// Smallest artifact size that fits `n` vertices (mirrors the real
    /// executor so size logic stays testable without the runtime).
    pub fn padded_size(n: usize) -> Option<usize> {
        [256usize, 512].into_iter().find(|&s| s >= n)
    }

    /// Always errors: the `xla` feature is disabled.
    pub fn pagerank(&self, _g: &Csr, _alpha: f32, _iters: usize) -> crate::Result<Vec<f64>> {
        bail!("{UNAVAILABLE}");
    }
}

/// Stub Louvain modularity scorer.
pub struct ModularityXla {
    _rt: Arc<XlaRuntime>,
}

impl ModularityXla {
    /// Wrap a runtime (unreachable without the `xla` feature).
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        ModularityXla { _rt: rt }
    }

    /// Always errors: the `xla` feature is disabled.
    pub fn score(&self, _g: &Csr, _community: &[VertexId]) -> crate::Result<f64> {
        bail!("{UNAVAILABLE}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_unavailable() {
        let e = XlaRuntime::new().err().expect("stub must fail");
        assert!(format!("{e}").contains("xla"), "{e}");
    }

    #[test]
    fn padded_sizes_match_real_executor() {
        assert_eq!(PageRankXla::padded_size(100), Some(256));
        assert_eq!(PageRankXla::padded_size(256), Some(256));
        assert_eq!(PageRankXla::padded_size(300), Some(512));
        assert_eq!(PageRankXla::padded_size(1000), None);
    }
}
