//! The XLA/PJRT runtime — executing the AOT-compiled JAX/Pallas
//! artifacts from Rust.
//!
//! Python runs **once**, at build time: `make artifacts` lowers the
//! Layer-2 JAX model (which calls the Layer-1 Pallas kernel) to HLO text
//! under `artifacts/`. At run time this module loads the text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes it — no Python anywhere on the request path.
//!
//! Two computations are hosted:
//! * [`PageRankXla`] — a dense-block damped power-iteration step, used as
//!   the numeric *verification engine* for the SEM PageRank
//!   implementations (`graphyti verify`, `examples/xla_pagerank.rs`).
//! * [`ModularityXla`] — the Louvain modularity scorer used to grade
//!   community assignments.

pub mod executor;

pub use executor::{artifacts_dir, ModularityXla, PageRankXla, XlaRuntime};
