//! The XLA/PJRT runtime — executing the AOT-compiled JAX/Pallas
//! artifacts from Rust.
//!
//! Python runs **once**, at build time: `make artifacts` lowers the
//! Layer-2 JAX model (which calls the Layer-1 Pallas kernel) to HLO text
//! under `artifacts/`. At run time this module loads the text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client,
//! and executes it — no Python anywhere on the request path.
//!
//! Two computations are hosted:
//! * [`PageRankXla`] — a dense-block damped power-iteration step, used as
//!   the numeric *verification engine* for the SEM PageRank
//!   implementations (`graphyti verify`, `examples/xla_pagerank.rs`).
//! * [`ModularityXla`] — the Louvain modularity scorer used to grade
//!   community assignments.
//!
//! ## Feature gating
//!
//! The real executor needs the `xla` bindings crate and
//! `libxla_extension`, which cannot be vendored in the offline build
//! image. It is therefore gated behind the off-by-default `xla` cargo
//! feature; without it, [`stub`] supplies the same API surface and every
//! constructor reports the runtime as unavailable, so the CLI `verify`
//! subcommand and `examples/xla_pagerank.rs` compile everywhere and fail
//! gracefully at run time.

#[cfg(feature = "xla")]
pub mod executor;
#[cfg(feature = "xla")]
pub use executor::{artifacts_dir, ModularityXla, PageRankXla, XlaRuntime};

#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{artifacts_dir, ModularityXla, PageRankXla, XlaRuntime};
