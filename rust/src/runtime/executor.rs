//! PJRT executor: load HLO text artifacts, compile once, execute many.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{bail, Context};

use crate::graph::csr::Csr;
use crate::VertexId;

/// Locate the artifacts directory: `$GRAPHYTI_ARTIFACTS`, else
/// `./artifacts`, else `<exe>/../../artifacts` (target/release layout).
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("GRAPHYTI_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let local = PathBuf::from("artifacts");
    if local.is_dir() {
        return local;
    }
    if let Ok(exe) = std::env::current_exe() {
        for anc in exe.ancestors().skip(1) {
            let cand = anc.join("artifacts");
            if cand.is_dir() {
                return cand;
            }
        }
    }
    local
}

/// A PJRT CPU client with a cache of compiled artifact executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaRuntime {
    /// Create a CPU PJRT client rooted at the default artifacts dir.
    pub fn new() -> crate::Result<Self> {
        Self::with_dir(&artifacts_dir())
    }

    /// Create with an explicit artifacts directory.
    pub fn with_dir(dir: &Path) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(XlaRuntime { client, dir: dir.to_path_buf(), cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached).
    pub fn executable(
        &self,
        name: &str,
    ) -> crate::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        // HLO *text* interchange: the text parser reassigns instruction
        // ids, sidestepping the 64-bit-id protos jax >= 0.5 emits that
        // xla_extension 0.5.1 rejects (see python/compile/aot.py).
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client.compile(&comp).with_context(|| format!("compile artifact {name}"))?,
        );
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }
}

/// Supported padded sizes (one AOT artifact each — HLO has static shapes).
const PAGERANK_SIZES: [usize; 2] = [256, 512];
/// Rank-matrix lane count baked into the artifact (see model.LANES).
const LANES: usize = 8;

/// Dense-block PageRank through the AOT JAX/Pallas artifact.
pub struct PageRankXla {
    rt: std::sync::Arc<XlaRuntime>,
}

impl PageRankXla {
    /// Wrap a runtime.
    pub fn new(rt: std::sync::Arc<XlaRuntime>) -> Self {
        PageRankXla { rt }
    }

    /// Smallest artifact size that fits `n` vertices.
    pub fn padded_size(n: usize) -> Option<usize> {
        PAGERANK_SIZES.iter().copied().find(|&s| s >= n)
    }

    /// Run `iters` damped power-iteration steps on a dense operator built
    /// from `g` (n ≤ 512). Returns the rank vector — numerically
    /// equivalent to [`crate::algs::oracle::pagerank`] at convergence.
    pub fn pagerank(&self, g: &Csr, alpha: f32, iters: usize) -> crate::Result<Vec<f64>> {
        let n = g.num_vertices();
        let Some(size) = Self::padded_size(n) else {
            bail!("graph too large for dense verification: n={n} > 512");
        };
        let exe = self.rt.executable(&format!("pagerank_step_{size}"))?;

        // M[u, v] = 1/outdeg(v) for edge v->u; dangling columns zero.
        let mut m = vec![0f32; size * size];
        for v in 0..n as VertexId {
            let outs = g.out(v);
            if outs.is_empty() {
                continue;
            }
            let w = 1.0 / outs.len() as f32;
            for &u in outs {
                m[u as usize * size + v as usize] = w;
            }
        }
        // The artifact supports dangling-mass redistribution (dang[v]=1
        // for dangling v), but the library-wide convention — shared by
        // the SEM implementations and the oracle — lets dangling mass
        // decay, so the verification path passes an all-zero vector.
        let dang = vec![0f32; size];
        let mut uni = vec![0f32; size];
        uni[..n].fill(1.0 / n as f32);
        let mut r = vec![0f32; size * LANES];
        for v in 0..n {
            r[v * LANES..(v + 1) * LANES].fill(1.0 / n as f32);
        }

        let m_lit = xla::Literal::vec1(&m).reshape(&[size as i64, size as i64])?;
        let dang_lit = xla::Literal::vec1(&dang).reshape(&[size as i64, 1])?;
        let uni_lit = xla::Literal::vec1(&uni).reshape(&[size as i64, 1])?;
        let alpha_lit = xla::Literal::scalar(alpha);
        for _ in 0..iters {
            let r_lit = xla::Literal::vec1(&r).reshape(&[size as i64, LANES as i64])?;
            let out = exe.execute::<xla::Literal>(&[
                m_lit.clone(),
                r_lit,
                dang_lit.clone(),
                uni_lit.clone(),
                alpha_lit.clone(),
            ])?[0][0]
                .to_literal_sync()?;
            r = out.to_tuple1()?.to_vec::<f32>()?;
        }
        // all lanes carry the same vector; read lane 0
        Ok((0..n).map(|v| r[v * LANES] as f64).collect())
    }
}

/// Louvain modularity scoring through the AOT artifact (n ≤ 256,
/// communities ≤ 64 after dense renumbering).
pub struct ModularityXla {
    rt: std::sync::Arc<XlaRuntime>,
}

impl ModularityXla {
    /// Wrap a runtime.
    pub fn new(rt: std::sync::Arc<XlaRuntime>) -> Self {
        ModularityXla { rt }
    }

    /// Score a community assignment on an undirected graph (n ≤ 256,
    /// ≤ 64 distinct communities).
    pub fn score(&self, g: &Csr, community: &[VertexId]) -> crate::Result<f64> {
        const SIZE: usize = 256;
        const C: usize = 64;
        let n = g.num_vertices();
        if n > SIZE {
            bail!("graph too large for dense modularity: n={n} > {SIZE}");
        }
        // dense renumber communities
        let mut map = HashMap::new();
        let mut dense = Vec::with_capacity(n);
        for &c in community.iter().take(n) {
            let next = map.len() as u32;
            dense.push(*map.entry(c).or_insert(next));
        }
        if map.len() > C {
            bail!("too many communities: {} > {C}", map.len());
        }
        let mut adj = vec![0f32; SIZE * SIZE];
        for v in 0..n as VertexId {
            for &u in g.out(v) {
                adj[v as usize * SIZE + u as usize] = 1.0;
            }
        }
        let mut onehot = vec![0f32; SIZE * C];
        for (v, &c) in dense.iter().enumerate() {
            onehot[v * C + c as usize] = 1.0;
        }
        let two_m = g.num_edges() as f32;
        let exe = self.rt.executable("modularity_256")?;
        let out = exe.execute::<xla::Literal>(&[
            xla::Literal::vec1(&adj).reshape(&[SIZE as i64, SIZE as i64])?,
            xla::Literal::vec1(&onehot).reshape(&[SIZE as i64, C as i64])?,
            xla::Literal::scalar(two_m),
        ])?[0][0]
            .to_literal_sync()?;
        let q = out.to_tuple1()?.to_vec::<f32>()?;
        Ok(q[0] as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algs::oracle;
    use crate::graph::gen;
    use std::sync::Arc;

    fn runtime_or_skip() -> Option<Arc<XlaRuntime>> {
        let dir = artifacts_dir();
        if !dir.join("pagerank_step_256.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return None;
        }
        Some(Arc::new(XlaRuntime::new().expect("PJRT client")))
    }

    #[test]
    fn xla_pagerank_matches_oracle() {
        let Some(rt) = runtime_or_skip() else { return };
        let edges = gen::rmat(7, 900, 3);
        let g = Csr::from_edges(128, &edges, true);
        let want = oracle::pagerank(&g, 0.85, 60);
        let got = PageRankXla::new(rt).pagerank(&g, 0.85, 60).unwrap();
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "rank[{i}] xla {a} oracle {b}");
        }
    }

    #[test]
    fn xla_pagerank_padded_sizes() {
        let Some(rt) = runtime_or_skip() else { return };
        let pr = PageRankXla::new(rt);
        assert_eq!(PageRankXla::padded_size(100), Some(256));
        assert_eq!(PageRankXla::padded_size(256), Some(256));
        assert_eq!(PageRankXla::padded_size(300), Some(512));
        assert_eq!(PageRankXla::padded_size(1000), None);
        // size-512 artifact works too
        let edges = gen::cycle(300);
        let g = Csr::from_edges(300, &edges, true);
        let got = pr.pagerank(&g, 0.85, 30).unwrap();
        for r in &got {
            assert!((r - 1.0 / 300.0).abs() < 1e-6, "cycle PR uniform, got {r}");
        }
    }

    #[test]
    fn xla_modularity_matches_oracle() {
        let Some(rt) = runtime_or_skip() else { return };
        let edges = gen::two_cliques(8);
        let g = Csr::from_edges(16, &edges, false);
        let split: Vec<VertexId> = (0..16).map(|v| if v < 8 { 0 } else { 777 }).collect();
        let want = oracle::modularity(&g, &split);
        let got = ModularityXla::new(rt).score(&g, &split).unwrap();
        assert!((got - want).abs() < 1e-5, "xla {got} oracle {want}");
    }
}
