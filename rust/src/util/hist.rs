//! Power-of-two histogram for latency / size distributions.
//!
//! Used by the SAFS substrate to report request-size and latency
//! distributions, and by the coreness algorithm's degree distribution
//! tracker (the hybrid-messaging switchover needs a cheap running
//! distribution over remaining degrees — see `algs::coreness`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Concurrent histogram with power-of-two buckets: bucket `i` counts
/// values in `[2^i, 2^(i+1))` (bucket 0 counts 0 and 1).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// 64-bucket histogram (covers all u64 values).
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&self, v: u64) {
        let b = (64 - v.max(1).leading_zeros() as usize).saturating_sub(1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile (exact upper bound of the bucket containing
    /// quantile `q`). Bucket 0 spans [0, 2) so its bound is 1; bucket
    /// `i` in 1..63 spans [2^i, 2^(i+1)) so its bound is 2^(i+1) - 1;
    /// bucket 63 spans [2^63, u64::MAX] so its bound is u64::MAX.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Self::bucket_bound(i);
            }
        }
        u64::MAX
    }

    /// Largest value bucket `i` can hold.
    #[inline]
    fn bucket_bound(i: usize) -> u64 {
        if i == 0 {
            1
        } else if i >= 63 {
            u64::MAX
        } else {
            (1u64 << (i + 1)) - 1
        }
    }

    /// Integer summary of the distribution: count, mean, p50, p99.
    pub fn summary(&self) -> HistSummary {
        let c = self.count();
        HistSummary {
            count: c,
            mean: if c == 0 { 0 } else { self.sum.load(Ordering::Relaxed) / c },
            p50: self.quantile(0.5),
            p99: self.quantile(0.99),
        }
    }

    /// Copy another histogram's buckets into this one (additive).
    pub fn merge_from(&self, other: &Histogram) {
        for (dst, src) in self.buckets.iter().zip(other.buckets.iter()) {
            let v = src.load(Ordering::Relaxed);
            if v > 0 {
                dst.fetch_add(v, Ordering::Relaxed);
            }
        }
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// Integer snapshot of a [`Histogram`]'s shape. All fields are plain
/// `u64` so the type is `Copy + Eq` and can embed in snapshot structs
/// that are compared for equality (`mean` is the truncated integer
/// mean; quantiles are bucket upper bounds).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HistSummary {
    /// Number of recorded values.
    pub count: u64,
    /// Integer mean of recorded values (0 when empty).
    pub mean: u64,
    /// Median (upper bound of the bucket holding the 50th percentile).
    pub p50: u64,
    /// 99th percentile (bucket upper bound).
    pub p99: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_mean() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn quantile_monotone() {
        let h = Histogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn quantile_bucket_bounds_are_exact() {
        // all-zero histogram: bucket 0 spans [0,2), bound must be 1
        let h = Histogram::new();
        for _ in 0..10 {
            h.record(0);
        }
        assert_eq!(h.quantile(0.5), 1, "bucket 0 upper bound is 1, not 2");

        // all-ones: still bucket 0
        let h = Histogram::new();
        h.record(1);
        assert_eq!(h.quantile(1.0), 1);

        // values in [2^10, 2^11) report 2^11 - 1, never a power of two
        let h = Histogram::new();
        h.record(1024);
        h.record(2047);
        assert_eq!(h.quantile(1.0), 2047);
    }

    #[test]
    fn quantile_top_bucket_reports_u64_max() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(1u64 << 63);
        assert_eq!(h.quantile(0.5), u64::MAX);
        assert_eq!(h.quantile(1.0), u64::MAX);
        // a value just below 2^63 lands in bucket 62: bound 2^63 - 1
        let h = Histogram::new();
        h.record((1u64 << 63) - 1);
        assert_eq!(h.quantile(1.0), (1u64 << 63) - 1);
    }

    #[test]
    fn summary_reports_integer_stats() {
        let h = Histogram::new();
        assert_eq!(h.summary(), HistSummary::default());
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        let s = h.summary();
        assert_eq!(s.count, 4);
        assert_eq!(s.mean, 25);
        assert!(s.p50 <= s.p99);
        assert!(s.p99 >= 40 && s.p99 < 64, "p99 bucket bound: {}", s.p99);
    }

    #[test]
    fn merge_from_adds_counts() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(5);
        b.record(500);
        b.record(7);
        a.merge_from(&b);
        assert_eq!(a.count(), 3);
        assert!((a.mean() - (512.0 / 3.0)).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(100);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
