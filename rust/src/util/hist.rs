//! Power-of-two histogram for latency / size distributions.
//!
//! Used by the SAFS substrate to report request-size and latency
//! distributions, and by the coreness algorithm's degree distribution
//! tracker (the hybrid-messaging switchover needs a cheap running
//! distribution over remaining degrees — see `algs::coreness`).

use std::sync::atomic::{AtomicU64, Ordering};

/// Concurrent histogram with power-of-two buckets: bucket `i` counts
/// values in `[2^i, 2^(i+1))` (bucket 0 counts 0 and 1).
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// 64-bucket histogram (covers all u64 values).
    pub fn new() -> Self {
        Histogram {
            buckets: (0..64).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one value.
    #[inline]
    pub fn record(&self, v: u64) {
        let b = (64 - v.max(1).leading_zeros() as usize).saturating_sub(1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile (bucket upper bound containing quantile `q`).
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return 1u64 << (i + 1).min(63);
            }
        }
        u64::MAX
    }

    /// Reset all counters.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_mean() {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn quantile_monotone() {
        let h = Histogram::new();
        for v in 0..1000u64 {
            h.record(v);
        }
        assert!(h.quantile(0.1) <= h.quantile(0.5));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn reset_clears() {
        let h = Histogram::new();
        h.record(100);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
    }
}
