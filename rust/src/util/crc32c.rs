//! CRC-32C (Castagnoli) — the page-checksum primitive behind verified
//! storage (`docs/FORMAT.md` §5).
//!
//! Software table-driven implementation, dependency-free: the 256-entry
//! table is computed at compile time from the reflected Castagnoli
//! polynomial `0x82F63B78`. This is the same polynomial iSCSI, ext4 and
//! btrfs use for data integrity, chosen here for its strictly better
//! error-detection properties over CRC-32 (IEEE) on 4 KiB blocks.
//!
//! The incremental form chains: `update(update(0, a), b) == crc32c(a ++ b)`,
//! which is what the streaming image converter relies on to checksum
//! pages it never holds in memory at once.

/// Reflected CRC-32C (Castagnoli) polynomial.
const POLY: u32 = 0x82F6_3B78;

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut j = 0;
        while j < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            j += 1;
        }
        table[i] = crc;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// CRC-32C of `data`.
#[inline]
pub fn crc32c(data: &[u8]) -> u32 {
    crc32c_update(0, data)
}

/// Continue a CRC-32C over more data: `seed` is the value returned by a
/// previous [`crc32c`]/[`crc32c_update`] call over the earlier bytes.
/// `crc32c_update(0, data)` equals `crc32c(data)`.
#[inline]
pub fn crc32c_update(seed: u32, data: &[u8]) -> u32 {
    let mut crc = !seed;
    for &b in data {
        crc = TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // RFC 3720 (iSCSI) appendix test vectors
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(&[0u8; 32]), 0x8A91_36AA);
        assert_eq!(crc32c(&[0xFFu8; 32]), 0x62A8_AB43);
        assert_eq!(crc32c(b""), 0);
    }

    #[test]
    fn incremental_chaining_matches_one_shot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 31 + 7) as u8).collect();
        let whole = crc32c(&data);
        for split in [0, 1, 7, 4096, 4097, data.len()] {
            let (a, b) = data.split_at(split);
            assert_eq!(crc32c_update(crc32c(a), b), whole, "split={split}");
        }
    }

    #[test]
    fn single_bit_flip_always_detected() {
        let mut page = vec![0u8; 4096];
        for (i, b) in page.iter_mut().enumerate() {
            *b = (i * 131) as u8;
        }
        let clean = crc32c(&page);
        for bit in [0usize, 9, 8 * 100 + 3, 8 * 4095 + 7] {
            page[bit / 8] ^= 1 << (bit % 8);
            assert_ne!(crc32c(&page), clean, "flip of bit {bit} must change the crc");
            page[bit / 8] ^= 1 << (bit % 8);
        }
        assert_eq!(crc32c(&page), clean);
    }
}
