//! Deterministic xorshift128+ PRNG.
//!
//! The `rand` crate is not available offline; workload generation and the
//! property-test driver need a fast, seedable, reproducible generator.
//! xorshift128+ passes BigCrush apart from lowest-bit linearity, which is
//! irrelevant for graph sampling.

/// xorshift128+ generator.
#[derive(Debug, Clone)]
pub struct XorShift {
    s0: u64,
    s1: u64,
}

impl XorShift {
    /// Create a generator from a seed (any value; zero is re-mapped).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed so similar seeds diverge.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s0 = next().max(1);
        let s1 = next().max(1);
        XorShift { s0, s1 }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Uniform value in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply trick (Lemire); bias negligible for our bounds.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // partial Fisher–Yates over an index map for small k, else shuffle
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift::new(7);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval_and_spread() {
        let mut r = XorShift::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = XorShift::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle was identity");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = XorShift::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(d.iter().all(|&i| i < 50));
    }
}
