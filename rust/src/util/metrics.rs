//! Unified metrics registry — one schema over engine, SAFS and
//! per-job service telemetry.
//!
//! The registry itself is deliberately dumb: an ordered list of named
//! counters, gauges and histogram summaries. Producers (the service,
//! the CLI) enumerate their snapshots into it; consumers get one of
//! two renderings — a JSON object for the `{"op":"metrics"}` protocol
//! op, or Prometheus-style text exposition for scraping. Living in
//! `util` keeps the dependency direction clean: `safs` and `engine`
//! produce the numbers, this module never needs to know about them.
//!
//! Metric names may carry Prometheus-style labels inline, e.g.
//! `job_rounds{job="3",alg="pagerank"}` — the text renderer prefixes
//! and sanitizes only the part before the brace.

use crate::util::hist::HistSummary;
use crate::util::json::Json;

/// What a metric is, for the `# TYPE` exposition line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
}

/// An ordered collection of named metrics.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    scalars: Vec<(String, Kind, f64)>,
    hists: Vec<(String, HistSummary)>,
}

impl MetricsRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a monotonic counter.
    pub fn counter(&mut self, name: impl Into<String>, v: u64) {
        self.scalars.push((name.into(), Kind::Counter, v as f64));
    }

    /// Add a point-in-time gauge.
    pub fn gauge(&mut self, name: impl Into<String>, v: f64) {
        self.scalars.push((name.into(), Kind::Gauge, v));
    }

    /// Add a histogram summary.
    pub fn hist(&mut self, name: impl Into<String>, h: HistSummary) {
        self.hists.push((name.into(), h));
    }

    /// Number of registered metrics (scalars + histograms).
    pub fn len(&self) -> usize {
        self.scalars.len() + self.hists.len()
    }

    /// True when nothing is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// JSON rendering: `{"counters":{..},"gauges":{..},"histograms":
    /// {name:{count,mean,p50,p99}}}`. Non-finite gauge values encode
    /// as null (JSON has no Infinity).
    pub fn to_json(&self) -> Json {
        let pick = |want: Kind| -> Json {
            Json::Obj(
                self.scalars
                    .iter()
                    .filter(|(_, k, _)| *k == want)
                    .map(|(n, _, v)| {
                        let jv = if v.is_finite() { Json::f(*v) } else { Json::Null };
                        (n.clone(), jv)
                    })
                    .collect(),
            )
        };
        Json::obj(vec![
            ("counters", pick(Kind::Counter)),
            ("gauges", pick(Kind::Gauge)),
            (
                "histograms",
                Json::Obj(
                    self.hists
                        .iter()
                        .map(|(n, h)| {
                            (
                                n.clone(),
                                Json::obj(vec![
                                    ("count", Json::u(h.count)),
                                    ("mean", Json::u(h.mean)),
                                    ("p50", Json::u(h.p50)),
                                    ("p99", Json::u(h.p99)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Prometheus-style text exposition. Every name gets `prefix_`
    /// prepended and non-identifier characters (before any `{label}`
    /// part) replaced with `_`. Histograms render as summaries with
    /// `quantile` labels plus `_count` and `_sum` series.
    pub fn to_prometheus(&self, prefix: &str) -> String {
        let mut out = String::new();
        for (name, kind, v) in &self.scalars {
            let (base, labels) = split_labels(name);
            let full = format!("{prefix}_{}", sanitize(base));
            let kind_s = match kind {
                Kind::Counter => "counter",
                Kind::Gauge => "gauge",
            };
            out.push_str(&format!("# TYPE {full} {kind_s}\n"));
            out.push_str(&format!("{full}{labels} {}\n", fmt_value(*v)));
        }
        for (name, h) in &self.hists {
            let (base, labels) = split_labels(name);
            let full = format!("{prefix}_{}", sanitize(base));
            let extra = |q: &str| merge_labels(labels, &format!("quantile=\"{q}\""));
            out.push_str(&format!("# TYPE {full} summary\n"));
            out.push_str(&format!("{full}{} {}\n", extra("0.5"), h.p50));
            out.push_str(&format!("{full}{} {}\n", extra("0.99"), h.p99));
            out.push_str(&format!("{full}_count{labels} {}\n", h.count));
            // integer mean * count reconstructs an approximate sum
            out.push_str(&format!("{full}_sum{labels} {}\n", h.mean.saturating_mul(h.count)));
        }
        out
    }
}

/// Split `name{labels}` into (`name`, `{labels}`); labels may be empty.
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], &name[i..]),
        None => (name, ""),
    }
}

/// Merge an extra label into an existing (possibly empty) label set.
fn merge_labels(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        format!("{{{extra}}}")
    } else {
        // "{a=\"b\"}" -> "{a=\"b\",extra}"
        format!("{},{extra}}}", &labels[..labels.len() - 1])
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

fn fmt_value(v: f64) -> String {
    if !v.is_finite() {
        // Prometheus text format spells infinities this way
        if v > 0.0 { "+Inf".to_string() } else { "-Inf".to_string() }
    } else if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hist::Histogram;

    fn sample_registry() -> MetricsRegistry {
        let mut m = MetricsRegistry::new();
        m.counter("io_bytes_read", 4096);
        m.gauge("cache_occupancy", 0.5);
        m.counter("job_rounds{job=\"3\",alg=\"pagerank\"}", 12);
        let h = Histogram::new();
        h.record(100);
        h.record(300);
        m.hist("io_fetch_latency_us", h.summary());
        m
    }

    #[test]
    fn json_shape() {
        let j = sample_registry().to_json();
        assert_eq!(
            j.get("counters").unwrap().get("io_bytes_read").unwrap().as_u64(),
            Some(4096)
        );
        assert_eq!(
            j.get("gauges").unwrap().get("cache_occupancy").unwrap().as_f64(),
            Some(0.5)
        );
        let h = j.get("histograms").unwrap().get("io_fetch_latency_us").unwrap();
        assert_eq!(h.get("count").unwrap().as_u64(), Some(2));
        assert_eq!(h.get("mean").unwrap().as_u64(), Some(200));
        // round-trips through the encoder
        assert!(Json::parse(&j.encode()).is_ok());
    }

    #[test]
    fn non_finite_gauges_encode_as_null() {
        let mut m = MetricsRegistry::new();
        m.gauge("busy_ratio", f64::INFINITY);
        let j = m.to_json();
        assert_eq!(j.get("gauges").unwrap().get("busy_ratio"), Some(&Json::Null));
        assert!(m.to_prometheus("gy").contains("gy_busy_ratio +Inf\n"));
    }

    #[test]
    fn prometheus_exposition() {
        let text = sample_registry().to_prometheus("graphyti");
        assert!(text.contains("# TYPE graphyti_io_bytes_read counter\n"));
        assert!(text.contains("graphyti_io_bytes_read 4096\n"));
        assert!(text.contains("graphyti_cache_occupancy 0.5\n"));
        // labeled counter keeps its labels, sanitizes only the base
        assert!(
            text.contains("graphyti_job_rounds{job=\"3\",alg=\"pagerank\"} 12\n"),
            "{text}"
        );
        // histogram renders as a summary with quantile labels
        assert!(text.contains("graphyti_io_fetch_latency_us{quantile=\"0.5\"}"));
        assert!(text.contains("graphyti_io_fetch_latency_us_count 2\n"));
        assert!(text.contains("graphyti_io_fetch_latency_us_sum 400\n"));
    }
}
