//! Minimal JSON value type with a recursive-descent parser and encoder.
//!
//! No serde in the offline image, so this module carries a small JSON
//! implementation — enough for the service wire protocol, the metrics
//! export and the machine-readable bench baselines. It lives in `util`
//! (rather than `service::protocol`, where it started) so the
//! coordinator and benchkit can emit JSON without depending on the
//! service layer.

use anyhow::{bail, ensure};

/// A JSON value. Objects preserve insertion order (no map semantics
/// needed at this scale).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Shorthand: string value.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Shorthand: unsigned number (callers stay well under 2^53).
    pub fn u(v: u64) -> Json {
        Json::Num(v as f64)
    }

    /// Shorthand: float number.
    pub fn f(v: f64) -> Json {
        Json::Num(v)
    }

    /// Shorthand: boolean.
    pub fn b(v: bool) -> Json {
        Json::Bool(v)
    }

    /// Shorthand: object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Float accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Unsigned accessor (rejects negatives and fractions).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    /// Encode to a single-line JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.encode_into(&mut out);
        out
    }

    /// Encode with two-space indentation — for files meant to be
    /// diffed and read by humans (bench baselines, metrics dumps).
    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.encode_pretty_into(&mut out, 0);
        out.push('\n');
        out
    }

    fn encode_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => encode_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.encode_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    encode_string(k, out);
                    out.push(':');
                    v.encode_into(out);
                }
                out.push('}');
            }
        }
    }

    fn encode_pretty_into(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    v.encode_pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(depth + 1));
                    encode_string(k, out);
                    out.push_str(": ");
                    v.encode_pretty_into(out, depth + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(depth));
                out.push('}');
            }
            other => other.encode_into(out),
        }
    }
}

fn encode_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> crate::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos);
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> crate::Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> crate::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected '{}' at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => bail!("bad number '{text}' at byte {start}"),
        }
    }

    fn string(&mut self) -> crate::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("dangling escape") };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let code = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.expect(b'\\')?;
                                self.expect(b'u')?;
                                let lo = self.hex4()?;
                                ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "bad low surrogate"
                                );
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                hi
                            };
                            match char::from_u32(code) {
                                Some(ch) => out.push(ch),
                                None => bail!("invalid \\u escape {code:#x}"),
                            }
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                c if c < 0x20 => bail!("raw control byte in string"),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: width from the lead byte, then
                    // validate just that scalar (not the whole rest of
                    // the input, which would be quadratic)
                    let start = self.pos - 1;
                    let width = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => bail!("invalid UTF-8 lead byte in string"),
                    };
                    ensure!(
                        start + width <= self.bytes.len(),
                        "truncated UTF-8 in string"
                    );
                    let s = std::str::from_utf8(&self.bytes[start..start + width])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                    out.push_str(s);
                    self.pos = start + width;
                }
            }
        }
    }

    fn hex4(&mut self) -> crate::Result<u32> {
        ensure!(self.pos + 4 <= self.bytes.len(), "truncated \\u escape");
        let text = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        let v = u32::from_str_radix(text, 16)
            .map_err(|_| anyhow::anyhow!("bad \\u escape '{text}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_encode_roundtrip() {
        let text = r#"{"op":"submit","graph":"/tmp/g","num":8,"deep":[1,2.5,null,true,{"k":"v"}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("submit"));
        assert_eq!(v.get("num").unwrap().as_u64(), Some(8));
        let arr = v.get("deep").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2], Json::Null);
        // encode -> parse is stable
        let re = Json::parse(&v.encode()).unwrap();
        assert_eq!(re, v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""line\nbreak \"quoted\" tab\t uA 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("line\nbreak \"quoted\" tab\t uA \u{1F600}"));
        let enc = Json::Str("a\"b\\c\nd".to_string()).encode();
        assert_eq!(Json::parse(&enc).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("18014398509481984").unwrap().as_u64(), Some(1 << 54));
        assert_eq!(Json::u(0).encode(), "0");
        assert_eq!(Json::f(2.5).encode(), "2.5");
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    }

    #[test]
    fn pretty_encoding_roundtrips() {
        let v = Json::obj(vec![
            ("name", Json::s("fig")),
            ("rows", Json::Arr(vec![Json::u(1), Json::u(2)])),
            ("empty", Json::Obj(vec![])),
        ]);
        let pretty = v.encode_pretty();
        assert!(pretty.contains("\n  \"rows\": ["));
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}
