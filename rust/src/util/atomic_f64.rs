//! Atomic f64 (bit-cast over `AtomicU64`) — tear-free shared rank /
//! residual arrays for the PageRank family.

use std::sync::atomic::{AtomicU64, Ordering};

/// An f64 with atomic load/store/add.
#[derive(Debug, Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    /// New with initial value.
    pub fn new(v: f64) -> Self {
        AtomicF64(AtomicU64::new(v.to_bits()))
    }

    /// Atomic read.
    #[inline]
    pub fn load(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    /// Atomic write.
    #[inline]
    pub fn store(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed)
    }

    /// Atomic `+= v`; returns the new value.
    #[inline]
    pub fn fetch_add(&self, v: f64) -> f64 {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let new = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return f64::from_bits(new),
                Err(c) => cur = c,
            }
        }
    }

    /// Atomic swap; returns the previous value.
    #[inline]
    pub fn swap(&self, v: f64) -> f64 {
        f64::from_bits(self.0.swap(v.to_bits(), Ordering::Relaxed))
    }
}

/// Build a vector of atomics initialized to `init`.
pub fn atomic_f64_vec(n: usize, init: f64) -> Vec<AtomicF64> {
    (0..n).map(|_| AtomicF64::new(init)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn load_store_swap() {
        let a = AtomicF64::new(1.5);
        assert_eq!(a.load(), 1.5);
        a.store(2.5);
        assert_eq!(a.swap(0.0), 2.5);
        assert_eq!(a.load(), 0.0);
    }

    #[test]
    fn concurrent_adds_sum() {
        let a = Arc::new(AtomicF64::new(0.0));
        let mut hs = vec![];
        for _ in 0..8 {
            let a = a.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    a.fetch_add(0.5);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(a.load(), 4000.0);
    }
}
