//! Exponential wait backoff for spin loops: spin → yield → bounded park.
//!
//! Every wait loop in the library used to be a bare
//! `std::thread::yield_now()` spin — cheap when the wakeup is
//! microseconds away, but a core-burning busy loop when it is not, and
//! a scheduler-thrash machine on oversubscribed boxes. This helper
//! implements the classic three-stage ladder (the same shape HVM's
//! reducer and crossbeam's `Backoff` use):
//!
//! 1. **Spin** — a handful of `spin_loop` hints, doubling each step.
//!    Free if the condition flips within a cache-miss or two.
//! 2. **Yield** — `yield_now`, giving the holder a scheduling slot
//!    without leaving the run queue.
//! 3. **Park** — bounded `thread::sleep`, doubling from
//!    [`PARK_BASE_US`] to [`PARK_CAP_US`] — the same bounded-backoff
//!    constants shape as the I/O pool's retry ladder
//!    (`safs/io.rs`), so a stuck waiter costs microwatts, not a core.
//!
//! The caller owns the counters: [`Backoff::snooze`] reports whether the
//! step escalated past pure spinning (a **backoff event**) and how long
//! it actually parked, so the engine can fold `backoff_events` /
//! `park_ns` into [`crate::engine::stats::EngineStats`].

use std::time::{Duration, Instant};

/// Steps spent in the spin stage (2^step `spin_loop` hints each).
pub const SPIN_LIMIT: u32 = 6;
/// Steps (inclusive of the spin stage) before the ladder starts
/// parking; steps in `SPIN_LIMIT..YIELD_LIMIT` are `yield_now` calls.
pub const YIELD_LIMIT: u32 = 10;
/// First park duration; doubles per step past [`YIELD_LIMIT`].
pub const PARK_BASE_US: u64 = 50;
/// Park ceiling — a waiter never sleeps longer than this per step.
pub const PARK_CAP_US: u64 = 5_000;

/// What one [`Backoff::snooze`] step did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Snooze {
    /// The step escalated past pure spinning (yielded or parked) — the
    /// thing `backoff_events` counts.
    pub escalated: bool,
    /// Wall time spent parked (zero for spin and yield steps).
    pub parked: Duration,
}

/// One wait loop's backoff state. Create per wait site, [`reset`] after
/// every successful acquisition so the next wait starts cheap.
///
/// [`reset`]: Backoff::reset
#[derive(Debug, Default)]
pub struct Backoff {
    step: u32,
}

impl Backoff {
    /// Fresh ladder at the spin stage.
    pub fn new() -> Self {
        Backoff { step: 0 }
    }

    /// Return to the spin stage (call after the awaited condition held).
    #[inline]
    pub fn reset(&mut self) {
        self.step = 0;
    }

    /// True when the next [`snooze`](Self::snooze) would park (useful
    /// for loops that want to re-check cheap conditions before paying a
    /// sleep).
    #[inline]
    pub fn is_parking(&self) -> bool {
        self.step >= YIELD_LIMIT
    }

    /// Wait one ladder step and escalate. Returns what the step did so
    /// the caller can count events and parked time.
    pub fn snooze(&mut self) -> Snooze {
        let step = self.step;
        self.step = self.step.saturating_add(1);
        if step < SPIN_LIMIT {
            for _ in 0..(1u32 << step) {
                std::hint::spin_loop();
            }
            Snooze { escalated: false, parked: Duration::ZERO }
        } else if step < YIELD_LIMIT {
            std::thread::yield_now();
            Snooze { escalated: true, parked: Duration::ZERO }
        } else {
            let us = (PARK_BASE_US << (step - YIELD_LIMIT).min(16)).min(PARK_CAP_US);
            let t = Instant::now();
            std::thread::sleep(Duration::from_micros(us));
            Snooze { escalated: true, parked: t.elapsed() }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_escalates_spin_yield_park() {
        let mut b = Backoff::new();
        for _ in 0..SPIN_LIMIT {
            let s = b.snooze();
            assert!(!s.escalated, "spin steps are not backoff events");
            assert_eq!(s.parked, Duration::ZERO);
        }
        assert!(!b.is_parking());
        for _ in SPIN_LIMIT..YIELD_LIMIT {
            let s = b.snooze();
            assert!(s.escalated, "yield steps count as backoff events");
            assert_eq!(s.parked, Duration::ZERO, "yield never parks");
        }
        assert!(b.is_parking());
        let s = b.snooze();
        assert!(s.escalated);
        assert!(s.parked >= Duration::from_micros(PARK_BASE_US), "park must actually sleep");
    }

    #[test]
    fn park_duration_is_capped() {
        let mut b = Backoff::new();
        // drive the step counter far past the cap point
        for _ in 0..64 {
            b.step = b.step.saturating_add(1);
        }
        let t = Instant::now();
        let s = b.snooze();
        assert!(s.escalated);
        // capped at PARK_CAP_US (plus scheduler slop): well under 10x cap
        assert!(t.elapsed() < Duration::from_micros(PARK_CAP_US * 10));
    }

    #[test]
    fn reset_returns_to_spin() {
        let mut b = Backoff::new();
        for _ in 0..YIELD_LIMIT + 2 {
            b.snooze();
        }
        assert!(b.is_parking());
        b.reset();
        assert!(!b.is_parking());
        assert!(!b.snooze().escalated, "post-reset steps spin again");
    }
}
