//! Tiny property-test driver (proptest is unavailable offline).
//!
//! [`for_random_cases`] runs a property over `n` seeded cases and, on
//! failure, retries the failing seed with progressively smaller "size"
//! parameters to report the smallest reproduction it can find. Graph
//! invariant tests throughout the library are built on this.

use super::prng::XorShift;

/// Size hint handed to generators; shrunk on failure.
#[derive(Debug, Clone, Copy)]
pub struct Size(pub usize);

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop(rng, size)` over `cases` seeds at `size0`.
///
/// On failure, halves the size down to 1 looking for a smaller failing
/// case, then panics with the seed + size of the smallest failure so the
/// case can be replayed deterministically.
pub fn for_random_cases<F>(cases: usize, size0: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut XorShift, Size) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = XorShift::new(seed);
        if let Err(msg) = prop(&mut rng, Size(size0)) {
            // shrink: retry same seed with smaller sizes
            let mut smallest = (size0, msg.clone());
            let mut size = size0 / 2;
            while size >= 1 {
                let mut rng = XorShift::new(seed);
                if let Err(m) = prop(&mut rng, Size(size)) {
                    smallest = (size, m);
                }
                size /= 2;
            }
            panic!(
                "property failed (seed={seed:#x}, smallest failing size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert helper producing `PropResult`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_when_property_holds() {
        for_random_cases(20, 64, 1, |rng, size| {
            let v = rng.next_below(size.0 as u64);
            prop_assert!(v < size.0 as u64, "out of range: {v}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_and_shrinks() {
        for_random_cases(5, 128, 2, |_rng, size| {
            prop_assert!(size.0 < 4, "size {} too big", size.0);
            Ok(())
        });
    }
}
