//! Atomic bitmap used for vertex activation scheduling.
//!
//! Workers set activation bits concurrently (release ordering is not
//! required — bits are only read after a barrier), and the engine scans
//! set bits word-at-a-time when building the next frontier.

use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed-size concurrent bitmap over `len` bits.
pub struct AtomicBitmap {
    words: Vec<AtomicU64>,
    len: usize,
}

impl AtomicBitmap {
    /// All-zero bitmap covering `len` bits.
    pub fn new(len: usize) -> Self {
        let nwords = len.div_ceil(64);
        let words = (0..nwords).map(|_| AtomicU64::new(0)).collect();
        AtomicBitmap { words, len }
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no bits can be stored.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`; returns true if it was previously clear.
    #[inline]
    pub fn set(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let prev = self.words[i / 64].fetch_or(1 << (i % 64), Ordering::Relaxed);
        prev & (1 << (i % 64)) == 0
    }

    /// Clear bit `i`; returns true if it was previously set.
    #[inline]
    pub fn clear(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        let prev = self.words[i / 64].fetch_and(!(1 << (i % 64)), Ordering::Relaxed);
        prev & (1 << (i % 64)) != 0
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        self.words[i / 64].load(Ordering::Relaxed) & (1 << (i % 64)) != 0
    }

    /// Clear every bit.
    pub fn clear_all(&self) {
        for w in &self.words {
            w.store(0, Ordering::Relaxed);
        }
    }

    /// Word-level clear of exactly `[start, end)`: one `store(0)` per
    /// fully covered 64-bit word instead of a per-bit test-and-clear
    /// scan, plus one masked `fetch_and` when `end` is ragged — bits at
    /// `end` and above are preserved. `start` must be word-aligned (the
    /// scheduler's chunks are). Callers must own the span exclusively
    /// (the scheduler clears a chunk only after the claiming worker
    /// finished scanning it, and nothing sets bits in the current-round
    /// bitmap during the vertex phase).
    pub fn clear_span(&self, start: usize, end: usize) {
        debug_assert_eq!(start % 64, 0, "clear_span start must be word-aligned");
        debug_assert!(end >= start && end <= self.len);
        let first = start / 64;
        let full = end / 64; // words fully inside the span
        for w in &self.words[first..full] {
            w.store(0, Ordering::Relaxed);
        }
        if end % 64 != 0 {
            // ragged tail: clear only bits below `end` in the last word
            self.words[full].fetch_and(!0u64 << (end % 64), Ordering::Relaxed);
        }
    }

    /// Raw 64-bit word `wi` (bits `[wi*64, wi*64 + 64)`), relaxed load.
    /// Lets scanners batch-read and lets the combiner-lane delivery
    /// sweep union several bitmaps word-at-a-time.
    #[inline]
    pub fn word(&self, wi: usize) -> u64 {
        self.words[wi].load(Ordering::Relaxed)
    }

    /// Atomically clear exactly the bits of `mask` within word `wi`
    /// (other bits untouched — safe on words shared between owners).
    #[inline]
    pub fn clear_word_bits(&self, wi: usize, mask: u64) {
        self.words[wi].fetch_and(!mask, Ordering::Relaxed);
    }

    /// Population count.
    pub fn count(&self) -> usize {
        self.words
            .iter()
            .map(|w| w.load(Ordering::Relaxed).count_ones() as usize)
            .sum()
    }

    /// True if any bit is set.
    pub fn any(&self) -> bool {
        self.words.iter().any(|w| w.load(Ordering::Relaxed) != 0)
    }

    /// Iterate set bits within `[start, end)` (single-threaded scan).
    pub fn iter_set_range(&self, start: usize, end: usize) -> SetBits<'_> {
        let end = end.min(self.len);
        SetBits { bm: self, pos: start, end }
    }

    /// Iterate all set bits.
    pub fn iter_set(&self) -> SetBits<'_> {
        self.iter_set_range(0, self.len)
    }
}

/// Iterator over set bit positions.
pub struct SetBits<'a> {
    bm: &'a AtomicBitmap,
    pos: usize,
    end: usize,
}

impl Iterator for SetBits<'_> {
    type Item = usize;

    fn next(&mut self) -> Option<usize> {
        while self.pos < self.end {
            let word_idx = self.pos / 64;
            let word = self.bm.words[word_idx].load(Ordering::Relaxed);
            // mask off bits below pos within this word
            let masked = word & (!0u64 << (self.pos % 64));
            if masked != 0 {
                let bit = masked.trailing_zeros() as usize;
                let idx = word_idx * 64 + bit;
                if idx >= self.end {
                    return None;
                }
                self.pos = idx + 1;
                return Some(idx);
            }
            self.pos = (word_idx + 1) * 64;
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn set_get_clear() {
        let bm = AtomicBitmap::new(130);
        assert!(bm.set(0));
        assert!(!bm.set(0), "second set reports already-set");
        assert!(bm.set(64));
        assert!(bm.set(129));
        assert!(bm.get(0) && bm.get(64) && bm.get(129));
        assert!(!bm.get(1));
        assert_eq!(bm.count(), 3);
        assert!(bm.clear(64));
        assert!(!bm.clear(64));
        assert_eq!(bm.count(), 2);
    }

    #[test]
    fn iter_set_matches_manual() {
        let bm = AtomicBitmap::new(300);
        let want = [0usize, 1, 63, 64, 65, 127, 128, 200, 299];
        for &i in &want {
            bm.set(i);
        }
        let got: Vec<usize> = bm.iter_set().collect();
        assert_eq!(got, want);
    }

    #[test]
    fn iter_range_boundaries() {
        let bm = AtomicBitmap::new(256);
        for i in 0..256 {
            bm.set(i);
        }
        let got: Vec<usize> = bm.iter_set_range(60, 70).collect();
        assert_eq!(got, (60..70).collect::<Vec<_>>());
        assert_eq!(bm.iter_set_range(10, 10).count(), 0);
    }

    #[test]
    fn concurrent_sets_all_land() {
        let bm = Arc::new(AtomicBitmap::new(100_000));
        let mut handles = vec![];
        for t in 0..8 {
            let bm = bm.clone();
            handles.push(std::thread::spawn(move || {
                for i in (t..100_000).step_by(8) {
                    bm.set(i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(bm.count(), 100_000);
    }

    #[test]
    fn clear_span_word_level() {
        let bm = AtomicBitmap::new(300);
        for i in 0..300 {
            bm.set(i);
        }
        // aligned start, ragged end: exactly [64, 200) cleared — live
        // bits at 200.. in the tail word must survive
        bm.clear_span(64, 200);
        let got: Vec<usize> = bm.iter_set().collect();
        let want: Vec<usize> = (0..64).chain(200..300).collect();
        assert_eq!(got, want);
        // empty span is a no-op
        bm.clear_span(0, 0);
        assert_eq!(bm.count(), want.len());
        // sub-word ragged span
        bm.clear_span(0, 10);
        assert_eq!(bm.iter_set_range(0, 64).collect::<Vec<_>>(), (10..64).collect::<Vec<_>>());
        // full clear via span (ragged at len)
        bm.clear_span(0, 300);
        assert_eq!(bm.count(), 0);
    }

    #[test]
    fn word_access_and_masked_clear() {
        let bm = AtomicBitmap::new(130);
        for i in [0usize, 3, 64, 65, 127, 129] {
            bm.set(i);
        }
        assert_eq!(bm.word(0), 0b1001);
        assert_eq!(bm.word(1), (1 << 0) | (1 << 1) | (1 << 63));
        // clear only bit 65 (bit 1 of word 1): neighbors survive
        bm.clear_word_bits(1, 1 << 1);
        assert_eq!(bm.word(1), (1 << 0) | (1 << 63));
        assert!(bm.get(64) && bm.get(127) && !bm.get(65));
        // clearing already-clear bits is a no-op
        bm.clear_word_bits(0, 0b0110);
        assert_eq!(bm.word(0), 0b1001);
    }

    #[test]
    fn clear_all_resets() {
        let bm = AtomicBitmap::new(1000);
        for i in (0..1000).step_by(7) {
            bm.set(i);
        }
        assert!(bm.any());
        bm.clear_all();
        assert!(!bm.any());
        assert_eq!(bm.count(), 0);
    }
}
