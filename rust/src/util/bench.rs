//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Runs a closure with warmup, takes `k` timed samples, reports
//! min/median/mean/max. Benches under `rust/benches/` use this through
//! `harness = false` main functions and print paper-figure-style rows.

use std::time::{Duration, Instant};

/// Summary statistics of a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Label for reporting.
    pub name: String,
    /// All timed samples, sorted ascending.
    pub samples: Vec<Duration>,
}

impl BenchResult {
    /// Fastest sample.
    pub fn min(&self) -> Duration {
        self.samples[0]
    }

    /// Slowest sample.
    pub fn max(&self) -> Duration {
        *self.samples.last().unwrap()
    }

    /// Median sample.
    pub fn median(&self) -> Duration {
        self.samples[self.samples.len() / 2]
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// `other.median() / self.median()` — how many times faster self is.
    pub fn speedup_over(&self, other: &BenchResult) -> f64 {
        other.median().as_secs_f64() / self.median().as_secs_f64()
    }

    /// One-line report.
    pub fn report(&self) -> String {
        format!(
            "{:<44} median {:>12}  mean {:>12}  min {:>12}  max {:>12}  (n={})",
            self.name,
            super::fmt_dur(self.median()),
            super::fmt_dur(self.mean()),
            super::fmt_dur(self.min()),
            super::fmt_dur(self.max()),
            self.samples.len(),
        )
    }
}

/// Run `f` `warmup` times untimed, then `samples` times timed.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, samples: usize, mut f: F) -> BenchResult {
    assert!(samples > 0);
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        f();
        out.push(t.elapsed());
    }
    out.sort_unstable();
    BenchResult { name: name.to_string(), samples: out }
}

/// Print a section header for a figure harness.
pub fn figure_header(fig: &str, caption: &str) {
    println!("\n=== {fig} — {caption} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let mut n = 0u64;
        let r = bench("t", 1, 9, || {
            n += 1;
            std::hint::black_box(n);
        });
        assert_eq!(r.samples.len(), 9);
        assert!(r.min() <= r.median() && r.median() <= r.max());
    }

    #[test]
    fn speedup_ratio() {
        let fast = BenchResult {
            name: "fast".into(),
            samples: vec![Duration::from_millis(10); 3],
        };
        let slow = BenchResult {
            name: "slow".into(),
            samples: vec![Duration::from_millis(30); 3],
        };
        let s = fast.speedup_over(&slow);
        assert!((s - 3.0).abs() < 1e-9);
    }
}
