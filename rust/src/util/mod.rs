//! Small self-contained utilities shared across the library.
//!
//! Several well-known crates (rand, criterion, proptest) are not available
//! in this offline build, so this module carries minimal, well-tested
//! replacements: a xorshift PRNG, an atomic bitmap, a partition-disjoint
//! shared vector, a median-of-k bench harness and a tiny property-test
//! driver.

pub mod affinity;
pub mod atomic_f64;
pub mod backoff;
pub mod bench;
pub mod bitmap;
pub mod crc32c;
pub mod hist;
pub mod json;
pub mod metrics;
pub mod prefetch;
pub mod prng;
pub mod prop;
pub mod shared_vec;

pub use atomic_f64::{atomic_f64_vec, AtomicF64};
pub use backoff::Backoff;
pub use bench::{bench, BenchResult};
pub use bitmap::AtomicBitmap;
pub use crc32c::{crc32c, crc32c_update};
pub use hist::{HistSummary, Histogram};
pub use json::Json;
pub use metrics::MetricsRegistry;
pub use prefetch::prefetch_read;
pub use prng::XorShift;
pub use shared_vec::SharedVec;

/// Fsync the directory containing `path`, making a just-published
/// rename durable (rename alone persists the name only once the parent
/// directory's metadata hits stable storage). Best-effort: errors are
/// swallowed — the file's own `sync_all` already guarantees content
/// durability, this closes the crash window on the directory entry.
pub fn fsync_parent_dir(path: &std::path::Path) {
    if let Some(parent) = path.parent() {
        let dir = if parent.as_os_str().is_empty() {
            std::path::Path::new(".")
        } else {
            parent
        };
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// Format a byte count human-readably (KiB/MiB/GiB).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b >= K * K * K {
        format!("{:.2} GiB", b / (K * K * K))
    } else if b >= K * K {
        format!("{:.2} MiB", b / (K * K))
    } else if b >= K {
        format!("{:.2} KiB", b / K)
    } else {
        format!("{b:.0} B")
    }
}

/// Format a duration in adaptive units.
pub fn fmt_dur(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
        assert_eq!(fmt_bytes(5 * 1024 * 1024 * 1024), "5.00 GiB");
    }

    #[test]
    fn fmt_dur_units() {
        use std::time::Duration;
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.000 s");
        assert_eq!(fmt_dur(Duration::from_millis(5)), "5.000 ms");
        assert_eq!(fmt_dur(Duration::from_micros(7)), "7.0 us");
    }
}
