//! Opt-in CPU affinity for engine workers (Linux `sched_setaffinity`).
//!
//! Pinning a worker to one core does two things for the SEM hot path:
//! the worker's cache working set (decode arenas, its combiner sender
//! lane) stops migrating between L1/L2 domains, and — because its
//! `FetchSlot` arenas are allocated *inside* the pinned thread — the
//! kernel's first-touch policy places those pages on the pinned core's
//! NUMA node. Off by default ([`crate::engine::EngineConfig`]
//! `pin_workers`), because on a shared box pinning fights the scheduler.
//!
//! No `libc` crate is vendored in this offline build, so the Linux
//! syscall wrapper is bound directly (the same pattern `main.rs` uses
//! for `signal`). Off Linux the call is a documented no-op returning
//! `false` — pinning is a locality hint, never a correctness
//! requirement, and every caller treats failure as "run unpinned".

/// Upper bound on addressable CPUs (16 × 64 = 1024, glibc's default
/// `cpu_set_t` size).
const MASK_WORDS: usize = 16;

/// Pin the calling thread to `core` (wrapping modulo the mask size is
/// the caller's job — pass `wid % cores`). Returns `true` when the
/// affinity call succeeded, `false` when it failed or the platform has
/// no pinning support; callers must treat `false` as "continue
/// unpinned".
#[cfg(target_os = "linux")]
pub fn pin_to_core(core: usize) -> bool {
    if core >= MASK_WORDS * 64 {
        return false;
    }
    extern "C" {
        // pid 0 = the calling thread; mask is a cpu_set_t's bit words
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; MASK_WORDS];
    mask[core / 64] = 1u64 << (core % 64);
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// No pinning support off Linux: always `false`, callers run unpinned.
#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_range_core_is_rejected() {
        assert!(!pin_to_core(MASK_WORDS * 64));
        assert!(!pin_to_core(usize::MAX));
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn pin_to_core_zero_succeeds_and_work_continues() {
        // core 0 exists on every machine; the thread keeps running after
        // the affinity change (CI containers may deny the syscall, in
        // which case false is the documented, non-fatal outcome)
        let ok = std::thread::spawn(|| {
            let ok = pin_to_core(0);
            // either way the thread computes correctly
            assert_eq!((0..100u64).sum::<u64>(), 4950);
            ok
        })
        .join()
        .unwrap();
        // no assert on `ok`: sandboxes may forbid sched_setaffinity
        let _ = ok;
    }
}
