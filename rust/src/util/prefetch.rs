//! Software prefetch hints for pointer-chasing hot loops.
//!
//! The combiner delivery sweep and the vertex-dispatch loop both walk
//! data the hardware prefetcher cannot predict: which message slab slot
//! or decoded edge list is touched next depends on bitmap contents
//! computed moments earlier. A `prefetch` hint issued one iteration
//! ahead turns the dependent-load cache miss into overlapped latency.
//!
//! This is a *hint* wrapper: on x86_64 it lowers to `prefetcht0`, on
//! aarch64 to `prfm pldl1keep`, and on anything else to a no-op — never
//! a fault, never a behavior change. Prefetching an invalid address is
//! architecturally harmless, but callers here only ever pass references,
//! so the address is always live.

/// Hint the CPU to pull the cache line holding `r` toward L1.
///
/// Safe on every target: architectures without a stable prefetch
/// intrinsic compile this to nothing.
#[inline(always)]
pub fn prefetch_read<T: ?Sized>(r: &T) {
    let p = r as *const T as *const u8;
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(p as *const i8);
    }
    #[cfg(target_arch = "aarch64")]
    unsafe {
        core::arch::asm!("prfm pldl1keep, [{0}]", in(reg) p, options(nostack, readonly));
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefetch_is_behavior_free() {
        // a hint has no observable effect; this pins the API shape and
        // exercises the intrinsic path on the build target
        let v = vec![7u64; 1024];
        for x in &v {
            prefetch_read(x);
        }
        prefetch_read(&v[..]);
        assert_eq!(v.iter().sum::<u64>(), 7 * 1024);
    }
}
