//! Partition-disjoint shared vector.
//!
//! Vertex programs own O(n) state arrays that workers mutate concurrently
//! — but only ever *their own vertex's* slot during `run_on_vertex` /
//! `run_on_message`. The engine guarantees each vertex is processed by
//! exactly one worker at a time: messages for `v` are delivered by `v`'s
//! owner worker in the message phase, and `v`'s vertex run executes on
//! whichever worker claimed `v`'s frontier chunk (possibly a stealing
//! one) — each phase gives one exclusive writer per slot, and the global
//! barrier between phases orders them. `SharedVec` encodes that
//! contract: reads from any thread, writes through
//! [`SharedVec::set`]/[`SharedVec::get_mut`] which the caller promises
//! are per-slot exclusive.
//!
//! This mirrors FlashGraph's design, where vertex state lives in flat
//! arrays indexed by vertex id and the engine's partitioning provides
//! exclusion.

use std::cell::UnsafeCell;

/// A `Vec<T>` with interior mutability under a partition-disjoint contract.
pub struct SharedVec<T> {
    data: Vec<UnsafeCell<T>>,
}

// Safety: access discipline is delegated to the engine's partitioning
// contract (documented above).
unsafe impl<T: Send> Send for SharedVec<T> {}
unsafe impl<T: Send> Sync for SharedVec<T> {}

impl<T: Clone> SharedVec<T> {
    /// Build with `n` copies of `init`.
    pub fn new(n: usize, init: T) -> Self {
        SharedVec {
            data: (0..n).map(|_| UnsafeCell::new(init.clone())).collect(),
        }
    }
}

impl<T> SharedVec<T> {
    /// Build from an existing vector.
    pub fn from_vec(v: Vec<T>) -> Self {
        SharedVec {
            data: v.into_iter().map(UnsafeCell::new).collect(),
        }
    }

    /// Number of slots.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Read slot `i`.
    ///
    /// Races with a concurrent `set(i, ..)` are the caller's
    /// responsibility; algorithms in this library only read slots that are
    /// stable in the current superstep (double-buffering or own-slot).
    #[inline]
    pub fn get(&self, i: usize) -> &T {
        unsafe { &*self.data[i].get() }
    }

    /// Write slot `i`.
    ///
    /// # Safety contract (checked by the engine's partitioning)
    /// No concurrent access to slot `i` may happen during this call.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn get_mut(&self, i: usize) -> &mut T {
        unsafe { &mut *self.data[i].get() }
    }

    /// Convenience: overwrite slot `i` (same contract as `get_mut`).
    #[inline]
    pub fn set(&self, i: usize, v: T) {
        *self.get_mut(i) = v;
    }

    /// Iterate immutable snapshots (single-threaded phases only).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.data.iter().map(|c| unsafe { &*c.get() })
    }

    /// Consume into a plain vector (single-threaded).
    pub fn into_vec(self) -> Vec<T> {
        self.data.into_iter().map(|c| c.into_inner()).collect()
    }
}

impl<T: Clone> SharedVec<T> {
    /// Clone contents out (single-threaded phases only).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn basic_ops() {
        let v = SharedVec::new(4, 0i64);
        v.set(2, 42);
        *v.get_mut(3) += 7;
        assert_eq!(*v.get(2), 42);
        assert_eq!(*v.get(3), 7);
        assert_eq!(v.to_vec(), vec![0, 0, 42, 7]);
        assert_eq!(v.len(), 4);
    }

    #[test]
    fn disjoint_parallel_writes() {
        let v = Arc::new(SharedVec::new(80_000, 0u64));
        let mut hs = vec![];
        for t in 0..8u64 {
            let v = v.clone();
            hs.push(std::thread::spawn(move || {
                // slot-disjoint striping
                for i in (t as usize..80_000).step_by(8) {
                    v.set(i, t + 1);
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        for i in 0..80_000 {
            assert_eq!(*v.get(i), (i % 8) as u64 + 1);
        }
    }

    #[test]
    fn from_into_vec_roundtrip() {
        let v = SharedVec::from_vec(vec![1, 2, 3]);
        assert_eq!(v.into_vec(), vec![1, 2, 3]);
    }
}
