//! Shared plumbing for the figure-reproduction benches
//! (`rust/benches/fig*.rs`): workload construction in the paper's SEM
//! regime and uniform result rows.
//!
//! Every bench prints the same row schema so EXPERIMENTS.md can quote
//! them directly: variant, wall time, rounds, read requests, logical
//! bytes, physical bytes, messages (sends, combiner folds, peak
//! transport bytes, summed phase-A wall), waits.

use std::path::PathBuf;

use crate::coordinator::config::RunConfig;
use crate::coordinator::report::Table;
use crate::engine::RunReport;
use crate::graph::builder::GraphBuilder;
use crate::graph::gen;
use crate::graph::source::{EdgeSource, SemGraph};
use crate::safs::IoStatsSnapshot;
use crate::util::{fmt_bytes, fmt_dur, Json};

/// Standard SSD-emulation latency for benches (µs per physical read).
/// Restores the I/O-bound regime the paper measures in (DESIGN.md §5);
/// override with `GRAPHYTI_BENCH_DELAY_US`.
pub fn bench_io_delay_us() -> u64 {
    std::env::var("GRAPHYTI_BENCH_DELAY_US")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(100)
}

/// R-MAT scale for benches (default 15; override `GRAPHYTI_BENCH_SCALE`).
pub fn bench_scale() -> u32 {
    std::env::var("GRAPHYTI_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15)
}

/// Round direction for benches (default `push`, the historical baseline
/// regime; override `GRAPHYTI_BENCH_MODE=push|pull|auto`).
pub fn bench_mode() -> crate::engine::RunMode {
    std::env::var("GRAPHYTI_BENCH_MODE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(crate::engine::RunMode::Push)
}

/// Build (once, cached on disk) an R-MAT image for benching and return
/// `(base path, RunConfig)` with the cache in the paper's 1/7 regime.
pub fn rmat_workload(scale: u32, edge_factor: usize, directed: bool, tag: &str) -> (PathBuf, RunConfig) {
    rmat_workload_fmt(scale, edge_factor, directed, tag, crate::graph::format::VERSION_V1)
}

/// [`rmat_workload`] with an explicit on-disk format version. The cache
/// is sized to 1/7 of *this* image's adjacency bytes; for cross-format
/// comparisons use [`compare_formats`], which holds the cache size fixed
/// across both images instead.
pub fn rmat_workload_fmt(
    scale: u32,
    edge_factor: usize,
    directed: bool,
    tag: &str,
    version: u32,
) -> (PathBuf, RunConfig) {
    // GRAPHYTI_BENCH_PLAIN=1 builds the image without checksum footers
    // (the pre-verified-storage layout); CI benches it against the
    // checksummed default to assert bytes_read parity on clean images.
    // The marker is part of the cache name so the two variants never
    // alias each other's cached image.
    let plain = std::env::var("GRAPHYTI_BENCH_PLAIN").is_ok_and(|v| v == "1");
    let base = std::env::temp_dir().join(format!(
        "graphyti-bench-{tag}-s{scale}-f{edge_factor}-{}-v{version}{}",
        if directed { "d" } else { "u" },
        if plain { "-plain" } else { "" }
    ));
    if !(base.with_extension("gy-idx").exists() && base.with_extension("gy-adj").exists()) {
        let n = 1usize << scale;
        let edges = gen::rmat(scale, n * edge_factor, 42);
        let mut b = GraphBuilder::new(n, directed);
        b.add_edges(&edges).format_version(version).checksums(!plain);
        // build under a pid-suffixed name, then rename into place, so a
        // killed or concurrent run can never leave a half-written image
        // behind the existence check (adj first: idx-present ⇒ adj done)
        let tmp = base.with_file_name(format!(
            "{}-tmp{}",
            base.file_name().unwrap().to_string_lossy(),
            std::process::id()
        ));
        let (tidx, tadj) = b.build_files(&tmp).expect("build bench image");
        std::fs::rename(&tadj, base.with_extension("gy-adj")).expect("publish bench adj");
        std::fs::rename(&tidx, base.with_extension("gy-idx")).expect("publish bench idx");
    }
    let adj_bytes = std::fs::metadata(base.with_extension("gy-adj")).unwrap().len();
    let cache_bytes = (adj_bytes as usize / 7).max(64 * 4096);
    let mut cfg = RunConfig::default();
    cfg.cache_mb = cache_bytes.div_ceil(1024 * 1024).max(1);
    cfg.io_delay_us = bench_io_delay_us();
    cfg.mode = bench_mode();
    (base, cfg)
}

/// Outcome of a v1-vs-v2 format comparison ([`compare_formats`]).
pub struct FormatComparison {
    /// Run on the v1 (fixed-width) image.
    pub v1: RunReport,
    /// Run on the v2 (delta+varint) image.
    pub v2: RunReport,
    /// `.gy-adj` size of the v1 image.
    pub v1_adj_bytes: u64,
    /// `.gy-adj` size of the v2 image.
    pub v2_adj_bytes: u64,
}

/// Build the same R-MAT graph as a v1 and a v2 image, run `run` against
/// each on a cold cache, and print a table comparing edge bytes on disk,
/// read volume and cache hit rate. Both runs use the identical cache
/// size (1/7 of the *v1* adjacency) and I/O configuration, so every
/// difference in the I/O columns is the format's doing.
pub fn compare_formats(
    scale: u32,
    edge_factor: usize,
    directed: bool,
    tag: &str,
    mut run: impl FnMut(&SemGraph) -> RunReport,
) -> FormatComparison {
    use crate::graph::format::{VERSION_V1, VERSION_V2};
    let (base1, cfg) = rmat_workload_fmt(scale, edge_factor, directed, tag, VERSION_V1);
    let (base2, _) = rmat_workload_fmt(scale, edge_factor, directed, tag, VERSION_V2);
    let v1_adj_bytes = std::fs::metadata(base1.with_extension("gy-adj")).unwrap().len();
    let v2_adj_bytes = std::fs::metadata(base2.with_extension("gy-adj")).unwrap().len();
    let v1 = run(&open_sem(&base1, &cfg));
    let v2 = run(&open_sem(&base2, &cfg));

    let mut t =
        Table::new(&["format", "adj-bytes", "wall", "read-reqs", "logical", "disk", "hit%"]);
    for (name, adj, r) in [
        ("v1 fixed-u32", v1_adj_bytes, &v1),
        ("v2 delta+varint", v2_adj_bytes, &v2),
    ] {
        t.row(&[
            name.to_string(),
            fmt_bytes(adj),
            fmt_dur(r.wall),
            r.io.read_requests.to_string(),
            fmt_bytes(r.io.logical_bytes),
            fmt_bytes(r.io.bytes_read),
            format!("{:.1}", 100.0 * r.io.hit_ratio()),
        ]);
    }
    t.print();
    println!(
        "v2/v1: adj {:.2}x smaller, disk reads {:.2}x smaller",
        v1_adj_bytes as f64 / v2_adj_bytes.max(1) as f64,
        v1.io.bytes_read as f64 / v2.io.bytes_read.max(1) as f64,
    );
    FormatComparison { v1, v2, v1_adj_bytes, v2_adj_bytes }
}

/// Open the workload semi-externally with a cold cache.
pub fn open_sem(base: &PathBuf, cfg: &RunConfig) -> SemGraph {
    SemGraph::open(base, cfg.cache_bytes(), cfg.io()).expect("open bench graph")
}

/// Format a busy ratio compactly (`inf` for an unbounded imbalance —
/// which is exactly what a static partition shows on a skewed frontier).
fn fmt_ratio(r: f64) -> String {
    if r.is_finite() {
        format!("{r:.2}")
    } else {
        "inf".to_string()
    }
}

/// Worker-scaling harness: run `run` against a **cold** SEM open of the
/// same image at each worker count and print wall time, steal count and
/// the max/min per-worker busy ratio — the table that makes the
/// work-stealing scheduler's balance visible (`fig_scaling` bench).
/// Returns the per-count reports in order.
pub fn worker_scaling(
    base: &PathBuf,
    cfg: &RunConfig,
    counts: &[usize],
    mut run: impl FnMut(&SemGraph, usize) -> RunReport,
) -> Vec<RunReport> {
    let mut t = Table::new(&[
        "workers",
        "wall",
        "speedup",
        "rounds",
        "steals",
        "busy-ratio",
        "busy(sum)",
        "idle(sum)",
        "disk",
    ]);
    let mut reports = Vec::with_capacity(counts.len());
    let mut base_wall = None;
    for &w in counts {
        let g = open_sem(base, cfg);
        let r = run(&g, w);
        let bw = *base_wall.get_or_insert(r.wall.as_secs_f64());
        t.row(&[
            w.to_string(),
            fmt_dur(r.wall),
            format!("{:.2}x", bw / r.wall.as_secs_f64()),
            r.rounds.to_string(),
            r.engine.steals.to_string(),
            fmt_ratio(r.engine.busy_ratio()),
            fmt_dur(r.engine.total_busy()),
            fmt_dur(r.engine.total_idle()),
            fmt_bytes(r.io.bytes_read),
        ]);
        reports.push(r);
    }
    t.print();
    reports
}

/// Like [`worker_scaling`], but each worker count runs twice — unpinned
/// then core-pinned (`run`'s third argument) — so the scaling table
/// shows what affinity buys at each width. Adds the wait-ladder columns
/// (`park`, `backoff`) since pinning changes *where* waits happen, not
/// results. The speedup baseline is the first unpinned run. Returns
/// `(pinned, report)` pairs in execution order.
pub fn worker_scaling_pinned(
    base: &PathBuf,
    cfg: &RunConfig,
    counts: &[usize],
    mut run: impl FnMut(&SemGraph, usize, bool) -> RunReport,
) -> Vec<(bool, RunReport)> {
    let mut t = Table::new(&[
        "workers",
        "pin",
        "wall",
        "speedup",
        "rounds",
        "steals",
        "busy-ratio",
        "park",
        "backoff",
        "disk",
    ]);
    let mut reports = Vec::with_capacity(counts.len() * 2);
    let mut base_wall = None;
    for &w in counts {
        for pin in [false, true] {
            let g = open_sem(base, cfg);
            let r = run(&g, w, pin);
            let bw = *base_wall.get_or_insert(r.wall.as_secs_f64());
            t.row(&[
                w.to_string(),
                if pin { "on" } else { "off" }.to_string(),
                fmt_dur(r.wall),
                format!("{:.2}x", bw / r.wall.as_secs_f64()),
                r.rounds.to_string(),
                r.engine.steals.to_string(),
                fmt_ratio(r.engine.busy_ratio()),
                fmt_dur(std::time::Duration::from_nanos(r.engine.park_ns)),
                r.engine.backoff_events.to_string(),
                fmt_bytes(r.io.bytes_read),
            ]);
            reports.push((pin, r));
        }
    }
    t.print();
    reports
}

/// Run `f` against `source` and return its output together with the
/// snapshot *delta* of the source's own I/O counters over the run.
///
/// This is the only correct way to attribute I/O to a measured section:
/// the counters are process-shared monotonic totals, so reading them
/// raw conflates everything that ran before (warmup, other variants on
/// the same handle) — and, in service mode, everything other jobs are
/// doing concurrently. Pair with [`crate::service::JobGraph`] to get a
/// per-job source whose counters only ever move for that job.
pub fn measure_io<T>(
    source: &dyn EdgeSource,
    f: impl FnOnce() -> T,
) -> (T, IoStatsSnapshot) {
    let before = source.io_stats().snapshot();
    let out = f();
    (out, source.io_stats().snapshot().delta(&before))
}

/// Output directory for machine-readable bench baselines
/// (`BENCH_<fig>.json`); override with `GRAPHYTI_BENCH_OUT`.
pub fn bench_out_dir() -> PathBuf {
    std::env::var("GRAPHYTI_BENCH_OUT").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("."))
}

/// Collector printing the uniform figure-row schema. Every added run is
/// also retained verbatim so [`FigTable::write_json`] can emit a
/// machine-readable `BENCH_<fig>.json` baseline next to the table.
pub struct FigTable {
    table: Table,
    baseline_wall: Option<f64>,
    rows: Vec<(String, RunReport)>,
}

impl Default for FigTable {
    fn default() -> Self {
        Self::new()
    }
}

impl FigTable {
    /// New empty table.
    pub fn new() -> Self {
        FigTable {
            rows: Vec::new(),
            table: Table::new(&[
                "variant",
                "wall",
                "vs-base",
                "rounds",
                "read-reqs",
                "logical",
                "disk",
                "hit%",
                "p2p",
                "mcast",
                "deliver",
                "combined",
                "peak-msg",
                "phaseA",
                "waits",
                "steals",
                "busy-ratio",
            ]),
            baseline_wall: None,
        }
    }

    /// Append a run; the first row becomes the speedup baseline. All
    /// I/O columns come from the run's own snapshot delta
    /// (`RunReport.io`), never from the live global counters — so rows
    /// stay correct when several runs (or service jobs) share one
    /// substrate.
    pub fn add(&mut self, variant: &str, r: &RunReport) {
        let wall = r.wall.as_secs_f64();
        let base = *self.baseline_wall.get_or_insert(wall);
        self.table.row(&[
            variant.to_string(),
            fmt_dur(r.wall),
            format!("{:.2}x", base / wall),
            r.rounds.to_string(),
            r.io.read_requests.to_string(),
            fmt_bytes(r.io.logical_bytes),
            fmt_bytes(r.io.bytes_read),
            format!("{:.1}", 100.0 * r.io.hit_ratio()),
            r.engine.p2p_msgs.to_string(),
            r.engine.multicast_msgs.to_string(),
            r.engine.deliveries.to_string(),
            r.engine.combined_msgs.to_string(),
            fmt_bytes(r.engine.peak_msg_bytes),
            fmt_dur(r.engine.phase_a()),
            r.io.thread_waits.to_string(),
            r.engine.steals.to_string(),
            fmt_ratio(r.engine.busy_ratio()),
        ]);
        self.rows.push((variant.to_string(), r.clone()));
    }

    /// Print the table.
    pub fn print(&self) {
        self.table.print();
    }

    /// Machine-readable rendering of every added run: the baseline
    /// schema `benchcheck` compares against (see `docs/METRICS.md`).
    pub fn to_json(&self, fig: &str, workload: &str) -> Json {
        Json::obj(vec![
            ("fig", Json::s(fig)),
            ("workload", Json::s(workload)),
            ("schema", Json::u(1)),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|(v, r)| report_row_json(v, r)).collect()),
            ),
        ])
    }

    /// Write `BENCH_<fig>.json` into [`bench_out_dir`]; returns the
    /// path. Benches call this unconditionally — the file is the
    /// machine-readable twin of the printed table.
    pub fn write_json(&self, fig: &str, workload: &str) -> std::io::Result<PathBuf> {
        let path = bench_out_dir().join(format!("BENCH_{fig}.json"));
        std::fs::write(&path, self.to_json(fig, workload).encode_pretty())?;
        println!("baseline written: {}", path.display());
        Ok(path)
    }
}

/// One bench row as JSON. Wall time is milliseconds (f64); everything
/// else is the raw counter. The trace summary rides along when the run
/// recorded one.
fn report_row_json(variant: &str, r: &RunReport) -> Json {
    let mut fields = vec![
        ("variant", Json::s(variant)),
        ("wall_ms", Json::f(r.wall.as_secs_f64() * 1e3)),
        ("rounds", Json::u(r.rounds)),
        (
            "io",
            Json::obj(vec![
                ("read_requests", Json::u(r.io.read_requests)),
                ("logical_bytes", Json::u(r.io.logical_bytes)),
                ("bytes_read", Json::u(r.io.bytes_read)),
                ("physical_reads", Json::u(r.io.physical_reads)),
                ("cache_hits", Json::u(r.io.cache_hits)),
                ("cache_misses", Json::u(r.io.cache_misses)),
                ("thread_waits", Json::u(r.io.thread_waits)),
                ("retries", Json::u(r.io.retries)),
                ("fetch_p50_us", Json::u(r.io.latency.fetch.p50)),
                ("fetch_p99_us", Json::u(r.io.latency.fetch.p99)),
            ]),
        ),
        (
            "engine",
            Json::obj(vec![
                ("p2p_msgs", Json::u(r.engine.p2p_msgs)),
                ("multicast_msgs", Json::u(r.engine.multicast_msgs)),
                ("deliveries", Json::u(r.engine.deliveries)),
                ("combined_msgs", Json::u(r.engine.combined_msgs)),
                ("peak_msg_bytes", Json::u(r.engine.peak_msg_bytes)),
                ("steals", Json::u(r.engine.steals)),
                ("vertex_runs", Json::u(r.engine.vertex_runs)),
                ("pull_rounds", Json::u(r.engine.pull_rounds)),
                ("blocks_skipped", Json::u(r.engine.blocks_skipped)),
                ("park_ns", Json::u(r.engine.park_ns)),
                ("backoff_events", Json::u(r.engine.backoff_events)),
                ("overlap_ratio", Json::f(r.engine.overlap_ratio())),
                (
                    "busy_ratio",
                    if r.engine.busy_ratio().is_finite() {
                        Json::f(r.engine.busy_ratio())
                    } else {
                        Json::Null
                    },
                ),
            ]),
        ),
    ];
    if let Some(tr) = &r.trace {
        fields.push(("trace", tr.summary_json()));
    }
    Json::obj(fields)
}

/// Outcome of a baseline-vs-current bench comparison.
pub struct BenchCheck {
    /// Whether the current run is within the regression budget.
    pub ok: bool,
    /// One human-readable line per compared (or skipped) row.
    pub notes: Vec<String>,
}

/// Compare a current `BENCH_<fig>.json` against a committed baseline.
///
/// Rows are matched by `variant`. A matched row fails when wall time
/// regresses more than `wall_tolerance` (fraction, e.g. 0.15) or when
/// `bytes_read` grows at all — read volume is deterministic for a given
/// image + cache size, so any growth is a real I/O regression, while
/// wall time gets slack for machine noise. A baseline with no rows (the
/// bootstrap placeholder committed before a toolchain ran the benches)
/// passes with a note, so CI can adopt the gate before the first real
/// baseline lands. The same courtesy applies per row: a baseline row
/// with `wall_ms == 0` is a hand-written placeholder, and since its
/// `bytes_read` is equally fictional, BOTH gates are skipped for it —
/// gating real reads against a made-up zero would fail every adoption
/// run.
pub fn bench_compare(baseline: &Json, current: &Json, wall_tolerance: f64) -> BenchCheck {
    let rows = |j: &Json| -> Vec<(String, f64, u64)> {
        j.get("rows")
            .and_then(Json::as_array)
            .unwrap_or(&[])
            .iter()
            .filter_map(|r| {
                Some((
                    r.get("variant")?.as_str()?.to_string(),
                    r.get("wall_ms")?.as_f64()?,
                    r.get("io")?.get("bytes_read")?.as_u64()?,
                ))
            })
            .collect()
    };
    let base_rows = rows(baseline);
    let cur_rows = rows(current);
    let mut notes = Vec::new();
    let mut ok = true;
    if base_rows.is_empty() {
        notes.push("baseline has no rows (bootstrap placeholder): pass".to_string());
        return BenchCheck { ok: true, notes };
    }
    for (variant, base_wall, base_bytes) in &base_rows {
        let Some((_, cur_wall, cur_bytes)) =
            cur_rows.iter().find(|(v, _, _)| v == variant)
        else {
            ok = false;
            notes.push(format!("{variant}: MISSING from current run"));
            continue;
        };
        if *base_wall == 0.0 {
            notes.push(format!(
                "{variant}: baseline is a bootstrap placeholder row (wall 0 ms): pass"
            ));
            continue;
        }
        let wall_ratio = cur_wall / base_wall.max(1e-9);
        let wall_ok = wall_ratio <= 1.0 + wall_tolerance;
        let bytes_ok = cur_bytes <= base_bytes;
        ok &= wall_ok && bytes_ok;
        notes.push(format!(
            "{variant}: wall {base_wall:.1} -> {cur_wall:.1} ms ({wall_ratio:.2}x, {}), \
             bytes_read {base_bytes} -> {cur_bytes} ({})",
            if wall_ok { "ok" } else { "FAIL" },
            if bytes_ok { "ok" } else { "FAIL" },
        ));
    }
    BenchCheck { ok, notes }
}

/// Print a figure banner.
pub fn banner(fig: &str, caption: &str, workload: &str) {
    println!("\n================================================================");
    println!("{fig} — {caption}");
    println!("workload: {workload}");
    println!("================================================================");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::format::EdgeRequest;
    use crate::graph::source::MemGraph;

    #[test]
    fn compare_formats_v2_is_smaller_and_reads_less() {
        let ecfg = crate::engine::EngineConfig { workers: 2, ..Default::default() };
        let cmp = compare_formats(9, 8, true, "fmt-unit", |g| {
            crate::algs::pagerank::pagerank_push(g, 0.85, 1e-8, &ecfg).report
        });
        assert!(
            cmp.v2_adj_bytes * 2 < cmp.v1_adj_bytes,
            "v2 adj {} should be well under half of v1 {}",
            cmp.v2_adj_bytes,
            cmp.v1_adj_bytes
        );
        assert!(
            cmp.v2.io.logical_bytes < cmp.v1.io.logical_bytes,
            "compressed records must shrink logical read volume"
        );
        assert!(
            cmp.v2.io.bytes_read <= cmp.v1.io.bytes_read,
            "fewer pages should leave disk: v2 {} vs v1 {}",
            cmp.v2.io.bytes_read,
            cmp.v1.io.bytes_read
        );
        // identical results aside: both ran the same algorithm to completion
        assert!(cmp.v1.rounds > 0 && cmp.v2.rounds > 0);
    }

    #[test]
    fn worker_scaling_reports_each_count() {
        let (base, mut cfg) = rmat_workload(9, 8, true, "scale-unit");
        cfg.io_delay_us = 0;
        let reports = worker_scaling(&base, &cfg, &[1, 2], |g, w| {
            let ecfg = crate::engine::EngineConfig { workers: w, ..Default::default() };
            crate::algs::bfs::bfs(g, 0, &ecfg).1
        });
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].engine.worker_busy_ns.len(), 1, "1-worker run tracks 1 slot");
        assert_eq!(reports[1].engine.worker_busy_ns.len(), 2, "2-worker run tracks 2 slots");
        assert!(reports[0].rounds > 0 && reports[1].rounds > 0);
    }

    #[test]
    fn worker_scaling_pinned_runs_both_variants_per_count() {
        let (base, mut cfg) = rmat_workload(9, 8, true, "scale-pin-unit");
        cfg.io_delay_us = 0;
        let reports = worker_scaling_pinned(&base, &cfg, &[1, 2], |g, w, pin| {
            let ecfg = crate::engine::EngineConfig {
                workers: w,
                pin_workers: pin,
                ..Default::default()
            };
            crate::algs::bfs::bfs(g, 0, &ecfg).1
        });
        // unpinned + pinned per count, in order, bit-identical rounds
        let pins: Vec<bool> = reports.iter().map(|(p, _)| *p).collect();
        assert_eq!(pins, vec![false, true, false, true]);
        let rounds: Vec<u64> = reports.iter().map(|(_, r)| r.rounds).collect();
        assert_eq!(rounds[0], rounds[1], "pinning must not change round count");
        assert_eq!(rounds[2], rounds[3]);
    }

    fn report_with(wall_ms: u64, bytes_read: u64) -> RunReport {
        let mut r = RunReport {
            rounds: 3,
            wall: std::time::Duration::from_millis(wall_ms),
            engine: Default::default(),
            io: Default::default(),
            trace: None,
            failure: None,
        };
        r.io.bytes_read = bytes_read;
        r
    }

    fn table_json(rows: &[(&str, u64, u64)]) -> Json {
        let mut t = FigTable::new();
        for (v, wall, bytes) in rows {
            t.add(v, &report_with(*wall, *bytes));
        }
        t.to_json("fig_unit", "unit workload")
    }

    #[test]
    fn fig_table_json_round_trips() {
        let j = table_json(&[("push", 100, 4096), ("pull", 150, 8192)]);
        let j = Json::parse(&j.encode_pretty()).unwrap();
        assert_eq!(j.get("fig").unwrap().as_str(), Some("fig_unit"));
        let rows = j.get("rows").unwrap().as_array().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("variant").unwrap().as_str(), Some("push"));
        assert_eq!(rows[1].get("io").unwrap().get("bytes_read").unwrap().as_u64(), Some(8192));
        assert_eq!(rows[0].get("wall_ms").unwrap().as_f64(), Some(100.0));
    }

    #[test]
    fn bench_compare_flags_regressions() {
        let base = table_json(&[("push", 100, 4096)]);
        // within wall tolerance, same bytes: ok
        let c = bench_compare(&base, &table_json(&[("push", 110, 4096)]), 0.15);
        assert!(c.ok, "{:?}", c.notes);
        // wall blown past tolerance
        let c = bench_compare(&base, &table_json(&[("push", 200, 4096)]), 0.15);
        assert!(!c.ok, "{:?}", c.notes);
        // any bytes_read growth fails
        let c = bench_compare(&base, &table_json(&[("push", 100, 4097)]), 0.15);
        assert!(!c.ok, "{:?}", c.notes);
        // bytes shrinking is fine
        let c = bench_compare(&base, &table_json(&[("push", 100, 1024)]), 0.15);
        assert!(c.ok, "{:?}", c.notes);
        // variant missing from the current run fails
        let c = bench_compare(&base, &table_json(&[("pull", 100, 4096)]), 0.15);
        assert!(!c.ok, "{:?}", c.notes);
    }

    #[test]
    fn bench_compare_passes_on_bootstrap_baseline() {
        let empty = table_json(&[]);
        let c = bench_compare(&empty, &table_json(&[("push", 100, 4096)]), 0.15);
        assert!(c.ok);
        assert!(c.notes[0].contains("bootstrap"), "{:?}", c.notes);
    }

    #[test]
    fn bench_compare_skips_both_gates_on_zero_wall_placeholder_row() {
        // a hand-written placeholder row carries wall_ms == 0 AND a
        // fictional bytes_read — any real run would "regress" both
        // infinitely, so the row must be skipped outright
        let base = table_json(&[("push", 0, 0), ("pull", 100, 4096)]);
        let c = bench_compare(&base, &table_json(&[("push", 250, 9999), ("pull", 100, 4096)]), 0.15);
        assert!(c.ok, "{:?}", c.notes);
        assert!(c.notes[0].contains("placeholder"), "{:?}", c.notes);
        // real rows alongside the placeholder still gate
        let c = bench_compare(&base, &table_json(&[("push", 250, 9999), ("pull", 100, 8192)]), 0.15);
        assert!(!c.ok, "{:?}", c.notes);
    }

    #[test]
    fn measure_io_reports_only_the_measured_section() {
        let g = MemGraph::from_edges(16, &gen::cycle(16), true);
        // warmup traffic that must NOT appear in the measurement
        g.fetch_batch(&[(0, EdgeRequest::Out)]).unwrap();
        let (out, io) = measure_io(&g, || {
            g.fetch_batch(&[(1, EdgeRequest::Out), (2, EdgeRequest::Out)]).unwrap()
        });
        assert_eq!(out.len(), 2);
        assert_eq!(io.read_requests, 2, "delta must exclude warmup: {io:?}");
    }
}
