//! Run configuration: `key=value` files + CLI overrides.
//!
//! The paper's experiments hinge on a handful of knobs (page-cache size
//! vs graph size, I/O parallelism, worker threads); this module makes
//! them uniform across the CLI, the examples and the bench harnesses.

use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use anyhow::{bail, Context};

use crate::engine::{EngineConfig, RunMode, TransportMode};
use crate::safs::IoConfig;

/// How (and whether) to surface the per-round engine trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceMode {
    /// No trace recorded (default; zero overhead).
    #[default]
    Off,
    /// Record and print a per-round table after the run.
    Table,
    /// Record and print the trace as one JSON line after the run.
    Json,
}

impl TraceMode {
    /// Whether the engine should record at all.
    pub fn enabled(self) -> bool {
        self != TraceMode::Off
    }
}

/// All tunables for a run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Page-cache capacity in MiB (the paper's central SEM knob).
    pub cache_mb: usize,
    /// I/O pool threads.
    pub io_threads: usize,
    /// Injected latency per physical read, microseconds (emulates SSD
    /// access cost; see DESIGN.md §5).
    pub io_delay_us: u64,
    /// Max pages per merged physical read.
    pub max_run_pages: usize,
    /// Engine worker threads (0 = one per core).
    pub workers: usize,
    /// Vertices per fetch batch.
    pub batch: usize,
    /// Message transport: `auto` (combiner lanes when the program
    /// declares a combiner) or `queue` (force the queue-lane baseline).
    pub transport: TransportMode,
    /// Push/pull round direction (`mode=push|pull|auto`); `auto`
    /// switches per round on frontier density for programs that opt in.
    pub mode: RunMode,
    /// `mode=auto` density threshold (active fraction ≥ this → pull).
    pub pull_density: f64,
    /// Edge batches kept in flight per worker beyond the one being
    /// processed (0 = synchronous fetch-then-compute baseline).
    pub fetch_window: usize,
    /// PageRank damping factor.
    pub alpha: f64,
    /// PageRank convergence threshold (absolute rank delta).
    pub threshold: f64,
    /// Deterministic seed for generators / source selection.
    pub seed: u64,
    /// Cooperative cancellation token forwarded to the engine (checked
    /// at round boundaries). Set by the service executor per job; not a
    /// `key=value` knob.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Per-round trace recording and rendering
    /// (`trace=off|on|table|json`; `on` is an alias for `table`).
    pub trace: TraceMode,
    /// Write a round-boundary checkpoint every N rounds (0 = off; see
    /// `checkpoint_path`). Only programs that declare themselves
    /// checkpointable honor it.
    pub checkpoint_every: u64,
    /// Checkpoint file location. Set by the service executor per job or
    /// via `checkpoint_path=<file>`.
    pub checkpoint_path: Option<std::path::PathBuf>,
    /// Resume from `checkpoint_path` if a usable snapshot exists
    /// (`resume=true`); otherwise start fresh.
    pub resume: bool,
    /// Pin engine worker `w` to core `w % cores` (`pin=true`; Linux
    /// only, no-op elsewhere). A locality hint — results are identical
    /// either way.
    pub pin: bool,
    /// Per-run deadline in milliseconds (0 = none). Enforced at round
    /// boundaries: an expired run fails with a "deadline exceeded"
    /// message through the normal failure path, never a hard kill.
    pub timeout_ms: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            cache_mb: 64,
            io_threads: 4,
            io_delay_us: 0,
            max_run_pages: 256,
            workers: 0,
            batch: 1024,
            transport: TransportMode::Auto,
            mode: RunMode::Push,
            pull_density: 0.125,
            fetch_window: 2,
            alpha: 0.85,
            threshold: 1e-10,
            seed: 42,
            cancel: None,
            trace: TraceMode::Off,
            checkpoint_every: 0,
            checkpoint_path: None,
            resume: false,
            pin: false,
            timeout_ms: 0,
        }
    }
}

impl RunConfig {
    /// Apply one `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> crate::Result<()> {
        let v = value.trim();
        match key.trim() {
            "cache_mb" => self.cache_mb = v.parse().context("cache_mb")?,
            "io_threads" => self.io_threads = v.parse().context("io_threads")?,
            "io_delay_us" => self.io_delay_us = v.parse().context("io_delay_us")?,
            "max_run_pages" => self.max_run_pages = v.parse().context("max_run_pages")?,
            "workers" => self.workers = v.parse().context("workers")?,
            "batch" => self.batch = v.parse().context("batch")?,
            "transport" => {
                self.transport = match v {
                    "auto" => TransportMode::Auto,
                    "queue" => TransportMode::Queue,
                    other => bail!("transport must be 'auto' or 'queue', got '{other}'"),
                }
            }
            "mode" => {
                self.mode = match v {
                    "push" => RunMode::Push,
                    "pull" => RunMode::Pull,
                    "auto" => RunMode::Auto,
                    other => bail!("mode must be push/pull/auto, got '{other}'"),
                }
            }
            "pull_density" => self.pull_density = v.parse().context("pull_density")?,
            "fetch_window" => self.fetch_window = v.parse().context("fetch_window")?,
            "alpha" => self.alpha = v.parse().context("alpha")?,
            "threshold" => self.threshold = v.parse().context("threshold")?,
            "seed" => self.seed = v.parse().context("seed")?,
            "checkpoint_every" => {
                self.checkpoint_every = v.parse().context("checkpoint_every")?
            }
            "checkpoint_path" => {
                self.checkpoint_path =
                    if v.is_empty() { None } else { Some(std::path::PathBuf::from(v)) }
            }
            "resume" => {
                self.resume = match v {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("resume must be true/false, got '{other}'"),
                }
            }
            "pin" => {
                self.pin = match v {
                    "true" | "1" | "on" => true,
                    "false" | "0" | "off" => false,
                    other => bail!("pin must be true/false, got '{other}'"),
                }
            }
            "timeout_ms" => self.timeout_ms = v.parse().context("timeout_ms")?,
            "trace" => {
                self.trace = match v {
                    "off" | "false" | "0" => TraceMode::Off,
                    "on" | "table" | "true" | "1" => TraceMode::Table,
                    "json" => TraceMode::Json,
                    other => bail!("trace must be off/on/table/json, got '{other}'"),
                }
            }
            other => bail!("unknown config key: {other}"),
        }
        Ok(())
    }

    /// Load `key=value` lines (`#` comments, blank lines ok).
    pub fn load(path: &Path) -> crate::Result<Self> {
        let mut cfg = RunConfig::default();
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("{}:{}: expected key=value", path.display(), lineno + 1);
            };
            cfg.set(k, v)?;
        }
        Ok(cfg)
    }

    /// Engine configuration slice.
    pub fn engine(&self) -> EngineConfig {
        let mut e = EngineConfig::default();
        if self.workers > 0 {
            e.workers = self.workers;
        }
        e.batch = self.batch;
        e.transport = self.transport;
        e.mode = self.mode;
        e.pull_density = self.pull_density;
        e.fetch_window = self.fetch_window;
        e.cancel = self.cancel.clone();
        e.trace = self.trace.enabled();
        e.checkpoint_every = self.checkpoint_every;
        e.checkpoint_path = self.checkpoint_path.clone();
        e.resume = self.resume;
        e.pin_workers = self.pin;
        if self.timeout_ms > 0 {
            e.deadline = Some(
                std::time::Instant::now()
                    + std::time::Duration::from_millis(self.timeout_ms),
            );
        }
        e
    }

    /// SAFS I/O configuration slice.
    pub fn io(&self) -> IoConfig {
        IoConfig {
            threads: self.io_threads,
            io_delay_us: self.io_delay_us,
            max_run_pages: self.max_run_pages,
            fault: None,
        }
    }

    /// Page-cache capacity in bytes.
    pub fn cache_bytes(&self) -> usize {
        self.cache_mb * 1024 * 1024
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let mut c = RunConfig::default();
        assert_eq!(c.cache_mb, 64);
        c.set("cache_mb", "8").unwrap();
        c.set("alpha", "0.9").unwrap();
        assert_eq!(c.cache_mb, 8);
        assert!((c.alpha - 0.9).abs() < 1e-12);
        assert_eq!(c.transport, TransportMode::Auto);
        c.set("transport", "queue").unwrap();
        assert_eq!(c.transport, TransportMode::Queue);
        assert_eq!(c.engine().transport, TransportMode::Queue);
        c.set("transport", "auto").unwrap();
        assert_eq!(c.transport, TransportMode::Auto);
        assert!(c.set("transport", "carrier-pigeon").is_err());
        assert!(c.set("nonsense", "1").is_err());
        assert!(c.set("cache_mb", "abc").is_err());
        assert_eq!(c.trace, TraceMode::Off);
        assert!(!c.engine().trace);
        c.set("trace", "on").unwrap();
        assert_eq!(c.trace, TraceMode::Table);
        assert!(c.engine().trace);
        c.set("trace", "json").unwrap();
        assert_eq!(c.trace, TraceMode::Json);
        c.set("trace", "off").unwrap();
        assert_eq!(c.trace, TraceMode::Off);
        assert!(c.set("trace", "loud").is_err());
        assert_eq!(c.mode, RunMode::Push);
        c.set("mode", "auto").unwrap();
        assert_eq!(c.mode, RunMode::Auto);
        assert_eq!(c.engine().mode, RunMode::Auto);
        c.set("mode", "pull").unwrap();
        assert_eq!(c.mode, RunMode::Pull);
        c.set("mode", "push").unwrap();
        assert_eq!(c.mode, RunMode::Push);
        assert!(c.set("mode", "sideways").is_err());
        assert!((c.pull_density - 0.125).abs() < 1e-12);
        c.set("pull_density", "0.25").unwrap();
        assert!((c.engine().pull_density - 0.25).abs() < 1e-12);
        assert_eq!(c.fetch_window, 2);
        c.set("fetch_window", "0").unwrap();
        assert_eq!(c.fetch_window, 0);
        assert_eq!(c.engine().fetch_window, 0);
        assert!(c.set("fetch_window", "many").is_err());
        assert_eq!(c.checkpoint_every, 0);
        assert!(c.checkpoint_path.is_none());
        assert!(!c.resume);
        c.set("checkpoint_every", "4").unwrap();
        c.set("checkpoint_path", "/tmp/job.ckpt").unwrap();
        c.set("resume", "true").unwrap();
        let e = c.engine();
        assert_eq!(e.checkpoint_every, 4);
        assert_eq!(e.checkpoint_path.as_deref(), Some(std::path::Path::new("/tmp/job.ckpt")));
        assert!(e.resume);
        assert!(c.set("resume", "maybe").is_err());
        c.set("resume", "off").unwrap();
        c.set("checkpoint_every", "0").unwrap();
        assert!(!c.pin);
        assert!(!c.engine().pin_workers);
        c.set("pin", "true").unwrap();
        assert!(c.pin);
        assert!(c.engine().pin_workers);
        c.set("pin", "off").unwrap();
        assert!(!c.pin);
        assert!(c.set("pin", "sideways").is_err());
        assert_eq!(c.timeout_ms, 0);
        assert!(c.engine().deadline.is_none());
        c.set("timeout_ms", "1500").unwrap();
        assert_eq!(c.timeout_ms, 1500);
        let d = c.engine().deadline.expect("deadline set");
        assert!(d > std::time::Instant::now());
        assert!(c.set("timeout_ms", "soon").is_err());
        c.set("timeout_ms", "0").unwrap();
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir().join(format!("graphyti-cfg-{}", std::process::id()));
        std::fs::write(&path, "# comment\ncache_mb = 16\n\nio_delay_us=50\nworkers=2\n").unwrap();
        let c = RunConfig::load(&path).unwrap();
        assert_eq!(c.cache_mb, 16);
        assert_eq!(c.io_delay_us, 50);
        assert_eq!(c.workers, 2);
        assert_eq!(c.engine().workers, 2);
        assert_eq!(c.io().io_delay_us, 50);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn bad_file_line_reports_error() {
        let path = std::env::temp_dir().join(format!("graphyti-cfg-bad-{}", std::process::id()));
        std::fs::write(&path, "cache_mb\n").unwrap();
        assert!(RunConfig::load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
