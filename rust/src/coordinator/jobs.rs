//! Job dispatch: open a graph (SEM or in-memory) and run any library
//! algorithm by spec, returning a uniform [`JobOutput`].

use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::algs::bc::{betweenness, BcVariant};
use crate::algs::bfs::bfs;
use crate::algs::coreness::{coreness, CorenessOptions};
use crate::algs::degree::{degree_stats, top_k_by_degree};
use crate::algs::diameter::{estimate_diameter, DiameterVariant};
use crate::algs::louvain::{louvain, LouvainMode};
use crate::algs::pagerank::{pagerank_pull, pagerank_push};
use crate::algs::scan_stat::scan_statistic;
use crate::algs::sssp::sssp;
use crate::algs::triangles::{triangles, TriangleOptions};
use crate::algs::wcc::wcc;
use crate::coordinator::config::RunConfig;
use crate::engine::RunReport;
use crate::graph::format::{ChecksumFooter, GraphIndex, CHECKSUM_PAGE};
use crate::graph::source::{EdgeSource, MemGraph, SemGraph};
use crate::VertexId;

/// How to open the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphMode {
    /// Semi-external: index in RAM, adjacency behind the page cache.
    Sem,
    /// Fully in-memory baseline.
    Mem,
}

/// Open `<base>.gy-idx/.gy-adj` in the requested mode.
pub fn open_graph(
    base: &Path,
    mode: GraphMode,
    cfg: &RunConfig,
) -> crate::Result<Box<dyn EdgeSource>> {
    match mode {
        GraphMode::Sem => {
            Ok(Box::new(SemGraph::open(base, cfg.cache_bytes(), cfg.io())?))
        }
        GraphMode::Mem => {
            // load the packed image straight into RAM
            let idx_bytes = std::fs::read(base.with_extension("gy-idx"))?;
            let index = GraphIndex::decode(&idx_bytes)?;
            let adj_path = base.with_extension("gy-adj");
            let mut adj = std::fs::read(&adj_path)?;
            if index.header().checksums {
                // the whole image is being loaded anyway: verify every
                // page now, then drop the footer so the RAM image is
                // byte-identical to one built without checksums
                let footer = ChecksumFooter::from_bytes(&adj)
                    .with_context(|| format!("checksum footer of {}", adj_path.display()))?;
                for p in 0..footer.npages() {
                    ensure!(
                        footer.page_ok(p, &adj[p as usize * CHECKSUM_PAGE..]),
                        "checksum mismatch on page {p} of {}",
                        adj_path.display()
                    );
                }
                adj.truncate(footer.data_len as usize);
            }
            Ok(Box::new(MemGraph::from_image(crate::graph::builder::RamImage {
                index,
                adj,
            })))
        }
    }
}

/// An algorithm + variant selection.
#[derive(Debug, Clone)]
pub enum AlgSpec {
    /// PR-push (Graphyti §4.1).
    PageRankPush,
    /// PR-pull (Pregel/Turi baseline).
    PageRankPull,
    /// k-core decomposition with options (§4.2).
    Coreness(CorenessOptions),
    /// Diameter estimation (§4.3).
    Diameter {
        /// Pseudo-peripheral sweeps (≤ 64).
        sweeps: usize,
        /// Uni- or multi-source.
        variant: DiameterVariant,
    },
    /// Betweenness centrality (§4.4).
    Bc {
        /// Number of sources (picked by descending degree).
        num_sources: usize,
        /// Execution variant.
        variant: BcVariant,
    },
    /// Triangle counting (§4.5).
    Triangles(TriangleOptions),
    /// Louvain communities (§4.6).
    Louvain(LouvainMode),
    /// BFS levels from a source.
    Bfs {
        /// Source vertex.
        src: VertexId,
    },
    /// Weakly connected components.
    Wcc,
    /// Shortest paths (synthetic weights) from a source.
    Sssp {
        /// Source vertex.
        src: VertexId,
    },
    /// Degree statistics (no I/O).
    Degree,
    /// Scan-1 locality statistic (undirected images).
    ScanStat,
}

impl AlgSpec {
    /// Parse an algorithm name + optional variant string from the CLI.
    pub fn parse(name: &str, variant: &str, num: usize) -> crate::Result<AlgSpec> {
        Ok(match (name, variant) {
            ("pagerank", "" | "push") => AlgSpec::PageRankPush,
            ("pagerank", "pull") => AlgSpec::PageRankPull,
            ("coreness", "" | "graphyti") => AlgSpec::Coreness(CorenessOptions::graphyti()),
            ("coreness", "pruned") => AlgSpec::Coreness(CorenessOptions::pruned()),
            ("coreness", "unopt") => AlgSpec::Coreness(CorenessOptions::unoptimized()),
            ("diameter", "" | "multi") => AlgSpec::Diameter {
                sweeps: num.clamp(1, 64),
                variant: DiameterVariant::MultiSource,
            },
            ("diameter", "uni") => AlgSpec::Diameter {
                sweeps: num.clamp(1, 64),
                variant: DiameterVariant::UniSource,
            },
            ("bc", "" | "async") => AlgSpec::Bc {
                num_sources: num.max(1),
                variant: BcVariant::MultiSourceAsync,
            },
            ("bc", "sync") => AlgSpec::Bc {
                num_sources: num.max(1),
                variant: BcVariant::MultiSourceSync,
            },
            ("bc", "uni") => AlgSpec::Bc {
                num_sources: num.max(1),
                variant: BcVariant::UniSource,
            },
            ("triangles", "" | "graphyti") => AlgSpec::Triangles(TriangleOptions::graphyti()),
            ("triangles", "naive") => AlgSpec::Triangles(TriangleOptions::naive()),
            ("louvain", "" | "graphyti") => AlgSpec::Louvain(LouvainMode::Graphyti),
            ("louvain", "physical") => AlgSpec::Louvain(LouvainMode::Physical),
            ("bfs", _) => AlgSpec::Bfs { src: num as VertexId },
            ("wcc", _) => AlgSpec::Wcc,
            ("sssp", _) => AlgSpec::Sssp { src: num as VertexId },
            ("degree", _) => AlgSpec::Degree,
            ("scan", _) => AlgSpec::ScanStat,
            (n, v) => bail!("unknown algorithm/variant: {n}/{v}"),
        })
    }
}

/// What a job produced.
pub struct JobOutput {
    /// Human-readable result summary.
    pub summary: String,
    /// Engine report (None for index-only jobs like `degree`).
    pub report: Option<RunReport>,
}

/// Run an algorithm spec against an open graph.
pub fn run_alg(source: &dyn EdgeSource, spec: &AlgSpec, cfg: &RunConfig) -> JobOutput {
    let ecfg = cfg.engine();
    match spec {
        AlgSpec::PageRankPush => {
            let r = pagerank_push(source, cfg.alpha, cfg.threshold, &ecfg);
            let top = top_indices(&r.rank, 5);
            JobOutput {
                summary: format!("pagerank(push): top5 {:?}", top),
                report: Some(r.report),
            }
        }
        AlgSpec::PageRankPull => {
            let r = pagerank_pull(source, cfg.alpha, cfg.threshold, 500, &ecfg);
            let top = top_indices(&r.rank, 5);
            JobOutput {
                summary: format!("pagerank(pull): top5 {:?}", top),
                report: Some(r.report),
            }
        }
        AlgSpec::Coreness(opts) => {
            let r = coreness(source, *opts, &ecfg);
            let kmax = r.core.iter().copied().max().unwrap_or(0);
            JobOutput { summary: format!("coreness: k_max={kmax}"), report: Some(r.report) }
        }
        AlgSpec::Diameter { sweeps, variant } => {
            let r = estimate_diameter(source, *sweeps, *variant, &ecfg);
            JobOutput {
                summary: format!(
                    "diameter({variant:?}): estimate={} from {} sweeps",
                    r.diameter,
                    r.sources.len()
                ),
                report: Some(r.report),
            }
        }
        AlgSpec::Bc { num_sources, variant } => {
            let sources = top_k_by_degree(source.index(), *num_sources);
            let r = betweenness(source, &sources, *variant, &ecfg);
            let top = top_indices(&r.bc, 5);
            JobOutput {
                summary: format!("bc({variant:?}, {} sources): top5 {:?}", sources.len(), top),
                report: Some(r.report),
            }
        }
        AlgSpec::Triangles(opts) => {
            let r = triangles(source, *opts, &ecfg);
            JobOutput {
                summary: format!("triangles: {}", r.triangles),
                report: Some(r.report),
            }
        }
        AlgSpec::Louvain(mode) => {
            let r = louvain(source, *mode, 10, &ecfg);
            let ncomm = {
                let mut c = r.community.clone();
                c.sort_unstable();
                c.dedup();
                c.len()
            };
            JobOutput {
                summary: format!(
                    "louvain({mode:?}): {} communities, Q={:.4}, {} levels (local {} / agg {})",
                    ncomm,
                    r.modularity,
                    r.levels,
                    crate::util::fmt_dur(r.local_move_wall),
                    crate::util::fmt_dur(r.aggregate_wall),
                ),
                report: Some(r.report),
            }
        }
        AlgSpec::Bfs { src } => {
            let (levels, report) = bfs(source, *src, &ecfg);
            let reached = levels.iter().filter(|&&l| l >= 0).count();
            let depth = levels.iter().copied().max().unwrap_or(0);
            JobOutput {
                summary: format!("bfs(src={src}): reached {reached}, depth {depth}"),
                report: Some(report),
            }
        }
        AlgSpec::Wcc => {
            let (labels, report) = wcc(source, &ecfg);
            let ncomp = {
                let mut l = labels.clone();
                l.sort_unstable();
                l.dedup();
                l.len()
            };
            JobOutput { summary: format!("wcc: {ncomp} components"), report: Some(report) }
        }
        AlgSpec::Sssp { src } => {
            let (dist, report) = sssp(source, *src, &ecfg);
            let reached = dist.iter().filter(|&&d| d != u64::MAX).count();
            JobOutput {
                summary: format!("sssp(src={src}): reached {reached}"),
                report: Some(report),
            }
        }
        AlgSpec::ScanStat => {
            let (_, max, report) = scan_statistic(source, &ecfg);
            JobOutput {
                summary: format!("scan-stat: max SS(v{}) = {}", max.0, max.1),
                report: Some(report),
            }
        }
        AlgSpec::Degree => {
            let s = degree_stats(source.index());
            JobOutput {
                summary: format!(
                    "degree: mean {:.2}, max {} at v{}, p99 {}",
                    s.mean,
                    s.max.1,
                    s.max.0,
                    s.hist.quantile(0.99)
                ),
                report: None,
            }
        }
    }
}

fn top_indices(xs: &[f64], k: usize) -> Vec<VertexId> {
    let mut idx: Vec<VertexId> = (0..xs.len() as VertexId).collect();
    idx.sort_by(|&a, &b| {
        xs[b as usize].partial_cmp(&xs[a as usize]).unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn build(tag: &str, directed: bool) -> std::path::PathBuf {
        let base =
            std::env::temp_dir().join(format!("graphyti-jobs-{}-{tag}", std::process::id()));
        let edges = gen::rmat(8, 1500, 7);
        let mut b = GraphBuilder::new(256, directed);
        b.add_edges(&edges);
        b.build_files(&base).unwrap();
        base
    }

    #[test]
    fn sem_and_mem_modes_agree_on_results() {
        let base = build("modes", true);
        let cfg = RunConfig { cache_mb: 1, ..Default::default() };
        let sem = open_graph(&base, GraphMode::Sem, &cfg).unwrap();
        let mem = open_graph(&base, GraphMode::Mem, &cfg).unwrap();
        for spec in [AlgSpec::PageRankPush, AlgSpec::Wcc, AlgSpec::Bfs { src: 0 }] {
            let a = run_alg(sem.as_ref(), &spec, &cfg);
            let b = run_alg(mem.as_ref(), &spec, &cfg);
            assert_eq!(a.summary, b.summary, "{spec:?}");
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn spec_parsing() {
        assert!(matches!(AlgSpec::parse("pagerank", "", 0).unwrap(), AlgSpec::PageRankPush));
        assert!(matches!(AlgSpec::parse("pagerank", "pull", 0).unwrap(), AlgSpec::PageRankPull));
        assert!(matches!(
            AlgSpec::parse("bc", "uni", 4).unwrap(),
            AlgSpec::Bc { num_sources: 4, variant: BcVariant::UniSource }
        ));
        assert!(matches!(
            AlgSpec::parse("diameter", "multi", 8).unwrap(),
            AlgSpec::Diameter { sweeps: 8, variant: DiameterVariant::MultiSource }
        ));
        assert!(AlgSpec::parse("bogus", "", 0).is_err());
    }

    #[test]
    fn degree_job_runs_without_io() {
        let base = build("deg", true);
        let cfg = RunConfig::default();
        let sem = open_graph(&base, GraphMode::Sem, &cfg).unwrap();
        let out = run_alg(sem.as_ref(), &AlgSpec::Degree, &cfg);
        assert!(out.summary.starts_with("degree:"));
        assert!(out.report.is_none());
        assert_eq!(sem.io_stats().snapshot().bytes_read, 0, "degree must not touch disk");
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }
}
