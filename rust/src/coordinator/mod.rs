//! The coordinator: configuration, job dispatch and reporting — the
//! layer a user of the library (or the `graphyti` CLI) talks to.
//!
//! * [`config`] — the run configuration system: `key=value` config files
//!   with CLI-style overrides, covering the SEM knobs (cache size, I/O
//!   threads, injected latency) and engine knobs (workers, batch size).
//! * [`jobs`] — graph opening (SEM or in-memory) and algorithm dispatch
//!   by name/variant, returning uniform [`jobs::JobOutput`]s.
//! * [`report`] — aligned-table formatting for figure harnesses and the
//!   CLI.

pub mod benchkit;
pub mod config;
pub mod jobs;
pub mod report;

pub use config::{RunConfig, TraceMode};
pub use jobs::{open_graph, run_alg, AlgSpec, GraphMode, JobOutput};
pub use report::Table;
