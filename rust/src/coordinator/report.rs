//! Aligned-table formatting for figure harnesses and the CLI.

/// A simple text table.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header count).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "22".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a  "));
        // columns aligned: "value" column starts at same offset
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[2][col..col + 1], "1");
        assert_eq!(&lines[3][col..col + 2], "22");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_bad_width() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
