//! Message transport: per-worker outboxes flushing into double-buffered
//! per-worker inboxes.
//!
//! A **point-to-point** send is one `(dst, msg)` tuple. A **multicast**
//! send is a *single* queue entry per destination worker carrying a
//! shared destination slice — one allocation and one queue slot for the
//! whole fan-out, which is why multicast is cheaper per destination
//! (paper §4.2). Message counters distinguish the two so benches can
//! report messaging volume the way Figure 3 does.

use std::sync::{Arc, Mutex};

use crate::VertexId;

/// One inbox entry.
pub enum Delivery<M> {
    /// Point-to-point message.
    P2p(VertexId, M),
    /// Multicast: one shared payload for many destinations (all owned by
    /// the receiving worker).
    Multi(Arc<[VertexId]>, M),
}

impl<M> Delivery<M> {
    /// Number of `run_on_message` calls this entry will produce.
    pub fn fanout(&self) -> usize {
        match self {
            Delivery::P2p(..) => 1,
            Delivery::Multi(dsts, _) => dsts.len(),
        }
    }
}

/// Double-buffered inboxes: `bufs[parity][worker]`. Messages sent during
/// round `r` land in parity `(r + 1) % 2` and are drained in round `r+1`.
pub struct Inboxes<M> {
    bufs: [Vec<Mutex<Vec<Delivery<M>>>>; 2],
}

impl<M> Inboxes<M> {
    /// Build for `workers` workers.
    pub fn new(workers: usize) -> Self {
        let mk = || (0..workers).map(|_| Mutex::new(Vec::new())).collect();
        Inboxes { bufs: [mk(), mk()] }
    }

    /// Append deliveries for `worker` into parity `p`.
    pub fn push(&self, p: usize, worker: usize, items: &mut Vec<Delivery<M>>) {
        let mut q = self.bufs[p][worker].lock().unwrap();
        q.append(items);
    }

    /// Take the whole inbox of `worker` at parity `p`.
    pub fn take(&self, p: usize, worker: usize) -> Vec<Delivery<M>> {
        std::mem::take(&mut *self.bufs[p][worker].lock().unwrap())
    }

    /// Total queued deliveries (entries, not fanout) at parity `p`.
    pub fn pending(&self, p: usize) -> usize {
        self.bufs[p].iter().map(|q| q.lock().unwrap().len()).sum()
    }
}

/// A worker's staging buffers, one per destination worker; flushed into
/// the shared inboxes when large or at phase end.
pub struct Outbox<M> {
    staged: Vec<Vec<Delivery<M>>>,
    /// Flush threshold per destination worker.
    flush_at: usize,
}

impl<M> Outbox<M> {
    /// Build for `workers` destination workers.
    pub fn new(workers: usize, flush_at: usize) -> Self {
        Outbox { staged: (0..workers).map(|_| Vec::new()).collect(), flush_at }
    }

    /// Stage a p2p message; returns destination workers needing a flush.
    #[inline]
    pub fn send(&mut self, dst_worker: usize, dst: VertexId, msg: M) -> bool {
        let q = &mut self.staged[dst_worker];
        q.push(Delivery::P2p(dst, msg));
        q.len() >= self.flush_at
    }

    /// Stage a multicast slice for one destination worker.
    #[inline]
    pub fn multicast(&mut self, dst_worker: usize, dsts: Arc<[VertexId]>, msg: M) -> bool {
        let q = &mut self.staged[dst_worker];
        q.push(Delivery::Multi(dsts, msg));
        q.len() >= self.flush_at
    }

    /// Flush one destination worker's staging buffer.
    pub fn flush_one(&mut self, inboxes: &Inboxes<M>, parity: usize, dst_worker: usize) {
        if !self.staged[dst_worker].is_empty() {
            inboxes.push(parity, dst_worker, &mut self.staged[dst_worker]);
        }
    }

    /// Flush everything.
    pub fn flush_all(&mut self, inboxes: &Inboxes<M>, parity: usize) {
        for w in 0..self.staged.len() {
            self.flush_one(inboxes, parity, w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn p2p_roundtrip() {
        let inboxes: Inboxes<u32> = Inboxes::new(2);
        let mut out = Outbox::new(2, 1000);
        out.send(1, 7, 99);
        out.send(0, 3, 42);
        out.flush_all(&inboxes, 0);
        let w1 = inboxes.take(0, 1);
        assert_eq!(w1.len(), 1);
        match &w1[0] {
            Delivery::P2p(v, m) => {
                assert_eq!((*v, *m), (7, 99));
            }
            _ => panic!("expected p2p"),
        }
        assert_eq!(inboxes.pending(0), 1); // worker 0 still queued
        assert_eq!(inboxes.pending(1), 0);
    }

    #[test]
    fn multicast_single_entry_fanout() {
        let inboxes: Inboxes<u8> = Inboxes::new(1);
        let mut out = Outbox::new(1, 1000);
        let dsts: Arc<[VertexId]> = Arc::from(vec![1, 2, 3, 4].into_boxed_slice());
        out.multicast(0, dsts, 5);
        out.flush_all(&inboxes, 1);
        let got = inboxes.take(1, 0);
        assert_eq!(got.len(), 1, "one queue slot for the whole fanout");
        assert_eq!(got[0].fanout(), 4);
    }

    #[test]
    fn flush_threshold_signals() {
        let mut out: Outbox<u8> = Outbox::new(1, 2);
        assert!(!out.send(0, 0, 0));
        assert!(out.send(0, 1, 0), "hit threshold");
    }

    #[test]
    fn parity_separation() {
        let inboxes: Inboxes<u8> = Inboxes::new(1);
        let mut out = Outbox::new(1, 1000);
        out.send(0, 0, 1);
        out.flush_all(&inboxes, 0);
        out.send(0, 0, 2);
        out.flush_all(&inboxes, 1);
        assert_eq!(inboxes.take(0, 0).len(), 1);
        assert_eq!(inboxes.take(1, 0).len(), 1);
        assert_eq!(inboxes.take(0, 0).len(), 0, "take drains");
    }
}
