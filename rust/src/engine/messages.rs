//! Message transport: two lane disciplines selected per program.
//!
//! The engine's message phase used to funnel every point-to-point send
//! through a `Mutex<Vec<_>>` inbox — O(m) queue entries per round and a
//! lock convoy exactly where the paper says messaging dominates
//! (§4.2, Fig. 3). This module replaces that with two transports:
//!
//! * **Combiner lanes** ([`CombinerLanes`]) — for programs whose
//!   messages are commutative-associative (PageRank rank mass, WCC/BFS/
//!   SSSP minima, coreness decrement counts, diameter lane bitsets).
//!   The program declares a [`Combiner`]; each send then *folds in
//!   place* into a dense per-sending-worker slab indexed by destination
//!   vertex, with a touched-bitmap so delivery sweeps only written
//!   slots. Message memory is `2 × workers × n` slots **regardless of
//!   how many messages are sent** — O(n), not O(m) — and the hot path
//!   takes no locks and performs no per-message allocation.
//! * **Queue lanes** ([`QueueLanes`]) — for programs whose messages
//!   cannot be folded (BC's lane/phase-tagged path counts, Louvain's
//!   pings). Per-(sender, receiver, parity) SPSC segment queues whose
//!   segments are recycled through a free list across rounds, so
//!   steady-state sends are allocation-free ([`QueueLanes`] counts
//!   segment allocations the way `FetchArena::allocs` counts fetch-path
//!   allocations, and tests assert the counter goes flat once warm).
//!
//! Both transports are wrapped by [`MessagePlane`], which also keeps the
//! per-parity pending counters (one relaxed atomic each — replacing the
//! old lock-every-queue `pending()` scan) and the peak-message-byte /
//! allocation accounting surfaced in `EngineStats`.
//!
//! ## Ownership protocol (why there are no locks)
//!
//! Every lane is written by exactly one worker and read by exactly one
//! worker, in *barrier-separated* rounds:
//!
//! * During round `r`, worker `s` sends at parity `p̄ = (r+1) % 2`,
//!   writing only its own lanes `(p̄, s, ·)`.
//! * During round `r+1` (whose current parity is `p̄`), worker `w`
//!   drains lanes `(p̄, ·, w)` in phase A — after the round-`r` end
//!   barrier published the writes — while round-`r+1` sends go to the
//!   *other* parity.
//! * Recycled queue segments stay inside their `(sender, receiver,
//!   parity)` lane: the receiver frees them during its drain, the
//!   sender reuses them one round later, again barrier-separated.
//!
//! A **point-to-point** send is one `(dst, msg)` tuple. A **multicast**
//! send on the queue transport is a *single* queue entry per destination
//! worker carrying a shared destination slice (paper §4.2); on the
//! combiner transport multicast folds per destination like any other
//! send — the fold *is* the minimize-message-memory mechanism.

use std::cell::UnsafeCell;
use std::mem::size_of;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::{AtomicBitmap, SharedVec};
use crate::VertexId;

/// How the engine moves messages for a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportMode {
    /// Combiner lanes when the program declares a [`Combiner`], queue
    /// lanes otherwise.
    #[default]
    Auto,
    /// Force queue lanes even for combinable programs — the baseline
    /// path, kept selectable for oracle comparisons and benches.
    Queue,
}

/// A commutative-associative fold for a program's message type.
///
/// Declared by [`crate::engine::VertexProgram::combiner`]. When present
/// (and the run is in [`TransportMode::Auto`]), the engine delivers each
/// destination vertex **one** folded message per round instead of one
/// `run_on_message` call per send, and message memory drops from O(m)
/// queue entries to a dense O(n) slab per worker.
///
/// Contract: `combine` must be commutative and associative over the
/// message domain, and `identity` must be a neutral element
/// (`combine(identity, m) == m`). The engine folds in a fixed
/// *structural* order (send order within a sender lane, worker-id
/// order across lanes), so integer folds are bit-stable everywhere.
/// For non-associative-in-floating-point folds like `+`, note that the
/// work-stealing scheduler may assign the same logical send to a
/// different sender lane from run to run: float results are exactly
/// reproducible at `workers = 1` (single lane, ascending delivery) and
/// oracle-tight — not bit-identical — at higher worker counts, same as
/// the queue transport's arrival-order folds before it.
pub struct Combiner<M> {
    /// Neutral element (used to pre-fill the dense slabs).
    pub identity: fn() -> M,
    /// Fold `msg` into the accumulator in place.
    pub combine: fn(&mut M, &M),
}

impl<M> Clone for Combiner<M> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<M> Copy for Combiner<M> {}

/// One queue-lane entry.
pub enum Delivery<M> {
    /// Point-to-point message.
    P2p(VertexId, M),
    /// Multicast: one shared payload for many destinations (all owned by
    /// the receiving worker).
    Multi(Arc<[VertexId]>, M),
}

impl<M> Delivery<M> {
    /// Number of `run_on_message` calls this entry will produce.
    pub fn fanout(&self) -> usize {
        match self {
            Delivery::P2p(..) => 1,
            Delivery::Multi(dsts, _) => dsts.len(),
        }
    }
}

// ------------------------------------------------------ combiner lanes --

/// Dense per-sender message slabs with touched-bitmaps (O(n) transport).
///
/// Layout: `slab[parity][sender][dst]` — `2 × workers` slabs of `n`
/// message slots plus `n`-bit touched maps. A send folds into the
/// sender's own slab (no lock, no allocation); after the phase-A
/// barrier the destination's owner worker sweeps its vertex range of
/// every sender's slab, folds across senders, and delivers one combined
/// message per touched vertex. Memory is fixed at construction —
/// [`CombinerLanes::mem_bytes`] — independent of message count.
pub struct CombinerLanes<M> {
    n: usize,
    combiner: Combiner<M>,
    /// `slabs[parity][sender]`, each `n` slots.
    slabs: [Vec<SharedVec<M>>; 2],
    /// Matching touched maps: bit `v` set ⇔ `slabs[p][s][v]` holds a
    /// live folded message.
    touched: [Vec<AtomicBitmap>; 2],
    /// Two-level sparsity index: bit `w` set ⇔ touched-map word `w`
    /// (64 vertices) may hold live bits. Lets the delivery sweep skip
    /// empty 4096-vertex blocks, so a sparse round (a handful of
    /// messages over a huge graph — think label-correcting SSSP on a
    /// road network) costs ~n/4096 word loads instead of n/64. Set by
    /// the sender on fresh touches, read-only for receivers (a stale
    /// bit costs one wasted 64-word scan, never correctness), cleared
    /// by the sender via [`CombinerLanes::begin_send_round`] one full
    /// round after the receivers finished reading it.
    summary: [Vec<AtomicBitmap>; 2],
}

impl<M: Clone> CombinerLanes<M> {
    /// Build lanes for `workers` senders over `n` vertices.
    pub fn new(workers: usize, n: usize, combiner: Combiner<M>) -> Self {
        let nwords = n.div_ceil(64);
        let mk_slabs = || {
            (0..workers)
                .map(|_| SharedVec::new(n, (combiner.identity)()))
                .collect::<Vec<_>>()
        };
        let mk_maps = |bits: usize| {
            (0..workers).map(|_| AtomicBitmap::new(bits)).collect::<Vec<_>>()
        };
        CombinerLanes {
            n,
            combiner,
            slabs: [mk_slabs(), mk_slabs()],
            touched: [mk_maps(n), mk_maps(n)],
            summary: [mk_maps(nwords), mk_maps(nwords)],
        }
    }

    /// Fixed transport memory: slabs + touched maps + word summaries,
    /// both parities.
    pub fn mem_bytes(&self) -> u64 {
        let nwords = self.n.div_ceil(64);
        let per_lane = self.n * size_of::<M>() + nwords * 8 + nwords.div_ceil(64) * 8;
        (2 * self.slabs[0].len() * per_lane) as u64
    }

    /// Reset `sender`'s word summary for the lane it is about to write
    /// (the runner calls this at the start of each round, before any
    /// sends). Safe because the lane's receivers finished their sweep a
    /// full round — two barriers — earlier, and its touched bits were
    /// all cleared by that sweep.
    pub fn begin_send_round(&self, parity: usize, sender: usize) {
        self.summary[parity][sender].clear_all();
    }

    /// Pre-touch every *untouched* slot of `sender`'s slabs (both
    /// parities) by writing the combiner identity — the value an
    /// untouched slot already logically holds, so this is
    /// state-invisible. Purpose: NUMA first-touch. The slabs were
    /// allocated on the spawning thread before workers existed; for
    /// zero-representable identities the kernel may have handed back
    /// untouched copy-on-write zero pages, and the first *real* write
    /// would fault them in wherever that sender thread happens to run.
    /// A pinned worker calls this once at startup so the faults land on
    /// its own core's node. Touched slots are skipped — a resumed run
    /// restores pending messages into lane 0 before workers spawn, and
    /// those must survive (the runner additionally skips warm-up
    /// entirely on resume, making the skip defense-in-depth).
    ///
    /// Protocol: only worker `sender`, before its first round.
    pub fn warm_lane(&self, sender: usize) {
        for parity in 0..2 {
            let slab = &self.slabs[parity][sender];
            let touched = &self.touched[parity][sender];
            for v in 0..self.n {
                if !touched.get(v) {
                    *slab.get_mut(v) = (self.combiner.identity)();
                }
            }
        }
    }

    /// Fold `msg` toward `dst` into `sender`'s lane at `parity`.
    /// Returns `true` when the slot was fresh (a new pending delivery),
    /// `false` when the send combined into an existing one.
    ///
    /// Protocol: only worker `sender` may call this for its own lane,
    /// and only during the round whose sends target `parity`.
    #[inline]
    pub fn send(&self, parity: usize, sender: usize, dst: VertexId, msg: &M) -> bool {
        let slot = self.slabs[parity][sender].get_mut(dst as usize);
        if self.touched[parity][sender].set(dst as usize) {
            // fresh slot: the message *is* the fold so far (identity ∘ m)
            *slot = msg.clone();
            // mark the 64-vertex word dirty in the sparsity index (load
            // first: the common repeated case stays RMW-free)
            let sw = dst as usize / 64;
            let summary = &self.summary[parity][sender];
            if !summary.get(sw) {
                summary.set(sw);
            }
            true
        } else {
            (self.combiner.combine)(slot, msg);
            false
        }
    }

    /// Sweep destination vertices `[lo, hi)` of every sender's lane at
    /// `parity`, fold across senders, call `f(v, combined)` once per
    /// touched vertex (ascending `v`), and clear the touched bits.
    ///
    /// The sweep is driven by the word-summary index, so its cost
    /// scales with the number of *dirty 64-vertex words*, not with `n`:
    /// a sparse round over a huge graph skips whole 4096-vertex blocks
    /// with one summary-word load per lane.
    ///
    /// `lane_words` is caller-owned scratch (one slot per sender lane,
    /// reused across calls so the sweep allocates nothing once warm).
    ///
    /// Protocol: only the owner worker of `[lo, hi)` may sweep it, in
    /// the round *after* the lanes were written (barrier-separated);
    /// `f` may send — sends target the other parity, never these lanes.
    pub fn deliver(
        &self,
        parity: usize,
        lo: usize,
        hi: usize,
        lane_words: &mut Vec<u64>,
        mut f: impl FnMut(VertexId, &M),
    ) {
        if lo >= hi {
            return;
        }
        let slabs = &self.slabs[parity];
        let touched = &self.touched[parity];
        let summary = &self.summary[parity];
        let first_word = lo / 64;
        let last_word = (hi - 1) / 64;
        for swi in first_word / 64..=last_word / 64 {
            // level 1: which 64-vertex words of this 4096-vertex block
            // are dirty in ANY lane (restricted to the owned words)
            let sbase = swi * 64;
            let s_lo = if sbase < first_word { !0u64 << (first_word - sbase) } else { !0 };
            let s_hi = if sbase + 64 > last_word + 1 {
                !0u64 >> (sbase + 64 - (last_word + 1))
            } else {
                !0
            };
            let mut dirty_words = 0u64;
            for t in summary {
                dirty_words |= t.word(swi);
            }
            dirty_words &= s_lo & s_hi;
            while dirty_words != 0 {
                let wb = dirty_words.trailing_zeros() as usize;
                dirty_words &= dirty_words - 1;
                let wi = sbase + wb;
                // level 2: the touched word itself
                let base = wi * 64;
                // restrict to the owned [lo, hi) bits of this word
                let lo_mask = if base < lo { !0u64 << (lo - base) } else { !0 };
                let hi_mask = if base + 64 > hi { !0u64 >> (base + 64 - hi) } else { !0 };
                let range_mask = lo_mask & hi_mask;
                lane_words.clear();
                let mut union = 0u64;
                for t in touched {
                    let w = t.word(wi) & range_mask;
                    lane_words.push(w);
                    union |= w;
                }
                if union == 0 {
                    continue; // stale summary bit: one wasted word load
                }
                // prefetch each lane's first touched slot of this word
                // before the fold walks them: the slab addresses depend
                // on bits just computed, a stride no hardware prefetcher
                // predicts, and with several sender lanes the fold is a
                // chain of dependent cold loads without this
                for (s, &w) in lane_words.iter().enumerate() {
                    if w != 0 {
                        crate::util::prefetch_read(
                            slabs[s].get(base + w.trailing_zeros() as usize),
                        );
                    }
                }
                let mut bits = union;
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    let v = base + b;
                    // fold across senders in worker-id order (bit-stable
                    // for integer folds; see the Combiner float caveat)
                    let mut acc: Option<M> = None;
                    for (s, &w) in lane_words.iter().enumerate() {
                        if w & (1 << b) != 0 {
                            let m = slabs[s].get(v);
                            match &mut acc {
                                None => acc = Some(m.clone()),
                                Some(a) => (self.combiner.combine)(a, m),
                            }
                        }
                    }
                    let msg = acc.expect("touched bit with no sender lane set");
                    f(v as VertexId, &msg);
                }
                for (s, &w) in lane_words.iter().enumerate() {
                    if w != 0 {
                        // atomic: boundary words are shared with the
                        // neighboring owner's range
                        touched[s].clear_word_bits(wi, w);
                    }
                }
            }
        }
    }

    /// Non-destructive snapshot of every undelivered fold staged at
    /// `parity`: for each touched destination, fold across sender lanes
    /// in worker-id order — the same structural order [`deliver`] uses,
    /// so a checkpointed fold is bit-identical to what delivery would
    /// have produced — and return `(dst, folded)` pairs in ascending
    /// destination order. Lane state is left untouched.
    ///
    /// Protocol: single-threaded quiescent points only (the runner's
    /// worker-0 bookkeeping step), when no sender is writing `parity`.
    ///
    /// [`deliver`]: CombinerLanes::deliver
    pub fn fold_pending(&self, parity: usize) -> Vec<(VertexId, M)> {
        let slabs = &self.slabs[parity];
        let touched = &self.touched[parity];
        let mut out = Vec::new();
        let nwords = self.n.div_ceil(64);
        for wi in 0..nwords {
            let mut union = 0u64;
            for t in touched {
                union |= t.word(wi);
            }
            let mut bits = union;
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let v = wi * 64 + b;
                let mut acc: Option<M> = None;
                for (s, t) in touched.iter().enumerate() {
                    if t.word(wi) & (1 << b) != 0 {
                        let m = slabs[s].get(v);
                        match &mut acc {
                            None => acc = Some(m.clone()),
                            Some(a) => (self.combiner.combine)(a, m),
                        }
                    }
                }
                if let Some(m) = acc {
                    out.push((v as VertexId, m));
                }
            }
        }
        out
    }

    /// Re-seed lane 0 at `parity` with checkpointed folds: slab slot,
    /// touched bit and summary bit per entry, exactly as if worker 0
    /// had sent each message. Because [`deliver`] folds a single lane's
    /// slot verbatim, restoring the pre-folded values into one lane
    /// reproduces the delivery the interrupted run would have made.
    ///
    /// Protocol: single-threaded, before workers are spawned.
    ///
    /// [`deliver`]: CombinerLanes::deliver
    pub fn restore_pending(&self, parity: usize, entries: impl IntoIterator<Item = (VertexId, M)>) {
        for (dst, m) in entries {
            let v = dst as usize;
            self.slabs[parity][0].set(v, m);
            self.touched[parity][0].set(v);
            self.summary[parity][0].set(v / 64);
        }
    }
}

// --------------------------------------------------------- queue lanes --

/// One `(sender, receiver, parity)` SPSC lane: filled segments awaiting
/// drain, the segment being filled, and drained empties for reuse.
struct SegQueue<M> {
    full: Vec<Vec<Delivery<M>>>,
    active: Vec<Delivery<M>>,
    free: Vec<Vec<Delivery<M>>>,
}

/// Per-(sender, receiver) segment queues for non-combinable programs.
///
/// Replaces the old `Mutex<Vec<Delivery>>` inboxes: a send appends to a
/// lane only its sender touches this round, a drain reads a lane only
/// its receiver touches this round (see the module docs for the barrier
/// protocol), so the hot path takes no locks. Segments are fixed-
/// capacity `Vec`s recycled through a per-lane free list across rounds;
/// [`QueueLanes::allocs`] counts segment allocations so tests can
/// assert steady-state sends allocate nothing once warm.
pub struct QueueLanes<M> {
    workers: usize,
    seg_cap: usize,
    /// `lanes[parity][sender * workers + receiver]`.
    lanes: [Vec<UnsafeCell<SegQueue<M>>>; 2],
    allocs: AtomicU64,
    seg_bytes: AtomicU64,
}

// Safety: interior mutability is gated by the single-writer /
// single-reader barrier protocol documented on the module — the engine
// never lets two threads touch the same (parity, sender, receiver)
// lane in the same round, and rounds are barrier-separated.
unsafe impl<M: Send> Send for QueueLanes<M> {}
unsafe impl<M: Send> Sync for QueueLanes<M> {}

impl<M> QueueLanes<M> {
    /// Build lanes for `workers` workers with `seg_cap` deliveries per
    /// segment.
    pub fn new(workers: usize, seg_cap: usize) -> Self {
        let mk = || {
            (0..workers * workers)
                .map(|_| {
                    UnsafeCell::new(SegQueue {
                        full: Vec::new(),
                        active: Vec::new(),
                        free: Vec::new(),
                    })
                })
                .collect::<Vec<_>>()
        };
        QueueLanes {
            workers,
            seg_cap: seg_cap.max(1),
            lanes: [mk(), mk()],
            allocs: AtomicU64::new(0),
            seg_bytes: AtomicU64::new(0),
        }
    }

    /// Segment allocations so far (flat once every lane is warm).
    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Bytes currently held in allocated segments (segments are never
    /// freed mid-run, so this is also the peak).
    pub fn mem_bytes(&self) -> u64 {
        self.seg_bytes.load(Ordering::Relaxed)
    }

    fn fresh_segment(&self) -> Vec<Delivery<M>> {
        self.allocs.fetch_add(1, Ordering::Relaxed);
        self.seg_bytes
            .fetch_add((self.seg_cap * size_of::<Delivery<M>>()) as u64, Ordering::Relaxed);
        Vec::with_capacity(self.seg_cap)
    }

    /// Append one delivery to lane `(parity, sender, receiver)`.
    ///
    /// Protocol: only worker `sender`, only during the round whose sends
    /// target `parity`.
    #[inline]
    pub fn push(&self, parity: usize, sender: usize, receiver: usize, d: Delivery<M>) {
        let cell = &self.lanes[parity][sender * self.workers + receiver];
        let q = unsafe { &mut *cell.get() };
        if q.active.len() == q.active.capacity() {
            // segment full (or never initialized): hand it off and pull a
            // recycled one — allocation only until the lane is warm
            if q.active.capacity() > 0 {
                let seg = std::mem::take(&mut q.active);
                q.full.push(seg);
            }
            q.active = q.free.pop().unwrap_or_else(|| self.fresh_segment());
        }
        q.active.push(d);
    }

    /// Drain lane `(parity, sender, receiver)` in FIFO order, recycling
    /// every segment into the lane's free list.
    ///
    /// Protocol: only worker `receiver`, in the round after the lane was
    /// written. `f` may send — sends target the other parity, never the
    /// lane being drained.
    pub fn drain(
        &self,
        parity: usize,
        sender: usize,
        receiver: usize,
        mut f: impl FnMut(&Delivery<M>),
    ) {
        let cell = &self.lanes[parity][sender * self.workers + receiver];
        // detach the segments so no lane borrow is held across `f`
        // (handlers re-enter the plane to send at the other parity)
        let (full, mut active) = {
            let q = unsafe { &mut *cell.get() };
            (std::mem::take(&mut q.full), std::mem::take(&mut q.active))
        };
        for seg in &full {
            for d in seg {
                f(d);
            }
        }
        for d in &active {
            f(d);
        }
        active.clear();
        let q = unsafe { &mut *cell.get() };
        for mut seg in full {
            seg.clear();
            q.free.push(seg);
        }
        q.active = active;
    }
}

// ------------------------------------------------------- message plane --

/// The transport behind a [`MessagePlane`].
pub enum Transport<M> {
    /// Dense combiner lanes (program declared a [`Combiner`]).
    Combine(CombinerLanes<M>),
    /// SPSC segment queues (non-combinable messages).
    Queue(QueueLanes<M>),
}

/// One run's message fabric: the selected transport plus the per-parity
/// pending counters and memory/allocation accounting.
///
/// `pending` is a relaxed atomic per parity, batched into by workers at
/// phase ends — replacing the old lock-every-queue scan worker 0 paid
/// (twice!) per round for quiescence detection.
pub struct MessagePlane<M> {
    /// The selected transport.
    pub transport: Transport<M>,
    pending: [AtomicUsize; 2],
}

impl<M: Clone> MessagePlane<M> {
    /// Combiner-lane plane for `workers` workers over `n` vertices.
    pub fn new_combine(workers: usize, n: usize, combiner: Combiner<M>) -> Self {
        MessagePlane {
            transport: Transport::Combine(CombinerLanes::new(workers, n, combiner)),
            pending: [AtomicUsize::new(0), AtomicUsize::new(0)],
        }
    }
}

impl<M> MessagePlane<M> {
    /// Queue-lane plane for `workers` workers.
    pub fn new_queue(workers: usize, seg_cap: usize) -> Self {
        MessagePlane {
            transport: Transport::Queue(QueueLanes::new(workers, seg_cap)),
            pending: [AtomicUsize::new(0), AtomicUsize::new(0)],
        }
    }

    /// Pending deliveries staged at `parity` (fresh combiner touches /
    /// queue entries — not fanout). One relaxed load.
    pub fn pending(&self, parity: usize) -> usize {
        self.pending[parity].load(Ordering::Relaxed)
    }

    /// Batch-add staged sends (called by workers at phase ends).
    pub fn add_pending(&self, parity: usize, k: usize) {
        if k > 0 {
            self.pending[parity].fetch_add(k, Ordering::Relaxed);
        }
    }

    /// Zero the counter for a drained parity (worker 0, bookkeeping).
    pub fn reset_pending(&self, parity: usize) {
        self.pending[parity].store(0, Ordering::Relaxed);
    }

    /// Peak transport memory over the run: the fixed O(n) slabs for
    /// combiner lanes, total allocated segment bytes for queue lanes.
    pub fn peak_msg_bytes(&self) -> u64 {
        match &self.transport {
            Transport::Combine(l) => l.mem_bytes(),
            Transport::Queue(q) => q.mem_bytes(),
        }
    }

    /// Transport allocations over the run (0 for combiner lanes, whose
    /// memory is fixed at construction).
    pub fn msg_allocs(&self) -> u64 {
        match &self.transport {
            Transport::Combine(_) => 0,
            Transport::Queue(q) => q.allocs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn min_combiner() -> Combiner<u32> {
        Combiner { identity: || u32::MAX, combine: |a, b| *a = (*a).min(*b) }
    }

    fn deliver_all<M: Clone>(
        lanes: &CombinerLanes<M>,
        parity: usize,
        n: usize,
        f: &mut impl FnMut(VertexId, &M),
    ) {
        let mut scratch = Vec::new();
        lanes.deliver(parity, 0, n, &mut scratch, |v, m| f(v, m));
    }

    #[test]
    fn combiner_folds_per_destination() {
        let lanes = CombinerLanes::new(2, 8, min_combiner());
        assert!(lanes.send(0, 0, 3, &9), "first touch is fresh");
        assert!(!lanes.send(0, 0, 3, &4), "second send folds");
        assert!(!lanes.send(0, 0, 3, &7));
        assert!(lanes.send(0, 1, 3, &5), "other sender's lane is fresh");
        assert!(lanes.send(0, 1, 6, &2));
        let mut got = Vec::new();
        deliver_all(&lanes, 0, 8, &mut |v, m| got.push((v, *m)));
        // v3 folded across both senders: min(9,4,7,5) = 4; ascending order
        assert_eq!(got, vec![(3, 4), (6, 2)]);
        // drained: a second sweep sees nothing
        let mut again = Vec::new();
        deliver_all(&lanes, 0, 8, &mut |v, m| again.push((v, *m)));
        assert!(again.is_empty(), "touched bits cleared by delivery");
    }

    #[test]
    fn combiner_parity_separation_and_reuse() {
        let lanes = CombinerLanes::new(1, 4, min_combiner());
        lanes.send(0, 0, 1, &10);
        lanes.send(1, 0, 1, &20);
        let mut p0 = Vec::new();
        deliver_all(&lanes, 0, 4, &mut |v, m| p0.push((v, *m)));
        assert_eq!(p0, vec![(1, 10)]);
        // parity 1 untouched by the parity-0 sweep
        let mut p1 = Vec::new();
        deliver_all(&lanes, 1, 4, &mut |v, m| p1.push((v, *m)));
        assert_eq!(p1, vec![(1, 20)]);
        // slots are reusable after drain (fresh again)
        assert!(lanes.send(0, 0, 1, &30));
        let mut p0b = Vec::new();
        deliver_all(&lanes, 0, 4, &mut |v, m| p0b.push((v, *m)));
        assert_eq!(p0b, vec![(1, 30)]);
    }

    #[test]
    fn combiner_delivery_respects_owner_ranges() {
        // two receivers split [0, 128): each sweep must deliver and
        // clear only its own half, even within a shared boundary word
        let lanes = CombinerLanes::new(1, 128, min_combiner());
        for v in [0u32, 59, 60, 63, 64, 90, 127] {
            lanes.send(0, 0, v, &(v + 1));
        }
        let mut scratch = Vec::new();
        let mut left = Vec::new();
        lanes.deliver(0, 0, 60, &mut scratch, |v, m| left.push((v, *m)));
        assert_eq!(left, vec![(0, 1), (59, 60)]);
        let mut right = Vec::new();
        lanes.deliver(0, 60, 128, &mut scratch, |v, m| right.push((v, *m)));
        assert_eq!(right, vec![(60, 61), (63, 64), (64, 65), (90, 91), (127, 128)]);
    }

    #[test]
    fn combiner_sparse_delivery_across_summary_blocks() {
        // a handful of sends scattered over many 4096-vertex summary
        // blocks: the two-level sweep must find exactly them, in order,
        // and survive summary resets across send rounds
        let n = 64 * 64 * 3 + 17; // several summary words, ragged tail
        let lanes = CombinerLanes::new(2, n, min_combiner());
        let targets = [0u32, 4095, 4096, 8191, 12288, (n - 1) as u32];
        for &v in &targets {
            lanes.send(0, (v as usize) % 2, v, &v);
        }
        let mut got = Vec::new();
        deliver_all(&lanes, 0, n, &mut |v, m| got.push((v, *m)));
        let want: Vec<(VertexId, u32)> = targets.iter().map(|&v| (v, v)).collect();
        assert_eq!(got, want);
        // next cycle: senders reset their summaries, slots are fresh again
        lanes.begin_send_round(0, 0);
        lanes.begin_send_round(0, 1);
        assert!(lanes.send(0, 0, 8191, &7));
        let mut again = Vec::new();
        deliver_all(&lanes, 0, n, &mut |v, m| again.push((v, *m)));
        assert_eq!(again, vec![(8191, 7)]);
    }

    #[test]
    fn warm_lane_is_state_invisible() {
        // warm-up writes identity into untouched slots only: staged
        // messages (e.g. checkpoint-restored pending) survive verbatim,
        // and the fresh/fold semantics of later sends are unchanged
        let lanes = CombinerLanes::new(2, 200, min_combiner());
        lanes.send(0, 0, 7, &42);
        lanes.restore_pending(1, [(123u32, 5u32)]);
        lanes.warm_lane(0);
        lanes.warm_lane(1);
        let mut p0 = Vec::new();
        deliver_all(&lanes, 0, 200, &mut |v, m| p0.push((v, *m)));
        assert_eq!(p0, vec![(7, 42)], "staged send survives warm-up");
        let mut p1 = Vec::new();
        deliver_all(&lanes, 1, 200, &mut |v, m| p1.push((v, *m)));
        assert_eq!(p1, vec![(123, 5)], "restored pending survives warm-up");
        // warmed (identity-filled) slots are still "fresh" to send
        assert!(lanes.send(0, 1, 9, &3));
        let mut again = Vec::new();
        deliver_all(&lanes, 0, 200, &mut |v, m| again.push((v, *m)));
        assert_eq!(again, vec![(9, 3)]);
    }

    #[test]
    fn combiner_mem_is_o_n_not_o_m() {
        let lanes = CombinerLanes::new(2, 1000, min_combiner());
        let fixed = lanes.mem_bytes();
        assert!(fixed > 0);
        // a million sends move the memory accounting not one byte
        for i in 0..1_000_000u32 {
            lanes.send(0, 0, i % 1000, &i);
        }
        assert_eq!(lanes.mem_bytes(), fixed);
    }

    #[test]
    fn fold_pending_snapshots_and_restore_reproduces_delivery() {
        let lanes = CombinerLanes::new(2, 130, min_combiner());
        lanes.send(0, 0, 3, &9);
        lanes.send(0, 1, 3, &5);
        lanes.send(0, 1, 64, &2);
        lanes.send(0, 0, 129, &7);
        let pend = lanes.fold_pending(0);
        assert_eq!(pend, vec![(3, 5), (64, 2), (129, 7)]);
        // non-destructive: delivery still sees everything afterwards
        let mut got = Vec::new();
        deliver_all(&lanes, 0, 130, &mut |v, m| got.push((v, *m)));
        assert_eq!(got, vec![(3, 5), (64, 2), (129, 7)]);
        // restored into a fresh plane (single lane 0), delivery is
        // bit-identical to what the interrupted plane would have done
        let fresh = CombinerLanes::new(2, 130, min_combiner());
        fresh.restore_pending(0, pend);
        let mut again = Vec::new();
        deliver_all(&fresh, 0, 130, &mut |v, m| again.push((v, *m)));
        assert_eq!(again, got);
    }

    #[test]
    fn queue_roundtrip_fifo() {
        let q: QueueLanes<u32> = QueueLanes::new(2, 4);
        q.push(0, 0, 1, Delivery::P2p(7, 99));
        q.push(0, 0, 1, Delivery::P2p(3, 42));
        let mut got = Vec::new();
        q.drain(0, 0, 1, |d| match d {
            Delivery::P2p(v, m) => got.push((*v, *m)),
            _ => panic!("expected p2p"),
        });
        assert_eq!(got, vec![(7, 99), (3, 42)], "FIFO within a lane");
        // other lanes untouched
        let mut empty = 0;
        q.drain(0, 1, 0, |_| empty += 1);
        assert_eq!(empty, 0);
    }

    #[test]
    fn queue_multicast_single_entry_fanout() {
        let q: QueueLanes<u8> = QueueLanes::new(1, 16);
        let dsts: Arc<[VertexId]> = Arc::from(vec![1, 2, 3, 4].into_boxed_slice());
        q.push(1, 0, 0, Delivery::Multi(dsts, 5));
        let mut entries = 0;
        let mut fanout = 0;
        q.drain(1, 0, 0, |d| {
            entries += 1;
            fanout += d.fanout();
        });
        assert_eq!(entries, 1, "one queue slot for the whole fanout");
        assert_eq!(fanout, 4);
    }

    #[test]
    fn messages_allocation_free_once_warm() {
        // the satellite invariant: after a warm-up round at each parity,
        // steady-state rounds recycle segments and never allocate
        let q: QueueLanes<u64> = QueueLanes::new(1, 8);
        let round = |parity: usize, msgs: usize| {
            for i in 0..msgs {
                q.push(parity, 0, 0, Delivery::P2p(i as VertexId, i as u64));
            }
            let mut n = 0;
            q.drain(parity, 0, 0, |_| n += 1);
            assert_eq!(n, msgs);
        };
        round(0, 40); // warm parity 0 (40 msgs / seg_cap 8 = 5+ segments)
        round(1, 40); // warm parity 1
        let warm = q.allocs();
        assert!(warm > 0, "warmup must have allocated segments");
        let bytes = q.mem_bytes();
        for r in 0..50 {
            round(r % 2, 40);
        }
        assert_eq!(q.allocs(), warm, "steady-state sends must be allocation-free");
        assert_eq!(q.mem_bytes(), bytes, "segment memory flat once warm");
    }

    #[test]
    fn queue_growth_allocates_only_new_peaks() {
        let q: QueueLanes<u8> = QueueLanes::new(1, 4);
        for i in 0..8 {
            q.push(0, 0, 0, Delivery::P2p(i, 0));
        }
        let two_segs = q.allocs();
        assert_eq!(two_segs, 2);
        q.drain(0, 0, 0, |_| {});
        // same volume again: fully recycled
        for i in 0..8 {
            q.push(0, 0, 0, Delivery::P2p(i, 0));
        }
        assert_eq!(q.allocs(), two_segs);
        // a higher peak allocates only the difference
        for i in 0..8 {
            q.push(0, 0, 0, Delivery::P2p(i, 0));
        }
        assert_eq!(q.allocs(), two_segs + 2);
    }

    #[test]
    fn plane_pending_counters() {
        let plane: MessagePlane<u32> = MessagePlane::new_queue(2, 8);
        assert_eq!(plane.pending(0), 0);
        plane.add_pending(0, 5);
        plane.add_pending(0, 0); // no-op fast path
        plane.add_pending(1, 2);
        assert_eq!(plane.pending(0), 5);
        assert_eq!(plane.pending(1), 2);
        plane.reset_pending(0);
        assert_eq!(plane.pending(0), 0);
        assert_eq!(plane.pending(1), 2);
    }

    #[test]
    fn plane_accounting_by_transport() {
        let adder = Combiner { identity: || 0u64, combine: |a: &mut u64, b: &u64| *a += *b };
        let combine: MessagePlane<u64> = MessagePlane::new_combine(2, 256, adder);
        assert_eq!(combine.msg_allocs(), 0, "combiner memory is fixed at construction");
        // per lane: 256 slots × 8 B + 4 touched words + 1 summary word
        let expect = 2 * 2 * (256 * 8 + 4 * 8 + 8) as u64;
        assert_eq!(combine.peak_msg_bytes(), expect);

        let queue: MessagePlane<u64> = MessagePlane::new_queue(1, 8);
        assert_eq!(queue.peak_msg_bytes(), 0, "no segments until traffic");
        if let Transport::Queue(q) = &queue.transport {
            q.push(0, 0, 0, Delivery::P2p(0, 1));
        } else {
            panic!("queue plane expected");
        }
        assert_eq!(queue.msg_allocs(), 1);
        assert!(queue.peak_msg_bytes() > 0);
    }
}
