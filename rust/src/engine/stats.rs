//! Engine-level counters: messaging volume, rounds, activations, and —
//! since the work-stealing scheduler — per-worker busy/idle time and
//! steal counts.
//!
//! Combined with [`crate::safs::IoStats`], these are the quantities the
//! paper's figures plot (message counts for Fig. 3, barrier/round counts
//! behind the multi-source arguments of Figs. 5–6). The busy/idle split
//! makes load imbalance *visible*: a skewed frontier under a static
//! partition shows up as an unbounded max/min busy ratio, while the
//! chunk-stealing scheduler keeps it near 1.

use std::sync::atomic::{AtomicU64, Ordering};

/// Concurrently-updated engine counters.
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Point-to-point messages sent.
    pub p2p_msgs: AtomicU64,
    /// Multicast operations sent (one per destination-worker slice).
    pub multicast_msgs: AtomicU64,
    /// Total `run_on_message` deliveries. On the queue transport this is
    /// p2p + multicast fanout; on the combiner transport each folded
    /// destination counts once per round (the folds it absorbed are in
    /// [`EngineStats::combined_msgs`]).
    pub deliveries: AtomicU64,
    /// Sends folded into an already-touched combiner-lane slot — each is
    /// a queue entry *and* a `run_on_message` call that never happened.
    pub combined_msgs: AtomicU64,
    /// Peak bytes held by the message transport over the run: the fixed
    /// O(n) slabs for combiner lanes, total recycled-segment bytes for
    /// queue lanes. Independent of edge count on the combiner path.
    pub peak_msg_bytes: AtomicU64,
    /// Transport allocations over the run (queue-lane segments; 0 on the
    /// combiner path). Flat once warm — the messaging analogue of
    /// `FetchArena::allocs`.
    pub msg_allocs: AtomicU64,
    /// Summed per-worker wall time in phase A (message delivery), ns —
    /// the phase the transport rework targets.
    pub phase_a_ns: AtomicU64,
    /// Summed per-worker wall time in phase B (vertex phase), ns.
    pub phase_b_ns: AtomicU64,
    /// Of `phase_b_ns`, time spent *blocked* on edge-fetch completions.
    /// The overlapped pipeline drives this toward zero while the I/O
    /// threads stay busy — see [`EngineStatsSnapshot::overlap_ratio`].
    pub io_wait_ns: AtomicU64,
    /// Total `run_on_vertex` invocations.
    pub vertex_runs: AtomicU64,
    /// Rounds executed.
    pub rounds: AtomicU64,
    /// Rounds whose vertex phase ran in pull mode.
    pub pull_rounds: AtomicU64,
    /// Edge blocks whose I/O was skipped by the per-block source-summary
    /// filter (pull rounds only).
    pub blocks_skipped: AtomicU64,
    /// Frontier chunks claimed from another worker's span that yielded
    /// at least one active vertex (empty claimed chunks don't count —
    /// they rebalanced no work).
    pub steals: AtomicU64,
    /// Fetch-path heap allocations, folded from each worker's
    /// `FetchArena::allocs` at run end. Flat once warm; the trace
    /// overhead test asserts tracing does not move it.
    pub fetch_allocs: AtomicU64,
    /// Round-boundary checkpoints published
    /// ([`crate::engine::EngineConfig::checkpoint_every`]; 0 when off).
    pub checkpoints: AtomicU64,
    /// Total bytes written by published checkpoints.
    pub checkpoint_bytes: AtomicU64,
    /// Wall time workers spent parked (bounded-sleep stage of the
    /// [`crate::util::Backoff`] ladder) while waiting for fetch
    /// completions, ns. Parked time is *released* CPU — unlike the old
    /// bare-yield spins it shows up here instead of burning a core.
    pub park_ns: AtomicU64,
    /// Wait-ladder escalations past pure spinning (yields + parks).
    /// Zero in a well-fed run; growth localizes which runs are
    /// wait-bound rather than compute-bound.
    pub backoff_events: AtomicU64,
    /// Per-worker time spent working (phases A/B + bookkeeping), ns.
    worker_busy_ns: Vec<AtomicU64>,
    /// Per-worker time spent waiting at barriers, ns.
    worker_idle_ns: Vec<AtomicU64>,
}

impl EngineStats {
    /// Fresh zeroed counters with no per-worker slots (use
    /// [`Self::with_workers`] when busy/idle tracking is wanted).
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh zeroed counters tracking `workers` busy/idle slots.
    pub fn with_workers(workers: usize) -> Self {
        EngineStats {
            worker_busy_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            worker_idle_ns: (0..workers).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Record busy time for a worker (no-op without per-worker slots).
    #[inline]
    pub fn add_worker_busy(&self, wid: usize, ns: u64) {
        if let Some(slot) = self.worker_busy_ns.get(wid) {
            slot.fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Record idle (barrier-wait) time for a worker.
    #[inline]
    pub fn add_worker_idle(&self, wid: usize, ns: u64) {
        if let Some(slot) = self.worker_idle_ns.get(wid) {
            slot.fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Snapshot.
    pub fn snapshot(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            p2p_msgs: self.p2p_msgs.load(Ordering::Relaxed),
            multicast_msgs: self.multicast_msgs.load(Ordering::Relaxed),
            deliveries: self.deliveries.load(Ordering::Relaxed),
            combined_msgs: self.combined_msgs.load(Ordering::Relaxed),
            peak_msg_bytes: self.peak_msg_bytes.load(Ordering::Relaxed),
            msg_allocs: self.msg_allocs.load(Ordering::Relaxed),
            phase_a_ns: self.phase_a_ns.load(Ordering::Relaxed),
            phase_b_ns: self.phase_b_ns.load(Ordering::Relaxed),
            io_wait_ns: self.io_wait_ns.load(Ordering::Relaxed),
            vertex_runs: self.vertex_runs.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
            pull_rounds: self.pull_rounds.load(Ordering::Relaxed),
            blocks_skipped: self.blocks_skipped.load(Ordering::Relaxed),
            steals: self.steals.load(Ordering::Relaxed),
            fetch_allocs: self.fetch_allocs.load(Ordering::Relaxed),
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
            checkpoint_bytes: self.checkpoint_bytes.load(Ordering::Relaxed),
            park_ns: self.park_ns.load(Ordering::Relaxed),
            backoff_events: self.backoff_events.load(Ordering::Relaxed),
            worker_busy_ns: self
                .worker_busy_ns
                .iter()
                .map(|w| w.load(Ordering::Relaxed))
                .collect(),
            worker_idle_ns: self
                .worker_idle_ns
                .iter()
                .map(|w| w.load(Ordering::Relaxed))
                .collect(),
        }
    }
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EngineStatsSnapshot {
    pub p2p_msgs: u64,
    pub multicast_msgs: u64,
    pub deliveries: u64,
    /// Sends absorbed by combiner-lane folds (0 on the queue transport).
    pub combined_msgs: u64,
    /// Peak transport bytes over the run (O(n)-bounded on the combiner
    /// path regardless of edge count).
    pub peak_msg_bytes: u64,
    /// Queue-lane segment allocations (flat once warm; 0 on the
    /// combiner path).
    pub msg_allocs: u64,
    /// Summed per-worker phase-A (message delivery) wall time, ns.
    pub phase_a_ns: u64,
    /// Summed per-worker phase-B (vertex phase) wall time, ns.
    pub phase_b_ns: u64,
    /// Of `phase_b_ns`, time blocked on edge-fetch completions, ns.
    pub io_wait_ns: u64,
    pub vertex_runs: u64,
    pub rounds: u64,
    /// Rounds whose vertex phase ran in pull mode.
    pub pull_rounds: u64,
    /// Edge blocks skipped by the per-block source-summary filter.
    pub blocks_skipped: u64,
    /// Non-empty frontier chunks executed by a worker other than their
    /// span owner.
    pub steals: u64,
    /// Fetch-path heap allocations over the run (warm steady state: 0
    /// per round).
    pub fetch_allocs: u64,
    /// Round-boundary checkpoints published over the run (0 when off).
    pub checkpoints: u64,
    /// Total bytes written by published checkpoints.
    pub checkpoint_bytes: u64,
    /// Wall time parked in the wait ladder (released CPU, not spin), ns.
    pub park_ns: u64,
    /// Wait-ladder escalations past pure spinning (yields + parks).
    pub backoff_events: u64,
    /// Per-worker busy time in nanoseconds (empty when untracked).
    pub worker_busy_ns: Vec<u64>,
    /// Per-worker barrier-wait time in nanoseconds.
    pub worker_idle_ns: Vec<u64>,
}

impl EngineStatsSnapshot {
    /// Total send operations (queue pressure — what load balancing works
    /// against in FlashGraph).
    pub fn send_ops(&self) -> u64 {
        self.p2p_msgs + self.multicast_msgs
    }

    /// Load-imbalance metric: max/min per-worker busy time. `1.0` for
    /// runs with fewer than two tracked workers; `f64::INFINITY` when a
    /// worker recorded no busy time at all (the unbounded imbalance a
    /// static partition produces on a skewed frontier).
    pub fn busy_ratio(&self) -> f64 {
        if self.worker_busy_ns.len() < 2 {
            return 1.0;
        }
        let max = *self.worker_busy_ns.iter().max().unwrap();
        let min = *self.worker_busy_ns.iter().min().unwrap();
        if min == 0 {
            if max == 0 {
                1.0
            } else {
                f64::INFINITY
            }
        } else {
            max as f64 / min as f64
        }
    }

    /// Summed busy time across workers.
    pub fn total_busy(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.worker_busy_ns.iter().sum())
    }

    /// Summed barrier-wait time across workers.
    pub fn total_idle(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.worker_idle_ns.iter().sum())
    }

    /// Phase-A (message delivery) wall time summed over workers.
    pub fn phase_a(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.phase_a_ns)
    }

    /// Fraction of phase-B wall time that was compute rather than
    /// blocked I/O wait: `1 − io_wait/phase_b`, clamped to `[0, 1]`;
    /// `1.0` when phase B recorded no time at all. A fully serialized
    /// fetch-then-compute round under I/O-dominated latency drives this
    /// toward 0; the overlapped pipeline keeps it high — the quantity
    /// the overlap regression test compares between the two.
    pub fn overlap_ratio(&self) -> f64 {
        if self.phase_b_ns == 0 {
            1.0
        } else {
            1.0 - (self.io_wait_ns.min(self.phase_b_ns) as f64 / self.phase_b_ns as f64)
        }
    }

    /// Terse single-line report.
    pub fn report(&self) -> String {
        let mut s = format!(
            "rounds={} vertex_runs={} p2p={} multicast={} deliveries={} combined={} peak_msg={} steals={}",
            self.rounds,
            self.vertex_runs,
            self.p2p_msgs,
            self.multicast_msgs,
            self.deliveries,
            self.combined_msgs,
            crate::util::fmt_bytes(self.peak_msg_bytes),
            self.steals,
        );
        if self.pull_rounds > 0 {
            s.push_str(&format!(
                " pull_rounds={} blocks_skipped={}",
                self.pull_rounds, self.blocks_skipped,
            ));
        }
        if self.phase_b_ns > 0 {
            s.push_str(&format!(" overlap={:.2}", self.overlap_ratio()));
        }
        if self.checkpoints > 0 {
            s.push_str(&format!(
                " checkpoints={} ckpt_bytes={}",
                self.checkpoints,
                crate::util::fmt_bytes(self.checkpoint_bytes),
            ));
        }
        if self.backoff_events > 0 {
            s.push_str(&format!(
                " backoff_events={} park={}",
                self.backoff_events,
                crate::util::fmt_dur(std::time::Duration::from_nanos(self.park_ns)),
            ));
        }
        if self.worker_busy_ns.len() >= 2 {
            s.push_str(&format!(
                " busy_ratio={:.2} busy={} idle={}",
                self.busy_ratio(),
                crate::util::fmt_dur(self.total_busy()),
                crate::util::fmt_dur(self.total_idle()),
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_send_ops() {
        let s = EngineStats::new();
        s.p2p_msgs.fetch_add(3, Ordering::Relaxed);
        s.multicast_msgs.fetch_add(2, Ordering::Relaxed);
        s.deliveries.fetch_add(40, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.send_ops(), 5);
        assert_eq!(snap.deliveries, 40);
    }

    #[test]
    fn busy_ratio_edges() {
        // untracked: neutral ratio
        assert_eq!(EngineStatsSnapshot::default().busy_ratio(), 1.0);
        let s = EngineStats::with_workers(3);
        // a worker with zero busy time = unbounded imbalance
        s.add_worker_busy(0, 100);
        s.add_worker_busy(1, 100);
        assert!(s.snapshot().busy_ratio().is_infinite());
        s.add_worker_busy(2, 50);
        let snap = s.snapshot();
        assert!((snap.busy_ratio() - 2.0).abs() < 1e-12, "{}", snap.busy_ratio());
        assert_eq!(snap.total_busy(), std::time::Duration::from_nanos(250));
    }

    #[test]
    fn overlap_ratio_edges() {
        // no phase-B time recorded => neutral ratio
        assert_eq!(EngineStatsSnapshot::default().overlap_ratio(), 1.0);
        let mut s = EngineStatsSnapshot { phase_b_ns: 1000, io_wait_ns: 250, ..Default::default() };
        assert!((s.overlap_ratio() - 0.75).abs() < 1e-12);
        // wait can exceed phase time under clock skew; clamp to 0
        s.io_wait_ns = 2000;
        assert_eq!(s.overlap_ratio(), 0.0);
        s.io_wait_ns = 0;
        assert_eq!(s.overlap_ratio(), 1.0);
    }

    #[test]
    fn backoff_counters_surface_in_snapshot_and_report() {
        let s = EngineStats::new();
        // silent when no escalation happened — the common well-fed case
        assert!(!s.snapshot().report().contains("backoff_events"));
        s.backoff_events.fetch_add(7, Ordering::Relaxed);
        s.park_ns.fetch_add(1_500_000, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!((snap.backoff_events, snap.park_ns), (7, 1_500_000));
        let r = snap.report();
        assert!(r.contains("backoff_events=7"), "{r}");
        assert!(r.contains("park="), "{r}");
    }

    #[test]
    fn untracked_worker_slots_are_noops() {
        let s = EngineStats::new();
        s.add_worker_busy(0, 10);
        s.add_worker_idle(5, 10);
        let snap = s.snapshot();
        assert!(snap.worker_busy_ns.is_empty());
        assert_eq!(snap.busy_ratio(), 1.0);
    }
}
