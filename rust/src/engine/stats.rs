//! Engine-level counters: messaging volume, rounds, activations.
//!
//! Combined with [`crate::safs::IoStats`], these are the quantities the
//! paper's figures plot (message counts for Fig. 3, barrier/round counts
//! behind the multi-source arguments of Figs. 5–6).

use std::sync::atomic::{AtomicU64, Ordering};

/// Concurrently-updated engine counters.
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Point-to-point messages sent.
    pub p2p_msgs: AtomicU64,
    /// Multicast operations sent (one per destination-worker slice).
    pub multicast_msgs: AtomicU64,
    /// Total `run_on_message` deliveries (p2p + multicast fanout).
    pub deliveries: AtomicU64,
    /// Total `run_on_vertex` invocations.
    pub vertex_runs: AtomicU64,
    /// Rounds executed.
    pub rounds: AtomicU64,
}

impl EngineStats {
    /// Fresh zeroed counters.
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot.
    pub fn snapshot(&self) -> EngineStatsSnapshot {
        EngineStatsSnapshot {
            p2p_msgs: self.p2p_msgs.load(Ordering::Relaxed),
            multicast_msgs: self.multicast_msgs.load(Ordering::Relaxed),
            deliveries: self.deliveries.load(Ordering::Relaxed),
            vertex_runs: self.vertex_runs.load(Ordering::Relaxed),
            rounds: self.rounds.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`EngineStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStatsSnapshot {
    pub p2p_msgs: u64,
    pub multicast_msgs: u64,
    pub deliveries: u64,
    pub vertex_runs: u64,
    pub rounds: u64,
}

impl EngineStatsSnapshot {
    /// Total send operations (queue pressure — what load balancing works
    /// against in FlashGraph).
    pub fn send_ops(&self) -> u64 {
        self.p2p_msgs + self.multicast_msgs
    }

    /// Terse single-line report.
    pub fn report(&self) -> String {
        format!(
            "rounds={} vertex_runs={} p2p={} multicast={} deliveries={}",
            self.rounds, self.vertex_runs, self.p2p_msgs, self.multicast_msgs, self.deliveries
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_and_send_ops() {
        let s = EngineStats::new();
        s.p2p_msgs.fetch_add(3, Ordering::Relaxed);
        s.multicast_msgs.fetch_add(2, Ordering::Relaxed);
        s.deliveries.fetch_add(40, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.send_ops(), 5);
        assert_eq!(snap.deliveries, 40);
    }
}
