//! The engine runner: worker threads, rounds, barriers, termination —
//! and the work-stealing frontier scheduler.
//!
//! ## Scheduling
//!
//! The activation bitmap is divided into fixed-size **chunks**
//! ([`CHUNK_BITS`] bits, word-aligned). Each worker owns a contiguous
//! span of chunks (the same range partition as before, for locality and
//! single-worker determinism) and claims chunks from its span through an
//! atomic cursor. When a worker's span drains it **steals**: it walks
//! the other workers' cursors and claims their remaining chunks. On a
//! balanced frontier this degenerates to the static partition (one
//! `fetch_add` per chunk of overhead); on a skewed frontier — power-law
//! graphs concentrate activations badly — every worker ends up pulling
//! from the hot span, bounding the per-worker busy-time ratio that the
//! static partition left unbounded (see [`EngineStats`] busy/idle and
//! steal counters, reported in every [`RunReport`]).
//!
//! A chunk is scanned once by its claimant, then cleared **word-level**
//! (`store(0)` per 64 bits) for reuse in the next round — replacing the
//! old per-bit test-and-clear sweep. This is safe because nothing sets
//! bits in the *current* round's bitmap during the vertex phase.
//!
//! ## Overlapped I/O
//!
//! The vertex phase is completion-driven: each worker keeps up to
//! `fetch_window + 1` edge batches in flight as async submissions to
//! the I/O pool ([`crate::graph::source::FetchSlot`]), processes
//! whichever batch's pages land first, and only charges `io_wait_ns`
//! when it must block on a batch that has not completed. With
//! `fetch_window = 0` the pipeline degenerates to the strictly
//! synchronous fetch-then-compute baseline (every fetch is a timed
//! wait), which is what the overlap regression tests compare against.
//!
//! ## Push/pull hybrid rounds
//!
//! Programs that opt in ([`VertexProgram::supports_pull`]) can run
//! dense rounds in **pull** mode: instead of active sources pushing
//! along their out-edges, every destination with relevant edges fetches
//! its neighbor list once and synthesizes messages from the active
//! sources it finds ([`VertexProgram::pull_message`]). A pull round
//! splits phase B in two: **B1** runs `run_on_vertex` (edge-less) over
//! the live frontier so per-vertex state and pull stashes update
//! exactly as a push round would, then after a barrier **B2** sweeps
//! destination chunks. Per-chunk **source-summary words**
//! (one 64-bit bucket mask per [`CHUNK_BITS`] destinations, built on
//! first scan) let later pull rounds skip the I/O for chunks whose
//! sources are all inactive — `blocks_skipped` in the stats.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration, Instant};

use crate::engine::checkpoint::{self, CheckpointHeader, CheckpointImage, CheckpointWriter};
use crate::engine::context::{EndCtx, WorkerCtx, N_RED_SLOTS};
use crate::engine::messages::{Delivery, MessagePlane, Transport, TransportMode};
use crate::engine::program::VertexProgram;
use crate::engine::stats::{EngineStats, EngineStatsSnapshot};
use crate::engine::trace::{EngineCum, RoundTrace};
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::graph::source::{EdgeSource, FetchSlot};
use crate::safs::IoStatsSnapshot;
use crate::util::{AtomicBitmap, SharedVec};
use crate::VertexId;

/// Bits per frontier chunk (a multiple of 64 so chunk edges are word
/// edges). Small enough that a skewed frontier splits into many
/// stealable units, large enough that the claim `fetch_add` amortizes
/// over hundreds of vertices.
pub const CHUNK_BITS: usize = 256;

/// Chunk span `[lo, hi)` owned by worker `wid` (same proportional split
/// as the old vertex partition, but in chunk units).
#[inline]
fn chunk_span(wid: usize, workers: usize, nchunks: usize) -> (usize, usize) {
    ((wid * nchunks).div_ceil(workers), ((wid + 1) * nchunks).div_ceil(workers))
}

/// Vertex range `[lo, hi)` owned by worker `wid` — the exact inverse of
/// `WorkerCtx::owner` (`owner(v) = v·W/n`), used by the combiner-lane
/// delivery sweep so each worker drains precisely its own destinations.
#[inline]
fn owner_span(wid: usize, workers: usize, n: usize) -> (usize, usize) {
    ((wid * n).div_ceil(workers), ((wid + 1) * n).div_ceil(workers))
}

/// Per-round vertex-phase direction strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunMode {
    /// Frontier-driven push every round (the classic path; default).
    Push,
    /// Pull every round on programs that opt in via
    /// [`VertexProgram::supports_pull`]; others degrade to push.
    Pull,
    /// Decide per round: pull when the next frontier's density reaches
    /// [`EngineConfig::pull_density`], push otherwise — the FlashGraph /
    /// Ligra-style direction switch.
    Auto,
}

impl std::str::FromStr for RunMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "push" => Ok(RunMode::Push),
            "pull" => Ok(RunMode::Pull),
            "auto" => Ok(RunMode::Auto),
            _ => Err(format!("unknown mode '{s}' (expected push|pull|auto)")),
        }
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Worker threads (0 = one per available core).
    pub workers: usize,
    /// Active vertices fetched per batch — the unit of I/O overlap.
    pub batch: usize,
    /// Queue-lane segment capacity (deliveries per recycled segment).
    /// Only used when the run is on the queue transport.
    pub seg_cap: usize,
    /// Message transport selection: [`TransportMode::Auto`] routes
    /// programs with a declared [`crate::engine::Combiner`] through the
    /// dense combiner lanes; [`TransportMode::Queue`] forces the
    /// recycled SPSC queue lanes (baseline / oracle comparisons).
    pub transport: TransportMode,
    /// Hard round cap (safety net; algorithms converge on their own).
    pub max_rounds: usize,
    /// Cooperative cancellation token, checked once per round at the
    /// global barrier (worker 0's bookkeeping phase). When it flips to
    /// `true` the run winds down at the next round boundary — in-flight
    /// vertex work finishes, so state stays consistent. Service-mode
    /// jobs each get their own token; `None` disables the check.
    pub cancel: Option<Arc<AtomicBool>>,
    /// Record a per-round [`RoundTrace`] into the [`RunReport`]. Off by
    /// default: an untraced run takes no snapshots and pays nothing; a
    /// traced run preallocates its ring up front and records
    /// allocation-free (one uncontended lock by worker 0 per round).
    pub trace: bool,
    /// Push/pull round strategy. Defaults to [`RunMode::Push`] (the
    /// classic frontier-driven path); `Auto` switches direction per
    /// round on programs that opt into pull.
    pub mode: RunMode,
    /// `Auto` threshold: pull when the frontier holds at least this
    /// fraction of all vertices.
    pub pull_density: f64,
    /// Edge batches each worker keeps in flight *beyond* the one it is
    /// processing (the overlap window). `0` forces the synchronous
    /// fetch-then-compute baseline; the service layer charges
    /// `workers × (fetch_window + 1)` slot footprints to admission.
    pub fetch_window: usize,
    /// Write a round-boundary checkpoint every this many rounds (plus a
    /// final one when the run is cancelled or hits `max_rounds`). `0`
    /// disables checkpointing entirely — the hot path takes no extra
    /// branches beyond one predictable compare per round. Requires
    /// [`Self::checkpoint_path`], a program that opts in via
    /// [`VertexProgram::checkpointable`], and the combiner transport.
    pub checkpoint_every: u64,
    /// Where the checkpoint snapshot lives (written atomically via a
    /// temp file + rename, so a crash mid-write never leaves a loadable
    /// torn image). A run that converges naturally removes it.
    pub checkpoint_path: Option<PathBuf>,
    /// Start from the snapshot at `checkpoint_path` instead of
    /// `init_active`: program state, frontier, pending folded messages
    /// and the round counter are restored, and the run continues from
    /// the saved round. A missing or corrupt snapshot falls back to a
    /// fresh run (logged, never fatal).
    pub resume: bool,
    /// Pin worker `w` to core `w % cores` (Linux `sched_setaffinity`;
    /// no-op elsewhere — see [`crate::util::affinity`]). Keeps each
    /// worker's decode arenas and combiner lane resident in one cache
    /// domain and, because `FetchSlot` arenas are allocated inside the
    /// worker thread, first-touch places them on the pinned core's NUMA
    /// node. Off by default: on shared boxes pinning fights the
    /// scheduler. A locality hint only — results are bit-identical
    /// either way (the determinism tests run both).
    pub pin_workers: bool,
    /// Per-run deadline, enforced at round boundaries (worker 0's
    /// bookkeeping phase — the same place cancellation is checked, so
    /// in-flight vertex work always finishes and state stays
    /// consistent). Past the deadline the run stops and the report
    /// carries a `deadline exceeded` failure, which service mode turns
    /// into `JobState::Failed` with a WAL record. `None` = no deadline.
    pub deadline: Option<std::time::Instant>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        EngineConfig {
            workers,
            batch: 1024,
            seg_cap: 1024,
            transport: TransportMode::Auto,
            max_rounds: 1_000_000,
            cancel: None,
            trace: false,
            mode: RunMode::Push,
            pull_density: 0.125,
            fetch_window: 2,
            checkpoint_every: 0,
            checkpoint_path: None,
            resume: false,
            pin_workers: false,
            deadline: None,
        }
    }
}

/// What a run did: rounds, wall time, messaging and I/O volume.
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Rounds executed.
    pub rounds: u64,
    /// Wall-clock time.
    pub wall: Duration,
    /// Engine counters (messages, vertex runs).
    pub engine: EngineStatsSnapshot,
    /// I/O counters delta over the run (from the edge source).
    pub io: IoStatsSnapshot,
    /// Per-round trace (only when `EngineConfig.trace` was set).
    pub trace: Option<RoundTrace>,
    /// First permanent I/O failure observed by any worker, if the run
    /// failed. The engine never panics on substrate errors: workers
    /// record the failure, ride the barriers to the next round boundary,
    /// and the run winds down with state intact for the caller to
    /// report. `None` means the run completed (or was cancelled)
    /// normally.
    pub failure: Option<String>,
}

impl RunReport {
    /// Combine sequential runs into one aggregate report (durations and
    /// counters add component-wise) — used when a multi-phase algorithm
    /// drives the engine several times.
    pub fn merged(reports: &[RunReport]) -> RunReport {
        let mut out = RunReport {
            rounds: 0,
            wall: Duration::ZERO,
            engine: Default::default(),
            io: Default::default(),
            // traces don't concatenate across separately-configured
            // runs; a single-run "merge" is an identity, so its trace
            // survives (multi-phase callers keep per-phase reports)
            trace: if reports.len() == 1 { reports[0].trace.clone() } else { None },
            failure: reports.iter().find_map(|r| r.failure.clone()),
        };
        fn add_per_worker(acc: &mut Vec<u64>, v: &[u64]) {
            if acc.len() < v.len() {
                acc.resize(v.len(), 0);
            }
            for (a, b) in acc.iter_mut().zip(v) {
                *a += b;
            }
        }
        for r in reports {
            out.rounds += r.rounds;
            out.wall += r.wall;
            out.engine.p2p_msgs += r.engine.p2p_msgs;
            out.engine.multicast_msgs += r.engine.multicast_msgs;
            out.engine.deliveries += r.engine.deliveries;
            out.engine.combined_msgs += r.engine.combined_msgs;
            // each run owns its transport, so the aggregate peak is the
            // largest single-run footprint, not a sum
            out.engine.peak_msg_bytes = out.engine.peak_msg_bytes.max(r.engine.peak_msg_bytes);
            out.engine.msg_allocs += r.engine.msg_allocs;
            out.engine.phase_a_ns += r.engine.phase_a_ns;
            out.engine.phase_b_ns += r.engine.phase_b_ns;
            out.engine.io_wait_ns += r.engine.io_wait_ns;
            out.engine.vertex_runs += r.engine.vertex_runs;
            out.engine.rounds += r.engine.rounds;
            out.engine.pull_rounds += r.engine.pull_rounds;
            out.engine.blocks_skipped += r.engine.blocks_skipped;
            out.engine.steals += r.engine.steals;
            out.engine.fetch_allocs += r.engine.fetch_allocs;
            out.engine.checkpoints += r.engine.checkpoints;
            out.engine.checkpoint_bytes += r.engine.checkpoint_bytes;
            out.engine.park_ns += r.engine.park_ns;
            out.engine.backoff_events += r.engine.backoff_events;
            add_per_worker(&mut out.engine.worker_busy_ns, &r.engine.worker_busy_ns);
            add_per_worker(&mut out.engine.worker_idle_ns, &r.engine.worker_idle_ns);
            out.io.read_requests += r.io.read_requests;
            out.io.cache_hits += r.io.cache_hits;
            out.io.cache_misses += r.io.cache_misses;
            out.io.physical_reads += r.io.physical_reads;
            out.io.bytes_read += r.io.bytes_read;
            out.io.merged_requests += r.io.merged_requests;
            out.io.logical_bytes += r.io.logical_bytes;
            out.io.thread_waits += r.io.thread_waits;
            out.io.evictions += r.io.evictions;
            out.io.retries += r.io.retries;
            out.io.transient_errors += r.io.transient_errors;
            out.io.permanent_errors += r.io.permanent_errors;
            out.io.backoff_waits += r.io.backoff_waits;
            out.io.backoff_us += r.io.backoff_us;
        }
        out
    }

    /// One-line summary.
    pub fn report(&self) -> String {
        format!(
            "wall={} {} | {}",
            crate::util::fmt_dur(self.wall),
            self.engine.report(),
            self.io.report()
        )
    }
}

/// Per-worker reduction snapshot: (add accumulators, max accumulators).
type RedPair = ([f64; N_RED_SLOTS], [f64; N_RED_SLOTS]);

/// Shared state for one run.
struct Shared<M> {
    bitmaps: [AtomicBitmap; 2],
    plane: MessagePlane<M>,
    barrier: Barrier,
    stop: AtomicBool,
    round: AtomicUsize,
    stats: EngineStats,
    /// Per-worker reduction slots: each worker overwrites its own slot
    /// before the phase-B barrier, worker 0 merges after it — replacing
    /// the per-round mutex every worker used to contend on.
    reductions: SharedVec<RedPair>,
    /// Per-worker chunk cursors over the activation bitmap; worker 0
    /// resets them to each span's start during round bookkeeping.
    cursors: Vec<AtomicUsize>,
    /// Separate cursors for the pull sweep (B2) — B1 drains the
    /// frontier through `cursors`, so pull rounds need their own claim
    /// state over the destination chunks.
    pull_cursors: Vec<AtomicUsize>,
    /// Direction of the round in flight; worker 0 decides the next
    /// round's value at bookkeeping, published by the final barrier.
    pull_round: AtomicBool,
    /// Per-chunk source-summary words: bit `b` set means some vertex of
    /// bucket `b` (see [`source_bucket`]) has an edge into this chunk.
    /// `0` is the "not yet scanned" sentinel — a chunk's claimant
    /// publishes its word after the first full pull scan, and later
    /// pull rounds skip chunks whose word misses the frontier summary
    /// entirely. The graph is static, so a published word never
    /// changes.
    block_src: Vec<AtomicU64>,
    /// Total chunks in the bitmap.
    nchunks: usize,
    /// Per-worker phase timings for the round in flight, published
    /// before the phase-B barrier when tracing (ns quads: phase A,
    /// phase B, inter-phase barrier, I/O wait inside phase B).
    phase_ns: SharedVec<(u64, u64, u64, u64)>,
    /// The per-round recorder. Only worker 0 touches it — during
    /// bookkeeping, when every other worker is parked between barriers
    /// — so the lock is uncontended; `None` when tracing is off.
    trace: Option<Mutex<RoundTrace>>,
    /// First permanent I/O failure recorded by any worker. A worker that
    /// hits one stores it here (first writer wins), finishes the round's
    /// barriers normally — never wedging the crew — and worker 0 winds
    /// the run down at the next boundary. Uncontended in the happy path:
    /// locked only to record a failure and once per round by worker 0.
    failure: Mutex<Option<String>>,
}

/// Claim-loop state: where the claimer is sourcing chunks from. A round
/// never needs a blocking wait state here — chunks are claimed exactly
/// once and nothing re-adds them mid-round, so a drained walk is a
/// terminal `Done`, not something to wait out (the engine's genuine
/// wait state is the fetch pipeline's poll-with-backoff in
/// [`run_pipeline`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ClaimState {
    /// Draining this worker's own span (the locality-preserving common
    /// case — on a balanced frontier the claimer never leaves it).
    Visit,
    /// Own span drained: walking the other workers' cursors, claiming
    /// their leftover chunks.
    Steal,
    /// Every span visited; `next_chunk` returns `None` forever.
    Done,
}

/// Claims frontier chunks: first from this worker's own span
/// ([`ClaimState::Visit`]), then — work stealing — from the other
/// workers' remaining spans ([`ClaimState::Steal`]).
struct ChunkClaimer<'a> {
    cursors: &'a [AtomicUsize],
    nchunks: usize,
    workers: usize,
    wid: usize,
    state: ClaimState,
    /// Span currently being drained (own span first).
    victim: usize,
    /// Spans visited so far this round (drives `Steal` → `Done`).
    visited: usize,
    /// Foreign chunks that yielded work (counted by [`FrontierStream`]).
    steals: u64,
}

impl<'a> ChunkClaimer<'a> {
    fn new(shared_cursors: &'a [AtomicUsize], nchunks: usize, workers: usize, wid: usize) -> Self {
        ChunkClaimer {
            cursors: shared_cursors,
            nchunks,
            workers,
            wid,
            state: ClaimState::Visit,
            victim: wid,
            visited: 0,
            steals: 0,
        }
    }

    /// Claim the next chunk, or `None` when every span is drained.
    /// Returns `(chunk, foreign)`; `foreign` chunks only count as steals
    /// once they yield a vertex (an empty claimed chunk rebalanced no
    /// work, so it must not inflate the steal metric).
    fn next_chunk(&mut self) -> Option<(usize, bool)> {
        loop {
            if self.state == ClaimState::Done {
                return None;
            }
            let v = self.victim;
            let (_, hi) = chunk_span(v, self.workers, self.nchunks);
            // cheap pre-check bounds cursor overshoot to one fetch_add
            // per contender per span
            if self.cursors[v].load(Ordering::Relaxed) < hi {
                let c = self.cursors[v].fetch_add(1, Ordering::Relaxed);
                if c < hi {
                    return Some((c, self.state == ClaimState::Steal));
                }
                // lost the claim race (another worker drained the span
                // between pre-check and fetch_add): fall through and
                // move on — there is nothing to wait for
            }
            self.visited += 1;
            if self.visited >= self.workers {
                self.state = ClaimState::Done;
                return None;
            }
            self.state = ClaimState::Steal;
            self.victim = (v + 1) % self.workers;
        }
    }
}

/// Streams set bits of the current frontier to one worker, claiming
/// chunks through the [`ChunkClaimer`] and clearing each chunk
/// word-level once it has been fully scanned.
struct FrontierStream<'a> {
    bm: &'a AtomicBitmap,
    claimer: ChunkClaimer<'a>,
    /// Partially scanned chunk: (bit iterator, chunk start, chunk end,
    /// foreign-and-not-yet-counted-as-steal).
    cur: Option<(crate::util::bitmap::SetBits<'a>, usize, usize, bool)>,
    n: usize,
    /// Clear each chunk after scanning it (push rounds). Pull rounds
    /// stream non-clearing: B2 still tests `bm.get(src)` after B1
    /// drained the frontier, so worker 0 retires the whole bitmap at
    /// bookkeeping instead.
    clear: bool,
}

impl FrontierStream<'_> {
    fn next_vertex(&mut self) -> Option<usize> {
        loop {
            if let Some((it, start, end, uncounted)) = self.cur.as_mut() {
                if let Some(v) = it.next() {
                    // a foreign chunk becomes a steal the moment it
                    // yields real work
                    if std::mem::take(uncounted) {
                        self.claimer.steals += 1;
                    }
                    return Some(v);
                }
                // fully scanned: word-level clear readies the chunk for
                // round r+1 (replaces the per-bit lo..hi sweep)
                if self.clear {
                    self.bm.clear_span(*start, *end);
                }
                self.cur = None;
            }
            let (c, foreign) = self.claimer.next_chunk()?;
            let start = c * CHUNK_BITS;
            let end = ((c + 1) * CHUNK_BITS).min(self.n);
            self.cur = Some((self.bm.iter_set_range(start, end), start, end, foreign));
        }
    }
}

/// Map a vertex id to one of 64 equal-width **source buckets** — the
/// bit it occupies in a chunk's source-summary word and in the round's
/// frontier summary. Buckets partition `[0, n)` so every vertex lands
/// in exactly one bit.
#[inline]
pub fn source_bucket(v: VertexId, n: usize) -> u32 {
    debug_assert!((v as usize) < n);
    (v as u64 * 64 / n as u64) as u32
}

/// Conservative 64-bit summary of a frontier bitmap: bit `b` is set if
/// any vertex of bucket `b` **may** be active. Built word-wise — a
/// nonzero bitmap word sets every bucket its 64-vertex range overlaps —
/// so the summary over-approximates (never misses) the true active set.
/// The block filter is therefore safe: a pull chunk is skipped only
/// when `block_src & summary == 0`, which implies no active vertex has
/// an edge into the chunk.
pub fn frontier_summary_word(bm: &AtomicBitmap, n: usize) -> u64 {
    let mut out = 0u64;
    for wi in 0..n.div_ceil(64) {
        if bm.word(wi) != 0 {
            let lo = source_bucket((wi * 64) as VertexId, n);
            let hi = source_bucket((wi * 64 + 63).min(n - 1) as VertexId, n);
            for b in lo..=hi {
                out |= 1u64 << b;
            }
        }
    }
    out
}

/// Parked-wait accounting for one worker's round, merged into
/// [`EngineStats`] alongside the other per-round counters.
#[derive(Debug, Default, Clone, Copy)]
struct WaitStats {
    /// Wall time actually slept in the backoff ladder's park stage, ns
    /// (also charged to `io_wait_ns` — a park *is* an I/O stall, just
    /// one that releases the core).
    park_ns: u64,
    /// Ladder escalations past pure spinning (yields + parks).
    backoff_events: u64,
}

/// Bounded parks the pipeline's wait state takes before giving up on
/// polling and blocking on the oldest submission (≈ 50+100+200+400 µs
/// of released-CPU waiting — long enough to catch any out-of-order
/// completion, short enough that a stalled pool degrades to the old
/// blocking behavior almost immediately).
const WAIT_PARK_STEPS: u32 = 4;

/// Drive one worker's vertex phase through the overlapped fetch
/// pipeline: `fill` stages the next batch of edge requests into a slot
/// (returning `false` when the frontier is drained), `process` consumes
/// a completed slot. With `window > 0`, up to `window + 1` slots are in
/// flight at once and the worker finishes whichever completed first —
/// only a blocking wait on a still-in-flight batch is charged to
/// `io_wait_ns`. With `window == 0` every batch is a synchronous, fully
/// timed fetch (the forced-baseline the overlap tests compare against).
///
/// **Wait state.** When no in-flight batch has completed, the worker
/// does not block on the oldest immediately: it re-polls under a
/// [`crate::util::Backoff`] ladder (spin → yield → bounded park), which
/// keeps catching *whichever* batch lands first instead of serializing
/// on submission order, and releases the core while parked instead of
/// burning it in a poll spin. After [`WAIT_PARK_STEPS`] parks with
/// nothing ready it falls back to the blocking wait on the oldest
/// submission, so a completion signal the poll path cannot observe
/// still makes progress. Parked time is charged to both `io_wait_ns`
/// (it is an I/O stall) and `wait.park_ns` (it released the CPU).
///
/// A permanent fetch failure no longer panics: the pipeline stops
/// filling, retires every in-flight slot back to the free pool (so later
/// rounds keep their allocation-free steady state), and returns the
/// first error for the worker to record.
fn run_pipeline(
    source: &dyn EdgeSource,
    slots: &mut Vec<FetchSlot>,
    window: usize,
    io_wait_ns: &mut u64,
    wait: &mut WaitStats,
    mut fill: impl FnMut(&mut FetchSlot) -> bool,
    mut process: impl FnMut(&FetchSlot),
) -> crate::Result<()> {
    if window == 0 {
        let slot = &mut slots[0];
        while fill(slot) {
            let t = Instant::now();
            let finished = source.finish_batch(slot);
            *io_wait_ns += t.elapsed().as_nanos() as u64;
            finished?;
            process(slot);
        }
        return Ok(());
    }
    let mut free: Vec<FetchSlot> = std::mem::take(slots);
    let mut inflight: VecDeque<FetchSlot> = VecDeque::with_capacity(free.len());
    let mut drained = false;
    let mut failure: Option<anyhow::Error> = None;
    let mut backoff = crate::util::Backoff::new();
    loop {
        // keep the window full before touching completions (no refills
        // once a batch has failed — the round is lost either way)
        while failure.is_none() && !drained && inflight.len() < window + 1 {
            let Some(mut s) = free.pop() else { break };
            if fill(&mut s) {
                match source.submit_batch(&mut s) {
                    Ok(()) => inflight.push_back(s),
                    Err(e) => {
                        failure = Some(e);
                        s.reqs.clear();
                        free.push(s);
                    }
                }
            } else {
                drained = true;
                free.push(s);
            }
        }
        if inflight.is_empty() {
            break;
        }
        if failure.is_some() {
            // failure drain: retire every in-flight batch unprocessed so
            // no slot leaks out of the pool
            while let Some(mut s) = inflight.pop_front() {
                let _ = source.finish_batch(&mut s);
                s.reqs.clear();
                free.push(s);
            }
            break;
        }
        // prefer whichever batch's pages have already landed (oldest
        // first, so in-memory sources process in submission order).
        // Wait state: nothing ready → re-poll under the backoff ladder
        // before paying the blocking path below.
        let mut parks = 0u32;
        let ready = loop {
            if let Some(i) = (0..inflight.len()).find(|&i| source.poll_batch(&mut inflight[i])) {
                break Some(i);
            }
            if parks >= WAIT_PARK_STEPS {
                break None;
            }
            if backoff.is_parking() {
                parks += 1;
            }
            let step = backoff.snooze();
            if step.escalated {
                wait.backoff_events += 1;
            }
            if !step.parked.is_zero() {
                let ns = step.parked.as_nanos() as u64;
                wait.park_ns += ns;
                *io_wait_ns += ns;
            }
        };
        backoff.reset();
        let mut s = match ready {
            Some(i) => {
                let mut s = inflight.remove(i).unwrap();
                // completed: finish assembles + decodes without blocking
                if let Err(e) = source.finish_batch(&mut s) {
                    failure = Some(e);
                }
                s
            }
            None => {
                // nothing landed yet — block on the oldest submission
                // and charge the stall to io_wait
                let mut s = inflight.pop_front().unwrap();
                let t = Instant::now();
                let finished = source.finish_batch(&mut s);
                *io_wait_ns += t.elapsed().as_nanos() as u64;
                if let Err(e) = finished {
                    failure = Some(e);
                }
                s
            }
        };
        if failure.is_none() {
            process(&s);
        }
        s.reqs.clear();
        free.push(s);
    }
    *slots = free;
    match failure {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// The BSP engine.
pub struct Engine;

impl Engine {
    /// Run `program` over `source`, starting with `init_active` vertices
    /// activated for round 0.
    pub fn run<P: VertexProgram>(
        program: &P,
        source: &dyn EdgeSource,
        init_active: &[VertexId],
        cfg: &EngineConfig,
    ) -> RunReport {
        let n = source.index().num_vertices();
        assert!(n > 0, "empty graph");
        let workers = cfg.workers.max(1).min(n);
        let nchunks = n.div_ceil(CHUNK_BITS);
        // transport selection: programs that declare a commutative-
        // associative combiner get the dense O(n) lanes (unless the run
        // forces the queue baseline); everything else gets recycled
        // SPSC segment queues
        let plane = match (cfg.transport, program.combiner()) {
            (TransportMode::Auto, Some(c)) => MessagePlane::new_combine(workers, n, c),
            _ => MessagePlane::new_queue(workers, cfg.seg_cap),
        };
        // snapshot before the trace is built: it is the base of both
        // the run-level delta and the trace's first per-round delta
        let io_before = source.io_stats().snapshot();
        let shared = Shared {
            bitmaps: [AtomicBitmap::new(n), AtomicBitmap::new(n)],
            plane,
            barrier: Barrier::new(workers),
            stop: AtomicBool::new(false),
            round: AtomicUsize::new(0),
            stats: EngineStats::with_workers(workers),
            reductions: SharedVec::new(
                workers,
                ([0.0; N_RED_SLOTS], [f64::NEG_INFINITY; N_RED_SLOTS]),
            ),
            cursors: (0..workers)
                .map(|w| AtomicUsize::new(chunk_span(w, workers, nchunks).0))
                .collect(),
            pull_cursors: (0..workers)
                .map(|w| AtomicUsize::new(chunk_span(w, workers, nchunks).0))
                .collect(),
            pull_round: AtomicBool::new(false),
            block_src: (0..nchunks).map(|_| AtomicU64::new(0)).collect(),
            nchunks,
            phase_ns: SharedVec::new(workers, (0u64, 0u64, 0u64, 0u64)),
            trace: cfg.trace.then(|| Mutex::new(RoundTrace::new(workers, io_before))),
            failure: Mutex::new(None),
        };
        // resume path: restore program state, frontier, pending folded
        // messages and the round counter from the snapshot instead of
        // seeding `init_active`. A missing or corrupt snapshot is not
        // fatal — the run degrades to a fresh start (the durability
        // contract is at-least-once completion, never wedging on a torn
        // file).
        let mut start_round = 0usize;
        let mut resumed = false;
        if cfg.resume {
            if let Some(path) = &cfg.checkpoint_path {
                match CheckpointImage::load(path)
                    .and_then(|img| Self::restore_from(program, &shared, &img, n))
                {
                    Ok(k) => {
                        start_round = k;
                        resumed = true;
                    }
                    Err(e) => {
                        eprintln!("graphyti: checkpoint unusable ({e:#}); starting fresh");
                    }
                }
            }
        }
        if !resumed {
            for &v in init_active {
                shared.bitmaps[0].set(v as usize);
            }
        }
        // the starting round's direction, single-threaded (worker 0
        // decides every later round at bookkeeping): pull only on
        // opted-in programs, and under Auto only when the initial
        // frontier is dense enough
        let init_frontier = shared.bitmaps[start_round % 2].count();
        let pull0 = program.supports_pull()
            && match cfg.mode {
                RunMode::Push => false,
                RunMode::Pull => true,
                RunMode::Auto => {
                    init_frontier > 0 && init_frontier as f64 >= cfg.pull_density * n as f64
                }
            };
        shared.pull_round.store(pull0, Ordering::Relaxed);
        if let Some(tr) = &shared.trace {
            tr.lock().unwrap().set_initial_frontier(init_frontier as u64);
        }

        let t0 = Instant::now();
        let ncores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        std::thread::scope(|s| {
            for wid in 0..workers {
                let shared = &shared;
                s.spawn(move || {
                    if cfg.pin_workers {
                        // affinity is per-thread, so the pin happens
                        // inside the worker; failure (denied syscall,
                        // non-Linux) just means running unpinned
                        let _ = crate::util::affinity::pin_to_core(wid % ncores);
                    }
                    Self::worker_loop(program, source, shared, wid, workers, n, cfg);
                });
            }
        });
        let wall = t0.elapsed();
        // fold the transport's memory/allocation accounting into the
        // engine counters (single-threaded: workers have joined)
        shared.stats.peak_msg_bytes.store(shared.plane.peak_msg_bytes(), Ordering::Relaxed);
        shared.stats.msg_allocs.store(shared.plane.msg_allocs(), Ordering::Relaxed);
        let io_final = source.io_stats().snapshot();
        let io = io_final.delta(&io_before);
        // close the trace against the post-join snapshot so straggler
        // async I/O lands in the final round's delta (exact-sum invariant)
        let trace = shared.trace.map(|m| {
            let mut t = m.into_inner().unwrap();
            t.finish(io_final);
            t
        });
        let failure = shared.failure.into_inner().unwrap();
        RunReport {
            rounds: shared.stats.rounds.load(Ordering::Relaxed),
            wall,
            engine: shared.stats.snapshot(),
            io,
            trace,
            failure,
        }
    }

    /// Rebuild a run's starting state from a checkpoint image: program
    /// sections, the frontier bitmap at the saved round's parity, the
    /// pending folded messages (into sender lane 0 — the delivery fold
    /// reproduces the pre-fold value bit-identically), and the round
    /// counter. Validates everything *before* mutating anything, so a
    /// failed restore leaves the shared state fresh.
    fn restore_from<P: VertexProgram>(
        program: &P,
        shared: &Shared<P::Msg>,
        img: &CheckpointImage,
        n: usize,
    ) -> crate::Result<usize> {
        anyhow::ensure!(
            img.n == n as u64,
            "checkpoint is for a {}-vertex graph, this run has {n}",
            img.n
        );
        let msg_size = std::mem::size_of::<P::Msg>();
        anyhow::ensure!(
            img.msg_size == msg_size as u64,
            "checkpoint message size {} != program message size {msg_size}",
            img.msg_size
        );
        let Transport::Combine(lanes) = &shared.plane.transport else {
            anyhow::bail!("checkpoint resume requires the combiner transport");
        };
        program.checkpoint_restore(img)?;
        let k = img.round as usize;
        let parity = k % 2;
        let bm = &shared.bitmaps[parity];
        for (wi, &word) in img.frontier_words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                bm.set(wi * 64 + b);
                w &= w - 1;
            }
        }
        lanes.restore_pending(
            parity,
            img.msg_dsts.iter().enumerate().map(|(i, &dst)| {
                // messages were saved as raw bytes; the save path gated
                // on a Copy-like (needs_drop-free) message type, so a
                // byte-wise read reconstructs the exact value
                let m = unsafe {
                    std::ptr::read_unaligned(
                        img.msg_bytes[i * msg_size..].as_ptr() as *const P::Msg
                    )
                };
                (dst, m)
            }),
        );
        shared.plane.add_pending(parity, img.pending as usize);
        shared.round.store(k, Ordering::Release);
        Ok(k)
    }

    /// Record a permanent fetch failure (first writer wins). The worker
    /// then rides the round's remaining barriers normally — no panic, no
    /// wedged crew — and worker 0 reads the flag at bookkeeping to wind
    /// the run down at the boundary.
    fn record_failure<M>(shared: &Shared<M>, e: &anyhow::Error) {
        let mut f = shared.failure.lock().unwrap();
        if f.is_none() {
            *f = Some(format!("{e:#}"));
        }
    }

    fn worker_loop<P: VertexProgram>(
        program: &P,
        source: &dyn EdgeSource,
        shared: &Shared<P::Msg>,
        wid: usize,
        workers: usize,
        n: usize,
        cfg: &EngineConfig,
    ) {
        let mut ctx = WorkerCtx {
            worker: wid,
            num_workers: workers,
            num_vertices: n,
            round: 0,
            in_message_phase: false,
            source,
            index: source.index(),
            bitmaps: &shared.bitmaps,
            plane: &shared.plane,
            c_p2p: 0,
            c_multicast: 0,
            c_deliveries: 0,
            c_vertex_runs: 0,
            c_steals: 0,
            c_combined: 0,
            c_pending: 0,
            red_add: [0.0; N_RED_SLOTS],
            red_max: [f64::NEG_INFINITY; N_RED_SLOTS],
        };
        // per-worker fetch slots: each holds one batch's requests plus
        // its decoded-edge arena, reused across every batch of the run
        // (allocation-free once warm). `fetch_window + 1` slots bound
        // how many batches can be in flight at once.
        let mut slots: Vec<FetchSlot> =
            (0..cfg.fetch_window + 1).map(|_| FetchSlot::new()).collect();
        // combiner-lane delivery scratch (one word slot per sender lane,
        // reused every round — the sweep allocates nothing once warm)
        let mut lane_words: Vec<u64> = Vec::with_capacity(workers);
        // pinned workers pre-touch their own combiner sender slabs so
        // any lazily-mapped (zero) pages fault in on the pinned core and
        // first-touch lands them on its NUMA node. Fresh runs only: no
        // touched bit exists anywhere yet and round-0 sends write only a
        // worker's own lane, so the writes race with nothing; a resumed
        // run has restored messages in flight and skips the warm-up.
        if cfg.pin_workers && !cfg.resume {
            if let Transport::Combine(lanes) = &shared.plane.transport {
                lanes.warm_lane(wid);
            }
        }

        loop {
            let round = shared.round.load(Ordering::Acquire);
            ctx.round = round;
            let cur_parity = round % 2;
            let nxt_parity = (round + 1) % 2;
            // this round's direction: stored by worker 0 before the
            // round counter, published to us by the final barrier
            let pull = shared.pull_round.load(Ordering::Relaxed);
            let t0 = Instant::now();

            // ---- phase A: deliver messages sent last round -------------
            // Lane ownership makes this lock-free both ways: combiner
            // lanes are swept per destination range (one folded message
            // per touched vertex), queue lanes are drained per sender
            // (each lane written by exactly one worker last round).
            // Handler sends target the *other* parity, never these lanes.
            ctx.in_message_phase = true;
            match &shared.plane.transport {
                Transport::Combine(lanes) => {
                    // reset this worker's send-lane sparsity index before
                    // any round-r send can happen (its readers finished a
                    // full round ago)
                    lanes.begin_send_round(nxt_parity, wid);
                    let (lo, hi) = owner_span(wid, workers, n);
                    lanes.deliver(cur_parity, lo, hi, &mut lane_words, |v, m| {
                        ctx.c_deliveries += 1;
                        program.run_on_message(&mut ctx, v, m);
                    });
                }
                Transport::Queue(q) => {
                    for s in 0..workers {
                        q.drain(cur_parity, s, wid, |d| match d {
                            Delivery::P2p(v, m) => {
                                ctx.c_deliveries += 1;
                                program.run_on_message(&mut ctx, *v, m);
                            }
                            Delivery::Multi(dsts, m) => {
                                ctx.c_deliveries += dsts.len() as u64;
                                for &v in dsts.iter() {
                                    program.run_on_message(&mut ctx, v, m);
                                }
                            }
                        });
                    }
                }
            }
            ctx.flush_sends();
            let t1 = Instant::now();
            let phase_a = t1 - t0;
            shared.barrier.wait();
            let t2 = Instant::now();

            // ---- phase B: vertex phase over the activation bitmap ------
            // Chunked claim + steal (see module docs), feeding the
            // completion-driven fetch pipeline: up to `fetch_window`
            // batches are in flight as async submissions while the
            // worker processes whichever batch completed first —
            // FlashGraph's overlap of computation with asynchronous I/O
            // (EXPERIMENTS.md §Perf).
            ctx.in_message_phase = false;
            let current = &shared.bitmaps[cur_parity];
            let mut io_wait_ns = 0u64;
            let mut wait = WaitStats::default();
            let mut blocks_skipped = 0u64;
            if pull {
                // ---- B1: edge-less pass over the live frontier --------
                // run_on_vertex fires once per active vertex exactly as
                // a push round would, but with no fetched edges: per-
                // vertex state updates and pull stashes (e.g. PageRank's
                // share) land here, while edge traffic is deferred to
                // B2's pull sweep. Non-clearing: B2 still reads
                // `current.get(src)`; worker 0 retires the bitmap at
                // bookkeeping.
                let empty = VertexEdges::default();
                let mut stream = FrontierStream {
                    bm: current,
                    claimer: ChunkClaimer::new(&shared.cursors, shared.nchunks, workers, wid),
                    cur: None,
                    n,
                    clear: false,
                };
                while let Some(v) = stream.next_vertex() {
                    ctx.c_vertex_runs += 1;
                    program.run_on_vertex(&mut ctx, v as VertexId, &empty);
                }
                ctx.c_steals += stream.claimer.steals;
                // B1 → B2 barrier: stashes written by any worker must be
                // visible before any worker pulls from them
                shared.barrier.wait();

                // ---- B2: pull sweep over destination chunks -----------
                let fsummary = frontier_summary_word(current, n);
                let pull_req = program.pull_request();
                let index = source.index();
                let mut claimer =
                    ChunkClaimer::new(&shared.pull_cursors, shared.nchunks, workers, wid);
                let piped = run_pipeline(
                    source,
                    &mut slots,
                    cfg.fetch_window,
                    &mut io_wait_ns,
                    &mut wait,
                    |slot| loop {
                        let Some((c, _)) = claimer.next_chunk() else { return false };
                        // block filter: a published summary disjoint
                        // from the frontier proves no active source has
                        // an edge into this chunk — skip its I/O
                        let known = shared.block_src[c].load(Ordering::Relaxed);
                        if known != 0 && known & fsummary == 0 {
                            blocks_skipped += 1;
                            continue;
                        }
                        let start = c * CHUNK_BITS;
                        let end = ((c + 1) * CHUNK_BITS).min(n);
                        slot.reqs.clear();
                        for v in start..end {
                            let vid = v as VertexId;
                            let deg = match pull_req {
                                EdgeRequest::In => index.in_deg(vid) as u64,
                                EdgeRequest::Out => index.out_deg(vid) as u64,
                                EdgeRequest::Both => {
                                    index.in_deg(vid) as u64 + index.out_deg(vid) as u64
                                }
                                EdgeRequest::None => 0,
                            };
                            if deg > 0 {
                                slot.reqs.push((vid, pull_req));
                            }
                        }
                        if slot.reqs.is_empty() {
                            continue;
                        }
                        slot.tag = c;
                        return true;
                    },
                    |slot| {
                        let mut bits = 0u64;
                        for (&(dst, _), e) in slot.reqs.iter().zip(slot.edges()) {
                            let (a, b): (&[VertexId], &[VertexId]) = match pull_req {
                                EdgeRequest::In => (&e.in_neighbors, &[]),
                                EdgeRequest::Out => (&e.out_neighbors, &[]),
                                _ => (&e.in_neighbors, &e.out_neighbors),
                            };
                            for &u in a.iter().chain(b.iter()) {
                                bits |= 1u64 << source_bucket(u, n);
                                if current.get(u as usize) {
                                    if let Some(m) = program.pull_message(u, dst) {
                                        ctx.send(dst, m);
                                    }
                                }
                            }
                        }
                        // first full scan publishes the chunk's source
                        // summary (static graph → the value is final;
                        // one claimant per chunk per round, and rounds
                        // are barrier-separated)
                        if bits != 0 && shared.block_src[slot.tag].load(Ordering::Relaxed) == 0
                        {
                            shared.block_src[slot.tag].store(bits, Ordering::Relaxed);
                        }
                    },
                );
                if let Err(e) = piped {
                    Self::record_failure(shared, &e);
                }
            } else {
                let mut stream = FrontierStream {
                    bm: current,
                    claimer: ChunkClaimer::new(&shared.cursors, shared.nchunks, workers, wid),
                    cur: None,
                    n,
                    clear: true,
                };
                let piped = run_pipeline(
                    source,
                    &mut slots,
                    cfg.fetch_window,
                    &mut io_wait_ns,
                    &mut wait,
                    |slot| {
                        slot.reqs.clear();
                        while let Some(v) = stream.next_vertex() {
                            let v = v as VertexId;
                            slot.reqs.push((v, program.edge_request(v)));
                            if slot.reqs.len() >= cfg.batch {
                                break;
                            }
                        }
                        !slot.reqs.is_empty()
                    },
                    |slot| {
                        ctx.c_vertex_runs += slot.reqs.len() as u64;
                        let edges = slot.edges();
                        for (i, &(v, _)) in slot.reqs.iter().enumerate() {
                            // pull the next vertex's decoded neighbor
                            // arrays toward L1 while this one runs — the
                            // arena layout is bitmap-dependent, so the
                            // hardware prefetcher can't see this stride
                            if let Some(nx) = edges.get(i + 1) {
                                if let Some(f) = nx.in_neighbors.first() {
                                    crate::util::prefetch_read(f);
                                }
                                if let Some(f) = nx.out_neighbors.first() {
                                    crate::util::prefetch_read(f);
                                }
                            }
                            program.run_on_vertex(&mut ctx, v, &edges[i]);
                        }
                    },
                );
                if let Err(e) = piped {
                    Self::record_failure(shared, &e);
                }
                ctx.c_steals += stream.claimer.steals;
            }
            ctx.flush_sends();

            let t3 = Instant::now();
            // merge local counters + publish this worker's reductions
            shared.stats.p2p_msgs.fetch_add(ctx.c_p2p, Ordering::Relaxed);
            shared.stats.multicast_msgs.fetch_add(ctx.c_multicast, Ordering::Relaxed);
            shared.stats.deliveries.fetch_add(ctx.c_deliveries, Ordering::Relaxed);
            shared.stats.combined_msgs.fetch_add(ctx.c_combined, Ordering::Relaxed);
            shared.stats.vertex_runs.fetch_add(ctx.c_vertex_runs, Ordering::Relaxed);
            shared.stats.steals.fetch_add(ctx.c_steals, Ordering::Relaxed);
            shared.stats.phase_a_ns.fetch_add(phase_a.as_nanos() as u64, Ordering::Relaxed);
            shared.stats.phase_b_ns.fetch_add((t3 - t2).as_nanos() as u64, Ordering::Relaxed);
            shared.stats.io_wait_ns.fetch_add(io_wait_ns, Ordering::Relaxed);
            shared.stats.blocks_skipped.fetch_add(blocks_skipped, Ordering::Relaxed);
            shared.stats.park_ns.fetch_add(wait.park_ns, Ordering::Relaxed);
            shared.stats.backoff_events.fetch_add(wait.backoff_events, Ordering::Relaxed);
            ctx.c_p2p = 0;
            ctx.c_multicast = 0;
            ctx.c_deliveries = 0;
            ctx.c_vertex_runs = 0;
            ctx.c_steals = 0;
            ctx.c_combined = 0;
            // own-slot write, merged by worker 0 after the barrier below
            // (contention-free: the old shared mutex is gone)
            shared.reductions.set(wid, (ctx.red_add, ctx.red_max));
            ctx.red_add = [0.0; N_RED_SLOTS];
            ctx.red_max = [f64::NEG_INFINITY; N_RED_SLOTS];
            if shared.trace.is_some() {
                // publish this round's phase timings for worker 0's
                // trace sample (own-slot write, read after the barrier)
                shared.phase_ns.set(
                    wid,
                    (
                        phase_a.as_nanos() as u64,
                        (t3 - t2).as_nanos() as u64,
                        (t2 - t1).as_nanos() as u64,
                        io_wait_ns,
                    ),
                );
            }
            shared.barrier.wait();
            let t4 = Instant::now();

            // ---- round bookkeeping (worker 0 only) ---------------------
            if wid == 0 {
                shared.stats.rounds.fetch_add(1, Ordering::Relaxed);
                if pull {
                    shared.stats.pull_rounds.fetch_add(1, Ordering::Relaxed);
                    // B1 streamed the frontier non-clearing so B2 could
                    // keep testing `current.get(src)` — retire it now so
                    // round r+2's parity reuse starts clean
                    current.clear_all();
                }
                // merge the per-worker reduction slots (every worker
                // overwrote its slot before the barrier above)
                let mut red_add = [0.0; N_RED_SLOTS];
                let mut red_max = [f64::NEG_INFINITY; N_RED_SLOTS];
                for w in 0..workers {
                    let (a, m) = shared.reductions.get(w);
                    for i in 0..N_RED_SLOTS {
                        red_add[i] += a[i];
                        if m[i] > red_max[i] {
                            red_max[i] = m[i];
                        }
                    }
                }
                // pending is one relaxed load (the counter was batched in
                // by every worker before the barrier) — read once; the
                // end hook cannot send, so no recount is needed
                let pending = shared.plane.pending(nxt_parity);
                let next = &shared.bitmaps[nxt_parity];
                let mut end = EndCtx {
                    round,
                    num_vertices: n,
                    next_active: next.count(),
                    pending_msgs: pending,
                    next_bitmap: next,
                    red_add,
                    red_max,
                    stop_requested: false,
                    continue_requested: false,
                };
                program.run_on_iteration_end(&mut end);
                let stop_requested = end.stop_requested;
                let continue_requested = end.continue_requested;
                // recount activations after the hook (it may have
                // activated vertices — unlike pending, which it can't
                // change; the old second lock-every-queue scan is gone)
                let next_active = next.count();
                // the current parity was fully drained in phase A; zero
                // its counter so round r+2's senders start clean
                shared.plane.reset_pending(cur_parity);
                if let Some(tr) = &shared.trace {
                    // every worker merged its round-r counters before
                    // the barrier above, so these cumulative loads are
                    // exact for rounds 0..=r
                    let st = &shared.stats;
                    let eng = EngineCum {
                        sent: st.p2p_msgs.load(Ordering::Relaxed)
                            + st.multicast_msgs.load(Ordering::Relaxed),
                        delivered: st.deliveries.load(Ordering::Relaxed),
                        combined: st.combined_msgs.load(Ordering::Relaxed),
                        vertex_runs: st.vertex_runs.load(Ordering::Relaxed),
                        steals: st.steals.load(Ordering::Relaxed),
                        blocks_skipped: st.blocks_skipped.load(Ordering::Relaxed),
                    };
                    let io_now = source.io_stats().snapshot();
                    tr.lock().unwrap().record(
                        round as u64,
                        next_active as u64,
                        pull,
                        eng,
                        io_now,
                        (0..workers).map(|w| shared.phase_ns.get(w)),
                    );
                }
                // per-run deadline: checked at the same consistent cut as
                // cancellation. First-writer-wins into the shared failure
                // slot, so it rides the existing failure → report →
                // Failed-job path (never the Cancelled one).
                if let Some(deadline) = cfg.deadline {
                    if std::time::Instant::now() >= deadline {
                        let mut slot = shared.failure.lock().unwrap();
                        if slot.is_none() {
                            *slot = Some(format!(
                                "deadline exceeded at round {round}"
                            ));
                        }
                    }
                }
                let cancelled =
                    cfg.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed));
                let failed = shared.failure.lock().unwrap().is_some();
                let converged = next_active == 0 && pending == 0 && !continue_requested;
                let done = failed
                    || stop_requested
                    || cancelled
                    || converged
                    || round + 1 >= cfg.max_rounds;
                // ---- round-boundary checkpoint -------------------------
                // Worker 0 is single-threaded here (everyone else parked
                // between barriers), so the cut is a consistent "start of
                // round r+1": program O(n) state, the next frontier, and
                // the folded messages pending for round r+1. Periodic
                // every `checkpoint_every` rounds, plus a final cut when
                // the run stops early (cancel / max_rounds) so a resumed
                // job loses no completed work. Never written on failure
                // (the state may be partial); removed on convergence so a
                // finished job leaves no stale snapshot behind.
                if cfg.checkpoint_every > 0 && program.checkpointable() {
                    if let Some(path) = &cfg.checkpoint_path {
                        let eligible = !std::mem::needs_drop::<P::Msg>()
                            && matches!(&shared.plane.transport, Transport::Combine(_));
                        let stopping_early = cancelled || round + 1 >= cfg.max_rounds;
                        let periodic =
                            !done && (round as u64 + 1) % cfg.checkpoint_every == 0;
                        if failed || (done && !stopping_early) {
                            // converged / stopped / failed: a snapshot is
                            // either stale or unsafe
                            if done && !failed {
                                let _ = std::fs::remove_file(path);
                            }
                        } else if eligible && (periodic || stopping_early) {
                            let mut w = CheckpointWriter::new();
                            program.checkpoint_save(&mut w);
                            let Transport::Combine(lanes) = &shared.plane.transport
                            else {
                                unreachable!()
                            };
                            let pend = lanes.fold_pending(nxt_parity);
                            let msg_size = std::mem::size_of::<P::Msg>();
                            let mut dsts = Vec::with_capacity(pend.len());
                            let mut bytes = Vec::with_capacity(pend.len() * msg_size);
                            for (v, m) in &pend {
                                dsts.push(*v);
                                // gated on needs_drop-free messages, so
                                // the raw bytes are the full value
                                let p = m as *const P::Msg as *const u8;
                                bytes.extend_from_slice(unsafe {
                                    std::slice::from_raw_parts(p, msg_size)
                                });
                            }
                            let hdr = CheckpointHeader {
                                round: round as u64 + 1,
                                n: n as u64,
                                frontier: next,
                                pending: pending as u64,
                                msg_size: msg_size as u64,
                                msg_dsts: &dsts,
                                msg_bytes: &bytes,
                            };
                            match checkpoint::save(path, &hdr, &w) {
                                Ok(written) => {
                                    shared
                                        .stats
                                        .checkpoints
                                        .fetch_add(1, Ordering::Relaxed);
                                    shared
                                        .stats
                                        .checkpoint_bytes
                                        .fetch_add(written, Ordering::Relaxed);
                                }
                                Err(e) => eprintln!(
                                    "graphyti: checkpoint write failed: {e:#}"
                                ),
                            }
                        }
                    }
                }
                // rewind every chunk cursor (frontier and pull sweeps)
                // for the next round (published to the other workers by
                // the barrier below)
                for w in 0..workers {
                    let start = chunk_span(w, workers, shared.nchunks).0;
                    shared.cursors[w].store(start, Ordering::Relaxed);
                    shared.pull_cursors[w].store(start, Ordering::Relaxed);
                }
                // next round's direction, from the frontier the hook saw
                let next_pull = program.supports_pull()
                    && match cfg.mode {
                        RunMode::Push => false,
                        RunMode::Pull => true,
                        RunMode::Auto => {
                            next_active > 0
                                && next_active as f64 >= cfg.pull_density * n as f64
                        }
                    };
                shared.pull_round.store(next_pull, Ordering::Relaxed);
                shared.stop.store(done, Ordering::Release);
                shared.round.store(round + 1, Ordering::Release);
            }
            let t5 = Instant::now();
            shared.barrier.wait();
            let t6 = Instant::now();
            // busy = both work phases (+ bookkeeping on worker 0);
            // idle = the three barrier waits
            let busy = (t1 - t0) + (t3 - t2) + (t5 - t4);
            let idle = (t2 - t1) + (t4 - t3) + (t6 - t5);
            shared.stats.add_worker_busy(wid, busy.as_nanos() as u64);
            shared.stats.add_worker_idle(wid, idle.as_nanos() as u64);
            if shared.stop.load(Ordering::Acquire) {
                break;
            }
        }
        // fold this worker's fetch-path allocation count into the run
        // counters (steady-state-zero once the slot arenas are warm; the
        // trace overhead test pins tracing to not move it)
        shared
            .stats
            .fetch_allocs
            .fetch_add(slots.iter().map(|s| s.allocs()).sum::<u64>(), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::format::VertexEdges;
    use crate::graph::gen;
    use crate::graph::source::MemGraph;
    use crate::util::SharedVec;

    /// BFS levels via messages: the canonical engine smoke test. Levels
    /// are min-combinable, so this also exercises the combiner lanes.
    struct Bfs {
        level: SharedVec<i64>,
    }

    impl VertexProgram for Bfs {
        type Msg = i64; // proposed level

        fn edge_request(&self, _v: VertexId) -> EdgeRequest {
            EdgeRequest::Out
        }

        fn combiner(&self) -> Option<crate::engine::messages::Combiner<i64>> {
            Some(crate::engine::messages::Combiner {
                identity: || i64::MAX,
                combine: |a, b| *a = (*a).min(*b),
            })
        }

        fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, edges: &VertexEdges) {
            let my = *self.level.get(v as usize);
            ctx.multicast(&edges.out_neighbors, my + 1);
        }

        fn run_on_message(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, msg: &i64) {
            let cur = self.level.get_mut(v as usize);
            if *cur < 0 || *msg < *cur {
                *cur = *msg;
                ctx.activate(v);
            }
        }
    }

    fn bfs_levels(n: usize, edges: &[(VertexId, VertexId)], src: VertexId, workers: usize) -> Vec<i64> {
        let g = MemGraph::from_edges(n, edges, true);
        let prog = Bfs { level: SharedVec::new(n, -1) };
        prog.level.set(src as usize, 0);
        let cfg = EngineConfig { workers, batch: 8, ..Default::default() };
        let report = Engine::run(&prog, &g, &[src], &cfg);
        assert!(report.rounds > 0);
        prog.level.to_vec()
    }

    #[test]
    fn bfs_on_path() {
        let edges = gen::path(6);
        let lv = bfs_levels(6, &edges, 0, 3);
        assert_eq!(lv, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn bfs_on_cycle_various_workers() {
        let edges = gen::cycle(10);
        for workers in [1, 2, 4, 7] {
            let lv = bfs_levels(10, &edges, 3, workers);
            for i in 0..10 {
                assert_eq!(lv[i], ((i + 10 - 3) % 10) as i64, "workers={workers}");
            }
        }
    }

    #[test]
    fn bfs_unreachable_stays_unset() {
        // two components: 0->1, 2->3
        let lv = bfs_levels(4, &[(0, 1), (2, 3)], 0, 2);
        assert_eq!(lv, vec![0, 1, -1, -1]);
    }

    #[test]
    fn combiner_and_queue_transports_agree() {
        // the same program on both transports, across worker counts and
        // skew shapes, must produce identical results — the tentpole's
        // core safety property
        let rmat = gen::rmat(9, 4000, 19);
        let star = gen::star(512);
        for (name, edges) in [("rmat", &rmat), ("star", &star)] {
            let g = MemGraph::from_edges(512, edges, true);
            let baseline = {
                let prog = Bfs { level: SharedVec::new(512, -1) };
                prog.level.set(0, 0);
                let cfg = EngineConfig {
                    workers: 1,
                    transport: TransportMode::Queue,
                    ..Default::default()
                };
                Engine::run(&prog, &g, &[0], &cfg);
                prog.level.to_vec()
            };
            for workers in [1, 2, 8] {
                for transport in [TransportMode::Auto, TransportMode::Queue] {
                    let prog = Bfs { level: SharedVec::new(512, -1) };
                    prog.level.set(0, 0);
                    let cfg = EngineConfig { workers, transport, batch: 8, ..Default::default() };
                    let r = Engine::run(&prog, &g, &[0], &cfg);
                    assert_eq!(
                        prog.level.to_vec(),
                        baseline,
                        "{name}: workers={workers} transport={transport:?}"
                    );
                    if transport == TransportMode::Auto {
                        assert_eq!(r.engine.msg_allocs, 0, "combiner path never allocates");
                        assert!(r.engine.peak_msg_bytes > 0);
                    }
                }
            }
        }
    }

    #[test]
    fn combiner_counts_folds_and_delivers_once() {
        // every vertex p2p-sends 1 to vertex 0 with a `+` combiner:
        // vertex 0 must observe the full sum in ONE delivery per round,
        // and all but `workers` sends (one fresh slot per sender lane)
        // must be counted as folds
        struct SumToZero {
            got: SharedVec<u64>,
        }
        impl VertexProgram for SumToZero {
            type Msg = u64;
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn combiner(&self) -> Option<crate::engine::messages::Combiner<u64>> {
                Some(crate::engine::messages::Combiner {
                    identity: || 0,
                    combine: |a, b| *a += *b,
                })
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, u64>, _v: VertexId, _e: &VertexEdges) {
                ctx.send(0, 1);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, u64>, v: VertexId, m: &u64) {
                *self.got.get_mut(v as usize) += *m;
            }
        }
        let n = 600;
        let g = MemGraph::from_edges(n, &gen::path(n), true);
        let workers = 4;
        let prog = SumToZero { got: SharedVec::new(n, 0u64) };
        let all: Vec<VertexId> = (0..n as VertexId).collect();
        let cfg = EngineConfig { workers, ..Default::default() };
        let r = Engine::run(&prog, &g, &all, &cfg);
        assert_eq!(*prog.got.get(0), n as u64, "folded sum must equal the send count");
        assert_eq!(r.engine.p2p_msgs, n as u64);
        // the delivery sweep folds across sender lanes too: vertex 0
        // gets exactly ONE run_on_message for all n sends
        assert_eq!(r.engine.deliveries, 1, "{:?}", r.engine);
        // all sends but the (≤ workers) fresh first-touches were folds
        assert!(
            r.engine.combined_msgs >= (n - workers) as u64 && r.engine.combined_msgs < n as u64,
            "{:?}",
            r.engine
        );
    }

    #[test]
    fn queue_lane_segments_recycle_across_rounds() {
        // one message per round for n-1 rounds: cross-round segment
        // recycling keeps the allocation count bounded by the number of
        // lanes, not the number of rounds
        let n = 256;
        let g = MemGraph::from_edges(n, &gen::path(n), true);
        let prog = Bfs { level: SharedVec::new(n, -1) };
        prog.level.set(0, 0);
        let workers = 2;
        let cfg = EngineConfig {
            workers,
            transport: TransportMode::Queue,
            ..Default::default()
        };
        let r = Engine::run(&prog, &g, &[0], &cfg);
        assert_eq!(r.rounds, n as u64, "path BFS takes one round per hop");
        let lane_bound = (2 * workers * workers) as u64;
        assert!(
            r.engine.msg_allocs <= lane_bound,
            "{} rounds must not allocate more than {} segments (got {})",
            r.rounds,
            lane_bound,
            r.engine.msg_allocs
        );
        assert!(r.engine.peak_msg_bytes > 0);
    }

    #[test]
    fn deterministic_across_worker_counts() {
        // adversarial skew: an RMAT power-law graph AND a star whose
        // whole frontier funnels through one hub — under work stealing
        // any worker may process any vertex, and the result must still
        // be bit-identical across 1/2/8 workers
        let rmat = gen::rmat(9, 4000, 11);
        let star = gen::star(512);
        for (name, n, edges, src) in
            [("rmat", 512usize, &rmat, 0u32), ("star", 512, &star, 0)]
        {
            let baseline = bfs_levels(n, edges, src, 1);
            for workers in [2, 8] {
                let got = bfs_levels(n, edges, src, workers);
                assert_eq!(
                    got, baseline,
                    "{name}: BFS levels must not depend on parallelism (workers={workers})"
                );
            }
        }
    }

    #[test]
    fn pinned_runs_match_unpinned_bit_identically() {
        // pinning (and the lane warm-up it triggers on the combiner
        // transport) is a locality hint: results must be bit-identical
        // with it on or off, at every worker count, on skewed inputs —
        // and the warm-up must not corrupt fold counts or message totals
        let rmat = gen::rmat(9, 4000, 23);
        let star = gen::star(512);
        for (name, edges) in [("rmat", &rmat), ("star", &star)] {
            let g = MemGraph::from_edges(512, edges, true);
            let baseline = {
                let prog = Bfs { level: SharedVec::new(512, -1) };
                prog.level.set(0, 0);
                Engine::run(
                    &prog,
                    &g,
                    &[0],
                    &EngineConfig { workers: 1, ..Default::default() },
                );
                prog.level.to_vec()
            };
            for workers in [1, 2, 8] {
                for pin in [false, true] {
                    let prog = Bfs { level: SharedVec::new(512, -1) };
                    prog.level.set(0, 0);
                    let cfg = EngineConfig {
                        workers,
                        batch: 8,
                        pin_workers: pin,
                        ..Default::default()
                    };
                    let r = Engine::run(&prog, &g, &[0], &cfg);
                    assert_eq!(
                        prog.level.to_vec(),
                        baseline,
                        "{name}: workers={workers} pin={pin}"
                    );
                    assert_eq!(r.engine.msg_allocs, 0, "warm-up must not allocate");
                }
            }
        }
    }

    #[test]
    fn skewed_frontier_triggers_steals() {
        // all activations land in the lowest chunks (worker 0's span):
        // with >1 workers, the others must steal to get any work, and
        // every activated vertex must still run exactly once
        struct Touch {
            ran: SharedVec<u32>,
        }
        impl VertexProgram for Touch {
            type Msg = ();
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
                *self.ran.get_mut(v as usize) += 1;
                // re-activate for several rounds so every worker is up
                // and barrier-synced while the skewed frontier repeats —
                // steals become structural, not a thread-startup race
                if ctx.round() < 4 {
                    ctx.activate(v);
                }
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
        }
        let n = CHUNK_BITS * 32; // 32 chunks
        let g = MemGraph::from_edges(n, &gen::path(n), true);
        let prog = Touch { ran: SharedVec::new(n, 0) };
        // frontier: the first 8 chunks only — worker 0's static span
        let active: Vec<VertexId> = (0..(CHUNK_BITS * 8) as VertexId).collect();
        let cfg = EngineConfig { workers: 4, batch: 64, ..Default::default() };
        let r = Engine::run(&prog, &g, &active, &cfg);
        assert_eq!(r.rounds, 5);
        for v in 0..n {
            let want = if v < CHUNK_BITS * 8 { 5 } else { 0 };
            assert_eq!(*prog.ran.get(v), want, "vertex {v} run count");
        }
        assert!(r.engine.steals > 0, "skewed frontier must induce steals: {:?}", r.engine);
        assert_eq!(r.engine.vertex_runs, 5 * (CHUNK_BITS * 8) as u64);
        assert_eq!(r.engine.worker_busy_ns.len(), 4, "per-worker busy slots tracked");
    }

    #[test]
    fn frontier_bitmap_fully_cleared_after_each_round() {
        // chunk-level word clearing must leave the current bitmap empty
        // after the vertex phase, no matter which worker claimed what —
        // a second engine run on the same Shared would otherwise see
        // ghost activations. Observable effect: a 2-round program's
        // round-0 activations never leak into round 2 (levels stay
        // minimal in BFS re-runs).
        let edges = gen::rmat(9, 3000, 5);
        for workers in [1, 3, 8] {
            let a = bfs_levels(512, &edges, 7, workers);
            let b = bfs_levels(512, &edges, 7, workers);
            assert_eq!(a, b, "repeat runs must agree (workers={workers})");
        }
    }

    /// Counting program: verifies reductions and message counters.
    struct CountDegrees;

    impl VertexProgram for CountDegrees {
        type Msg = ();

        fn edge_request(&self, _v: VertexId) -> EdgeRequest {
            EdgeRequest::Out
        }

        fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, edges: &VertexEdges) {
            ctx.reduce_add(0, edges.out_neighbors.len() as f64);
            ctx.reduce_max(1, edges.out_neighbors.len() as f64);
            let _ = v;
        }

        fn run_on_message(&self, _ctx: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
    }

    #[test]
    fn reductions_merge_across_workers() {
        let edges = gen::star(100); // center 0 has 99 out-edges
        let g = MemGraph::from_edges(100, &edges, true);
        struct Capture {
            inner: CountDegrees,
            total: std::sync::Mutex<f64>,
            max: std::sync::Mutex<f64>,
        }
        impl VertexProgram for Capture {
            type Msg = ();
            fn edge_request(&self, v: VertexId) -> EdgeRequest {
                self.inner.edge_request(v)
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, e: &VertexEdges) {
                self.inner.run_on_vertex(ctx, v, e);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
            fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
                *self.total.lock().unwrap() = ctx.reduction_add(0);
                *self.max.lock().unwrap() = ctx.reduction_max(1);
            }
        }
        let prog = Capture {
            inner: CountDegrees,
            total: std::sync::Mutex::new(0.0),
            max: std::sync::Mutex::new(0.0),
        };
        let all: Vec<VertexId> = (0..100).collect();
        let r = Engine::run(&prog, &g, &all, &EngineConfig { workers: 4, ..Default::default() });
        assert_eq!(r.engine.vertex_runs, 100);
        assert_eq!(*prog.total.lock().unwrap(), 99.0);
        assert_eq!(*prog.max.lock().unwrap(), 99.0);
    }

    /// Message counters: multicast counted once, fanout at delivery.
    #[test]
    fn message_accounting() {
        let edges = gen::star(50);
        let g = MemGraph::from_edges(50, &edges, true);
        let prog = Bfs { level: SharedVec::new(50, -1) };
        prog.level.set(0, 0);
        let r = Engine::run(&prog, &g, &[0], &EngineConfig { workers: 4, ..Default::default() });
        // center multicasts to 49 leaves; leaves have no out-edges
        assert!(r.engine.multicast_msgs >= 1 && r.engine.multicast_msgs <= 4);
        assert_eq!(r.engine.deliveries, 49);
        assert_eq!(r.engine.p2p_msgs, 0);
    }

    #[test]
    fn max_rounds_cap() {
        // self-perpetuating program: vertex reactivates itself forever
        struct Forever;
        impl VertexProgram for Forever {
            type Msg = ();
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
                ctx.activate(v);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
        }
        let g = MemGraph::from_edges(4, &[(0, 1)], true);
        let cfg = EngineConfig { workers: 2, max_rounds: 5, ..Default::default() };
        let r = Engine::run(&Forever, &g, &[0], &cfg);
        assert_eq!(r.rounds, 5);
    }

    #[test]
    fn cancellation_stops_at_round_boundary() {
        // a self-perpetuating program never quiesces; a pre-set cancel
        // token must stop it at the first round boundary
        struct Spin;
        impl VertexProgram for Spin {
            type Msg = ();
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
                ctx.activate(v);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
        }
        let g = MemGraph::from_edges(4, &[(0, 1)], true);
        let token = Arc::new(AtomicBool::new(true));
        let cfg = EngineConfig { workers: 2, cancel: Some(token), ..Default::default() };
        let r = Engine::run(&Spin, &g, &[0], &cfg);
        assert_eq!(r.rounds, 1, "pre-cancelled run must stop at the first boundary");
    }

    #[test]
    fn stop_from_iteration_end() {
        struct StopAt3;
        impl VertexProgram for StopAt3 {
            type Msg = ();
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
                ctx.activate(v);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
            fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
                if ctx.round() == 2 {
                    ctx.stop();
                }
            }
        }
        let g = MemGraph::from_edges(4, &[(0, 1)], true);
        let r = Engine::run(&StopAt3, &g, &[0], &EngineConfig::default());
        assert_eq!(r.rounds, 3);
    }

    #[test]
    fn iteration_end_can_restart_frontier() {
        // nothing active after round 0; hook re-activates vertex 1 once
        struct Restart {
            fired: std::sync::atomic::AtomicBool,
            ran: SharedVec<bool>,
        }
        impl VertexProgram for Restart {
            type Msg = ();
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::None
            }
            fn run_on_vertex(&self, _c: &mut WorkerCtx<'_, ()>, v: VertexId, _e: &VertexEdges) {
                self.ran.set(v as usize, true);
            }
            fn run_on_message(&self, _c: &mut WorkerCtx<'_, ()>, _v: VertexId, _m: &()) {}
            fn run_on_iteration_end(&self, ctx: &mut EndCtx<'_>) {
                if ctx.quiescent() && !self.fired.swap(true, Ordering::SeqCst) {
                    ctx.activate(1);
                }
            }
        }
        let g = MemGraph::from_edges(3, &[(0, 1)], true);
        let prog = Restart {
            fired: AtomicBool::new(false),
            ran: SharedVec::new(3, false),
        };
        let r = Engine::run(&prog, &g, &[0], &EngineConfig::default());
        assert_eq!(r.rounds, 2);
        assert!(*prog.ran.get(0));
        assert!(*prog.ran.get(1));
        assert!(!*prog.ran.get(2));
    }

    /// Pull-capable BFS: level proposals are min-combinable and
    /// synthesizable per edge, so push and pull rounds must agree.
    struct PullBfs {
        level: SharedVec<i64>,
    }

    impl VertexProgram for PullBfs {
        type Msg = i64;

        fn edge_request(&self, _v: VertexId) -> EdgeRequest {
            EdgeRequest::Out
        }

        fn combiner(&self) -> Option<crate::engine::messages::Combiner<i64>> {
            Some(crate::engine::messages::Combiner {
                identity: || i64::MAX,
                combine: |a, b| *a = (*a).min(*b),
            })
        }

        fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, edges: &VertexEdges) {
            let my = *self.level.get(v as usize);
            ctx.multicast(&edges.out_neighbors, my + 1);
        }

        fn run_on_message(&self, ctx: &mut WorkerCtx<'_, i64>, v: VertexId, msg: &i64) {
            let cur = self.level.get_mut(v as usize);
            if *cur < 0 || *msg < *cur {
                *cur = *msg;
                ctx.activate(v);
            }
        }

        fn supports_pull(&self) -> bool {
            true
        }

        fn pull_message(&self, src: VertexId, _dst: VertexId) -> Option<i64> {
            // level[src] is stable through phase B (only run_on_message
            // writes it), exactly the discipline the contract requires
            Some(*self.level.get(src as usize) + 1)
        }
    }

    fn pull_bfs_levels(
        n: usize,
        edges: &[(VertexId, VertexId)],
        src: VertexId,
        workers: usize,
        mode: RunMode,
    ) -> (Vec<i64>, RunReport) {
        let g = MemGraph::from_edges(n, edges, true);
        let prog = PullBfs { level: SharedVec::new(n, -1) };
        prog.level.set(src as usize, 0);
        let cfg = EngineConfig { workers, batch: 8, mode, ..Default::default() };
        let report = Engine::run(&prog, &g, &[src], &cfg);
        (prog.level.to_vec(), report)
    }

    #[test]
    fn source_bucket_and_summary_are_conservative() {
        let n = 1000;
        for v in 0..n {
            assert!(source_bucket(v as VertexId, n) < 64);
        }
        assert_eq!(source_bucket(0, n), 0);
        assert_eq!(source_bucket((n - 1) as VertexId, n), 63);
        let bm = AtomicBitmap::new(n);
        assert_eq!(frontier_summary_word(&bm, n), 0, "empty frontier → empty summary");
        bm.set(0);
        bm.set(537);
        bm.set(999);
        let s = frontier_summary_word(&bm, n);
        // conservative: every active vertex's bucket must be present
        for v in [0u32, 537, 999] {
            assert!(s & (1u64 << source_bucket(v, n)) != 0, "bucket of {v} missing");
        }
    }

    #[test]
    fn pull_rounds_match_push_results() {
        // push vs pull vs auto on skewed and regular shapes, across
        // worker counts: levels must be identical, and forced pull on a
        // supporting program must actually run pull rounds
        let rmat = gen::rmat(9, 4000, 23);
        let star = gen::star(512);
        let cyc = gen::cycle(512);
        for (name, edges) in [("rmat", &rmat), ("star", &star), ("cycle", &cyc)] {
            let (baseline, _) = pull_bfs_levels(512, edges, 0, 1, RunMode::Push);
            for workers in [1, 2, 8] {
                for mode in [RunMode::Push, RunMode::Pull, RunMode::Auto] {
                    let (got, r) = pull_bfs_levels(512, edges, 0, workers, mode);
                    assert_eq!(got, baseline, "{name}: workers={workers} mode={mode:?}");
                    match mode {
                        RunMode::Push => assert_eq!(r.engine.pull_rounds, 0),
                        RunMode::Pull => assert_eq!(
                            r.engine.pull_rounds, r.engine.rounds,
                            "{name}: forced pull must pull every round"
                        ),
                        RunMode::Auto => {}
                    }
                }
            }
        }
    }

    #[test]
    fn auto_mode_pulls_only_on_dense_frontiers() {
        // single-source BFS on a long path: every frontier is one
        // vertex, far below the density threshold → auto never pulls
        let n = 2048;
        let path = gen::path(n);
        let (_, sparse) = pull_bfs_levels(n, &path, 0, 2, RunMode::Auto);
        assert_eq!(sparse.engine.pull_rounds, 0, "sparse frontiers must stay push");
        // full-frontier start on a cycle: round 0 is maximally dense
        let cyc = gen::cycle(512);
        let g = MemGraph::from_edges(512, &cyc, true);
        let prog = PullBfs { level: SharedVec::new(512, -1) };
        prog.level.set(0, 0);
        let all: Vec<VertexId> = (0..512).collect();
        let cfg = EngineConfig { workers: 2, mode: RunMode::Auto, ..Default::default() };
        let r = Engine::run(&prog, &g, &all, &cfg);
        assert!(r.engine.pull_rounds >= 1, "dense round 0 must pull: {:?}", r.engine);
    }

    #[test]
    fn pull_on_unsupporting_program_degrades_to_push() {
        // plain Bfs never opts in: mode=Pull must silently run push and
        // still converge to the same levels
        let edges = gen::rmat(9, 3000, 31);
        let baseline = bfs_levels(512, &edges, 0, 2);
        let g = MemGraph::from_edges(512, &edges, true);
        let prog = Bfs { level: SharedVec::new(512, -1) };
        prog.level.set(0, 0);
        let cfg = EngineConfig { workers: 2, mode: RunMode::Pull, ..Default::default() };
        let r = Engine::run(&prog, &g, &[0], &cfg);
        assert_eq!(prog.level.to_vec(), baseline);
        assert_eq!(r.engine.pull_rounds, 0);
        assert_eq!(r.engine.blocks_skipped, 0);
    }

    #[test]
    fn pull_block_filter_skips_and_stays_correct() {
        // banded graph u → (u + n/2) mod n: every chunk's sources sit
        // half the id space away, so once round 0 publishes the
        // summaries, round 1's one-vertex frontier intersects a single
        // chunk and every other chunk is skipped without I/O
        let n = CHUNK_BITS * 8;
        let edges: Vec<(VertexId, VertexId)> =
            (0..n).map(|u| (u as VertexId, ((u + n / 2) % n) as VertexId)).collect();
        let (push_lv, _) = pull_bfs_levels(n, &edges, 0, 2, RunMode::Push);
        let (pull_lv, r) = pull_bfs_levels(n, &edges, 0, 2, RunMode::Pull);
        assert_eq!(pull_lv, push_lv, "filter must never change results");
        assert!(
            r.engine.blocks_skipped > 0,
            "later pull rounds must skip summary-miss chunks: {:?}",
            r.engine
        );
        assert_eq!(r.engine.pull_rounds, r.engine.rounds);
    }

    #[test]
    fn fetch_window_sizes_agree() {
        // the overlap window must be invisible to results: forced
        // synchronous (0), default (2) and deep (7) pipelines produce
        // identical levels and vertex-run counts
        let edges = gen::rmat(9, 4000, 7);
        let g = MemGraph::from_edges(512, &edges, true);
        let mut runs = vec![];
        for window in [0usize, 2, 7] {
            let prog = Bfs { level: SharedVec::new(512, -1) };
            prog.level.set(0, 0);
            let cfg =
                EngineConfig { workers: 3, batch: 8, fetch_window: window, ..Default::default() };
            let r = Engine::run(&prog, &g, &[0], &cfg);
            runs.push((prog.level.to_vec(), r.engine.vertex_runs));
        }
        assert_eq!(runs[0], runs[1], "window 0 vs 2");
        assert_eq!(runs[0], runs[2], "window 0 vs 7");
    }

    /// Message-phase activation runs the vertex in the same round.
    #[test]
    fn message_activation_same_round() {
        struct TwoHop {
            seen_round: SharedVec<i64>,
        }
        impl VertexProgram for TwoHop {
            type Msg = u8;
            fn edge_request(&self, _v: VertexId) -> EdgeRequest {
                EdgeRequest::Out
            }
            fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, u8>, v: VertexId, e: &VertexEdges) {
                self.seen_round.set(v as usize, ctx.round() as i64);
                ctx.multicast(&e.out_neighbors, 1);
            }
            fn run_on_message(&self, ctx: &mut WorkerCtx<'_, u8>, v: VertexId, _m: &u8) {
                if *self.seen_round.get(v as usize) < 0 {
                    ctx.activate(v); // same-round activation
                }
            }
        }
        let g = MemGraph::from_edges(3, &[(0, 1), (1, 2)], true);
        let prog = TwoHop { seen_round: SharedVec::new(3, -1) };
        let r = Engine::run(&prog, &g, &[0], &EngineConfig { workers: 2, ..Default::default() });
        // round 0: v0 runs, msg to v1. round 1: v1 delivered+activated+runs
        // in the same round, msg to v2. round 2: v2 likewise, sends
        // nothing => quiescent at round 2's barrier.
        assert_eq!(prog.seen_round.to_vec(), vec![0, 1, 2]);
        assert_eq!(r.rounds, 3, "same-round activation: one round per hop");
    }
}
