//! Round-boundary engine checkpoints.
//!
//! SEM's core bargain — O(n) vertex state in memory, O(m) edges on disk
//! — makes crash recovery cheap: the only state worth persisting is the
//! per-vertex arrays, the activation frontier and the undelivered
//! message folds, all O(n). This module defines the on-disk snapshot
//! format and the typed section API vertex programs use to save and
//! restore their `SharedVec` state; `runner.rs` decides *when* to write
//! (the worker-0 bookkeeping step of a round is the engine's only
//! single-threaded quiescent point, so a snapshot taken there is a
//! consistent cut by construction — see ARCHITECTURE.md §"Durability &
//! recovery").
//!
//! Format (version 1, little-endian, single file):
//!
//! ```text
//! "GYCK" | version u32 | round u64 | n u64
//! | frontier: nwords u64, words [u64 × nwords]
//! | pending u64
//! | messages: count u64, msg_size u64, (dst u32, msg [msg_size]) × count
//! | sections: count u64,
//!     (name_len u8, name, elem_kind u8, len u64, raw bytes) × count
//! | fnv1a-64 checksum over everything above
//! ```
//!
//! Writes go to a `.tmp` sibling and are published by `rename`, so a
//! torn write is never observable under the real path; loads verify
//! magic, version and checksum and fail cleanly on any mismatch — a
//! corrupt or truncated checkpoint degrades to "no checkpoint", never
//! to wrong answers.

use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::util::bitmap::AtomicBitmap;
use crate::util::shared_vec::SharedVec;

/// File magic: "GYCK" (GraphYti ChecKpoint).
pub const MAGIC: [u8; 4] = *b"GYCK";
/// Current format version.
pub const VERSION: u32 = 1;

/// Section element kinds (one byte on disk).
const KIND_F64: u8 = 0;
const KIND_U32: u8 = 1;
const KIND_U64: u8 = 2;
const KIND_I64: u8 = 3;

fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_F64 => "f64",
        KIND_U32 => "u32",
        KIND_U64 => "u64",
        KIND_I64 => "i64",
        _ => "unknown",
    }
}

/// FNV-1a 64-bit over a byte slice — cheap, dependency-free, and good
/// enough to catch torn writes and bit rot (not an integrity MAC).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Collector for a program's typed O(n) state sections. The engine owns
/// the header (round, frontier, pending messages); the vertex program
/// contributes named sections via [`VertexProgram::checkpoint_save`].
///
/// [`VertexProgram::checkpoint_save`]: crate::engine::VertexProgram::checkpoint_save
#[derive(Default)]
pub struct CheckpointWriter {
    sections: Vec<(String, u8, u64, Vec<u8>)>,
}

impl CheckpointWriter {
    /// Empty writer.
    pub fn new() -> Self {
        CheckpointWriter { sections: Vec::new() }
    }

    fn push(&mut self, name: &str, kind: u8, len: u64, raw: Vec<u8>) {
        debug_assert!(name.len() <= u8::MAX as usize, "section name too long");
        self.sections.push((name.to_string(), kind, len, raw));
    }

    /// Save an `f64` state array under `name`.
    pub fn put_f64(&mut self, name: &str, v: &SharedVec<f64>) {
        let mut raw = Vec::with_capacity(v.len() * 8);
        for x in v.iter() {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        self.push(name, KIND_F64, v.len() as u64, raw);
    }

    /// Save a `u32` state array under `name`.
    pub fn put_u32(&mut self, name: &str, v: &SharedVec<u32>) {
        let mut raw = Vec::with_capacity(v.len() * 4);
        for x in v.iter() {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        self.push(name, KIND_U32, v.len() as u64, raw);
    }

    /// Save a `u64` state array under `name`.
    pub fn put_u64(&mut self, name: &str, v: &SharedVec<u64>) {
        let mut raw = Vec::with_capacity(v.len() * 8);
        for x in v.iter() {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        self.push(name, KIND_U64, v.len() as u64, raw);
    }

    /// Save an `i64` state array under `name`.
    pub fn put_i64(&mut self, name: &str, v: &SharedVec<i64>) {
        let mut raw = Vec::with_capacity(v.len() * 8);
        for x in v.iter() {
            raw.extend_from_slice(&x.to_le_bytes());
        }
        self.push(name, KIND_I64, v.len() as u64, raw);
    }

    /// Number of collected sections.
    pub fn len(&self) -> usize {
        self.sections.len()
    }

    /// True if no sections were collected.
    pub fn is_empty(&self) -> bool {
        self.sections.is_empty()
    }
}

/// Engine-side inputs to a snapshot: everything the runner knows at the
/// round barrier that the program does not.
pub struct CheckpointHeader<'a> {
    /// Round the restored run will start at (the round *after* the
    /// barrier the snapshot was cut at).
    pub round: u64,
    /// Vertex count (restore sanity check).
    pub n: u64,
    /// Activation frontier for `round` (the bitmap at parity
    /// `round % 2`).
    pub frontier: &'a AtomicBitmap,
    /// The message plane's pending count for `round`'s parity.
    pub pending: u64,
    /// Size in bytes of one message value (0 when no messages follow).
    pub msg_size: u64,
    /// Destination vertex per undelivered fold.
    pub msg_dsts: &'a [u32],
    /// Raw little-endian message payloads, `msg_size` bytes each.
    pub msg_bytes: &'a [u8],
}

/// Serialize and atomically publish a snapshot at `path`. Returns the
/// number of bytes written (for the `checkpoint_bytes` counter).
pub fn save(path: &Path, hdr: &CheckpointHeader<'_>, w: &CheckpointWriter) -> crate::Result<u64> {
    debug_assert_eq!(hdr.msg_dsts.len() as u64 * hdr.msg_size, hdr.msg_bytes.len() as u64);
    let mut buf = Vec::with_capacity(
        64 + hdr.n as usize / 8
            + hdr.msg_bytes.len()
            + w.sections.iter().map(|(_, _, _, r)| r.len() + 16).sum::<usize>(),
    );
    buf.extend_from_slice(&MAGIC);
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.extend_from_slice(&hdr.round.to_le_bytes());
    buf.extend_from_slice(&hdr.n.to_le_bytes());
    let nwords = (hdr.n as usize).div_ceil(64);
    buf.extend_from_slice(&(nwords as u64).to_le_bytes());
    for wi in 0..nwords {
        buf.extend_from_slice(&hdr.frontier.word(wi).to_le_bytes());
    }
    buf.extend_from_slice(&hdr.pending.to_le_bytes());
    buf.extend_from_slice(&(hdr.msg_dsts.len() as u64).to_le_bytes());
    buf.extend_from_slice(&hdr.msg_size.to_le_bytes());
    for (i, dst) in hdr.msg_dsts.iter().enumerate() {
        buf.extend_from_slice(&dst.to_le_bytes());
        let off = i * hdr.msg_size as usize;
        buf.extend_from_slice(&hdr.msg_bytes[off..off + hdr.msg_size as usize]);
    }
    buf.extend_from_slice(&(w.sections.len() as u64).to_le_bytes());
    for (name, kind, len, raw) in &w.sections {
        buf.push(name.len() as u8);
        buf.extend_from_slice(name.as_bytes());
        buf.push(*kind);
        buf.extend_from_slice(&len.to_le_bytes());
        buf.extend_from_slice(raw);
    }
    let ck = fnv1a(&buf);
    buf.extend_from_slice(&ck.to_le_bytes());

    // tmp + rename: a crash mid-write leaves only the tmp file, and the
    // previous published snapshot (if any) stays intact and loadable
    let tmp = path.with_extension("ckpt-tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("create {}", tmp.display()))?;
        std::io::Write::write_all(&mut f, &buf)
            .with_context(|| format!("write {}", tmp.display()))?;
        f.sync_all().with_context(|| format!("fsync {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publish {} -> {}", tmp.display(), path.display()))?;
    // a rename survives a crash only once the parent directory's entry
    // is on stable storage too
    crate::util::fsync_parent_dir(path);
    Ok(buf.len() as u64)
}

/// A parsed, checksum-verified snapshot.
pub struct CheckpointImage {
    /// Round the restored run starts at.
    pub round: u64,
    /// Vertex count at save time.
    pub n: u64,
    /// Raw frontier words (bit `v` set ⇒ vertex `v` active at `round`).
    pub frontier_words: Vec<u64>,
    /// Message-plane pending count for `round`'s parity.
    pub pending: u64,
    /// Size of one message value, bytes.
    pub msg_size: u64,
    /// Destination per undelivered message fold.
    pub msg_dsts: Vec<u32>,
    /// Concatenated message payloads, `msg_size` bytes each.
    pub msg_bytes: Vec<u8>,
    sections: Vec<(String, u8, u64, Vec<u8>)>,
}

/// Little-endian cursor over the snapshot body.
struct Cursor<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> crate::Result<&'a [u8]> {
        ensure!(self.pos + n <= self.bytes.len(), "checkpoint truncated at byte {}", self.pos);
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> crate::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> crate::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> crate::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

impl CheckpointImage {
    /// Read and verify a snapshot. Any structural damage — wrong magic,
    /// version skew, truncation, checksum mismatch — is an error; the
    /// caller treats it as "no checkpoint" and starts from round 0.
    pub fn load(path: &Path) -> crate::Result<CheckpointImage> {
        let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
        ensure!(bytes.len() >= MAGIC.len() + 4 + 8, "checkpoint too short ({} B)", bytes.len());
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let want = u64::from_le_bytes(tail.try_into().unwrap());
        let got = fnv1a(body);
        ensure!(got == want, "checkpoint checksum mismatch ({got:#x} != {want:#x})");
        let mut c = Cursor { bytes: body, pos: 0 };
        ensure!(c.take(4)? == MAGIC, "bad checkpoint magic");
        let version = c.u32()?;
        ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let round = c.u64()?;
        let n = c.u64()?;
        let nwords = c.u64()? as usize;
        ensure!(nwords == (n as usize).div_ceil(64), "frontier word count mismatch");
        let mut frontier_words = Vec::with_capacity(nwords);
        for _ in 0..nwords {
            frontier_words.push(c.u64()?);
        }
        let pending = c.u64()?;
        let msg_count = c.u64()? as usize;
        let msg_size = c.u64()?;
        let mut msg_dsts = Vec::with_capacity(msg_count);
        let mut msg_bytes = Vec::with_capacity(msg_count * msg_size as usize);
        for _ in 0..msg_count {
            msg_dsts.push(c.u32()?);
            msg_bytes.extend_from_slice(c.take(msg_size as usize)?);
        }
        let nsections = c.u64()? as usize;
        let mut sections = Vec::with_capacity(nsections);
        for _ in 0..nsections {
            let name_len = c.u8()? as usize;
            let name = std::str::from_utf8(c.take(name_len)?)
                .context("checkpoint section name is not UTF-8")?
                .to_string();
            let kind = c.u8()?;
            let len = c.u64()?;
            let width: u64 = match kind {
                KIND_U32 => 4,
                KIND_F64 | KIND_U64 | KIND_I64 => 8,
                other => bail!("unknown section kind {other}"),
            };
            let raw = c.take((len * width) as usize)?.to_vec();
            sections.push((name, kind, len, raw));
        }
        ensure!(c.pos == body.len(), "trailing bytes in checkpoint");
        Ok(CheckpointImage {
            round,
            n,
            frontier_words,
            pending,
            msg_size,
            msg_dsts,
            msg_bytes,
            sections,
        })
    }

    fn section(&self, name: &str, kind: u8) -> crate::Result<(&[u8], u64)> {
        let Some((_, k, len, raw)) = self.sections.iter().find(|(n, ..)| n == name) else {
            bail!("checkpoint has no section '{name}'");
        };
        ensure!(
            *k == kind,
            "section '{name}' is {} (expected {})",
            kind_name(*k),
            kind_name(kind)
        );
        Ok((raw, *len))
    }

    /// Restore an `f64` section into `v` (lengths must match).
    pub fn restore_f64(&self, name: &str, v: &SharedVec<f64>) -> crate::Result<()> {
        let (raw, len) = self.section(name, KIND_F64)?;
        ensure!(len as usize == v.len(), "section '{name}' len {len} != state len {}", v.len());
        for i in 0..v.len() {
            v.set(i, f64::from_le_bytes(raw[i * 8..i * 8 + 8].try_into().unwrap()));
        }
        Ok(())
    }

    /// Restore a `u32` section into `v` (lengths must match).
    pub fn restore_u32(&self, name: &str, v: &SharedVec<u32>) -> crate::Result<()> {
        let (raw, len) = self.section(name, KIND_U32)?;
        ensure!(len as usize == v.len(), "section '{name}' len {len} != state len {}", v.len());
        for i in 0..v.len() {
            v.set(i, u32::from_le_bytes(raw[i * 4..i * 4 + 4].try_into().unwrap()));
        }
        Ok(())
    }

    /// Restore a `u64` section into `v` (lengths must match).
    pub fn restore_u64(&self, name: &str, v: &SharedVec<u64>) -> crate::Result<()> {
        let (raw, len) = self.section(name, KIND_U64)?;
        ensure!(len as usize == v.len(), "section '{name}' len {len} != state len {}", v.len());
        for i in 0..v.len() {
            v.set(i, u64::from_le_bytes(raw[i * 8..i * 8 + 8].try_into().unwrap()));
        }
        Ok(())
    }

    /// Restore an `i64` section into `v` (lengths must match).
    pub fn restore_i64(&self, name: &str, v: &SharedVec<i64>) -> crate::Result<()> {
        let (raw, len) = self.section(name, KIND_I64)?;
        ensure!(len as usize == v.len(), "section '{name}' len {len} != state len {}", v.len());
        for i in 0..v.len() {
            v.set(i, i64::from_le_bytes(raw[i * 8..i * 8 + 8].try_into().unwrap()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("graphyti-ckpt-{}-{tag}.ckpt", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip() {
        let path = tmp("rt");
        let n = 130usize;
        let frontier = AtomicBitmap::new(n);
        for v in [0usize, 5, 63, 64, 129] {
            frontier.set(v);
        }
        let ranks = SharedVec::new(n, 0.0f64);
        for i in 0..n {
            ranks.set(i, i as f64 * 0.5);
        }
        let labels = SharedVec::new(n, 0u32);
        for i in 0..n {
            labels.set(i, (i % 7) as u32);
        }
        let mut w = CheckpointWriter::new();
        w.put_f64("rank", &ranks);
        w.put_u32("label", &labels);
        let msgs: Vec<(u32, f64)> = vec![(3, 1.25), (64, -2.0)];
        let mut dsts = Vec::new();
        let mut raw = Vec::new();
        for (d, m) in &msgs {
            dsts.push(*d);
            raw.extend_from_slice(&m.to_le_bytes());
        }
        let hdr = CheckpointHeader {
            round: 9,
            n: n as u64,
            frontier: &frontier,
            pending: 2,
            msg_size: 8,
            msg_dsts: &dsts,
            msg_bytes: &raw,
        };
        let bytes = save(&path, &hdr, &w).unwrap();
        assert!(bytes > 0);
        assert!(
            !path.with_extension("ckpt-tmp").exists(),
            "tmp file must be renamed away"
        );

        let img = CheckpointImage::load(&path).unwrap();
        assert_eq!(img.round, 9);
        assert_eq!(img.n, n as u64);
        assert_eq!(img.pending, 2);
        assert_eq!(img.msg_dsts, dsts);
        assert_eq!(img.msg_size, 8);
        let back = AtomicBitmap::new(n);
        for (wi, word) in img.frontier_words.iter().enumerate() {
            let mut w = *word;
            while w != 0 {
                let b = w.trailing_zeros() as usize;
                w &= w - 1;
                back.set(wi * 64 + b);
            }
        }
        assert_eq!(
            back.iter_set().collect::<Vec<_>>(),
            vec![0usize, 5, 63, 64, 129]
        );
        let r2 = SharedVec::new(n, 0.0f64);
        img.restore_f64("rank", &r2).unwrap();
        assert_eq!(r2.to_vec(), ranks.to_vec());
        let l2 = SharedVec::new(n, 0u32);
        img.restore_u32("label", &l2).unwrap();
        assert_eq!(l2.to_vec(), labels.to_vec());
        // typed accessors reject wrong kind / missing sections
        assert!(img.restore_u32("rank", &l2).is_err());
        assert!(img.restore_f64("nope", &r2).is_err());
        let short = SharedVec::new(n - 1, 0.0f64);
        assert!(img.restore_f64("rank", &short).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_or_corrupt_checkpoints_fail_cleanly() {
        let path = tmp("torn");
        let n = 64usize;
        let frontier = AtomicBitmap::new(n);
        frontier.set(1);
        let state = SharedVec::new(n, 7.0f64);
        let mut w = CheckpointWriter::new();
        w.put_f64("s", &state);
        let hdr = CheckpointHeader {
            round: 3,
            n: n as u64,
            frontier: &frontier,
            pending: 0,
            msg_size: 0,
            msg_dsts: &[],
            msg_bytes: &[],
        };
        save(&path, &hdr, &w).unwrap();
        let good = std::fs::read(&path).unwrap();

        // truncation (torn write) is rejected by the checksum
        std::fs::write(&path, &good[..good.len() - 5]).unwrap();
        assert!(CheckpointImage::load(&path).is_err());
        // a single flipped byte is rejected
        let mut bad = good.clone();
        bad[20] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(CheckpointImage::load(&path).is_err());
        // garbage is rejected
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(CheckpointImage::load(&path).is_err());
        // the pristine bytes still load
        std::fs::write(&path, &good).unwrap();
        assert!(CheckpointImage::load(&path).is_ok());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn checksum_is_stable() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
