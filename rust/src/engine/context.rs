//! Per-worker execution context ([`WorkerCtx`]) and the barrier-time
//! context ([`EndCtx`]) passed to `run_on_iteration_end`.

use std::sync::Arc;

use crate::engine::messages::{Delivery, MessagePlane, Transport};
use crate::graph::format::{EdgeRequest, GraphIndex, VertexEdges};
use crate::graph::source::EdgeSource;
use crate::util::AtomicBitmap;
use crate::VertexId;

/// Number of functional-reduction slots ("utilize functional constructs",
/// §4.4): per-worker accumulators merged contention-free at the barrier.
pub const N_RED_SLOTS: usize = 8;

/// Context handed to `run_on_vertex` / `run_on_message`.
///
/// One per worker thread; lives for the whole run. Sends go straight
/// into this worker's own message lanes (combiner slab or SPSC queue —
/// no locks either way), while statistics and the pending-delivery
/// count are accumulated locally and published at phase boundaries.
pub struct WorkerCtx<'a, M> {
    pub(crate) worker: usize,
    pub(crate) num_workers: usize,
    pub(crate) num_vertices: usize,
    pub(crate) round: usize,
    pub(crate) in_message_phase: bool,
    pub(crate) source: &'a dyn EdgeSource,
    pub(crate) index: &'a GraphIndex,
    pub(crate) bitmaps: &'a [AtomicBitmap; 2],
    pub(crate) plane: &'a MessagePlane<M>,
    // local counters, merged into EngineStats at round end
    pub(crate) c_p2p: u64,
    pub(crate) c_multicast: u64,
    pub(crate) c_deliveries: u64,
    pub(crate) c_vertex_runs: u64,
    /// Frontier chunks this worker claimed from another worker's span.
    pub(crate) c_steals: u64,
    /// Sends folded into an already-touched combiner slot this round.
    pub(crate) c_combined: u64,
    /// Fresh pending deliveries staged this phase (batched into the
    /// plane's atomic pending counter at phase ends).
    pub(crate) c_pending: usize,
    // local reductions, merged at round end
    pub(crate) red_add: [f64; N_RED_SLOTS],
    pub(crate) red_max: [f64; N_RED_SLOTS],
}

impl<'a, M: Send + Sync + Clone + 'static> WorkerCtx<'a, M> {
    /// Owner worker of a vertex (range partitioning).
    #[inline]
    pub(crate) fn owner(&self, v: VertexId) -> usize {
        (v as u64 * self.num_workers as u64 / self.num_vertices as u64) as usize
    }

    #[inline]
    fn send_parity(&self) -> usize {
        (self.round + 1) % 2
    }

    /// This worker's id.
    pub fn worker(&self) -> usize {
        self.worker
    }

    /// Current round (BSP superstep) index.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Total vertices in the graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Out-degree from the in-memory index (no I/O).
    #[inline]
    pub fn out_deg(&self, v: VertexId) -> u32 {
        self.index.out_deg(v)
    }

    /// In-degree from the in-memory index (no I/O).
    #[inline]
    pub fn in_deg(&self, v: VertexId) -> u32 {
        self.index.in_deg(v)
    }

    /// Total degree from the in-memory index (no I/O).
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        self.index.degree(v)
    }

    /// Activate `v`: during the message phase, into *this* round's vertex
    /// phase; during the vertex phase, into the next round.
    #[inline]
    pub fn activate(&mut self, v: VertexId) {
        let p = if self.in_message_phase { self.round % 2 } else { (self.round + 1) % 2 };
        self.bitmaps[p].set(v as usize);
    }

    /// Point-to-point message to `dst` (delivered next round).
    ///
    /// On the combiner transport this folds into the dense lane in
    /// place (no allocation, no lock); on the queue transport it
    /// appends to this worker's private SPSC lane toward `dst`'s owner.
    #[inline]
    pub fn send(&mut self, dst: VertexId, msg: M) {
        self.c_p2p += 1;
        let p = self.send_parity();
        match &self.plane.transport {
            Transport::Combine(lanes) => {
                if lanes.send(p, self.worker, dst, &msg) {
                    self.c_pending += 1;
                } else {
                    self.c_combined += 1;
                }
            }
            Transport::Queue(q) => {
                q.push(p, self.worker, self.owner(dst), Delivery::P2p(dst, msg));
                self.c_pending += 1;
            }
        }
    }

    /// Multicast `msg` to all of `dsts` (delivered next round). On the
    /// queue transport this is one entry per destination worker (a
    /// shared payload slice — far cheaper per destination than repeated
    /// [`WorkerCtx::send`], §4.2); on the combiner transport each
    /// destination folds into its dense slot, which subsumes the same
    /// economy without the shared-slice allocation.
    pub fn multicast(&mut self, dsts: &[VertexId], msg: M) {
        if dsts.is_empty() {
            return;
        }
        self.c_multicast += 1;
        let parity = self.send_parity();
        match &self.plane.transport {
            Transport::Combine(lanes) => {
                for &d in dsts {
                    if lanes.send(parity, self.worker, d, &msg) {
                        self.c_pending += 1;
                    } else {
                        self.c_combined += 1;
                    }
                }
            }
            Transport::Queue(q) => {
                // group consecutive same-owner runs (dst lists are sorted)
                let mut i = 0;
                while i < dsts.len() {
                    let w = self.owner(dsts[i]);
                    let mut j = i + 1;
                    while j < dsts.len() && self.owner(dsts[j]) == w {
                        j += 1;
                    }
                    let slice: Arc<[VertexId]> = Arc::from(&dsts[i..j]);
                    q.push(parity, self.worker, w, Delivery::Multi(slice, msg.clone()));
                    self.c_pending += 1;
                    i = j;
                }
            }
        }
    }

    /// Publish this phase's staged send count to the plane's pending
    /// counter (one relaxed `fetch_add`; called by the runner at the
    /// end of each phase).
    #[inline]
    pub(crate) fn flush_sends(&mut self) {
        if self.c_pending > 0 {
            self.plane.add_pending(self.send_parity(), self.c_pending);
            self.c_pending = 0;
        }
    }

    /// Fetch another vertex's edge lists on demand (triangle counting's
    /// neighbor-list requests, §4.5). Goes through the page cache and is
    /// counted as I/O.
    pub fn fetch_edges(&self, v: VertexId, req: EdgeRequest) -> VertexEdges {
        self.source.fetch(v, req).expect("edge fetch failed (graph image unreadable)")
    }

    /// Prefetch hint for upcoming `fetch_edges` calls.
    pub fn prefetch_edges(&self, reqs: &[(VertexId, EdgeRequest)]) {
        self.source.prefetch(reqs);
    }

    /// Functional reduction: add `val` into slot `slot` (merged across
    /// workers contention-free at the barrier).
    #[inline]
    pub fn reduce_add(&mut self, slot: usize, val: f64) {
        self.red_add[slot] += val;
    }

    /// Functional reduction: max of `val` into slot `slot`.
    #[inline]
    pub fn reduce_max(&mut self, slot: usize, val: f64) {
        if val > self.red_max[slot] {
            self.red_max[slot] = val;
        }
    }
}

/// Barrier-time context: passed to `run_on_iteration_end`, which runs
/// single-threaded after all workers finished the round.
pub struct EndCtx<'a> {
    pub(crate) round: usize,
    pub(crate) num_vertices: usize,
    pub(crate) next_active: usize,
    pub(crate) pending_msgs: usize,
    pub(crate) next_bitmap: &'a AtomicBitmap,
    pub(crate) red_add: [f64; N_RED_SLOTS],
    pub(crate) red_max: [f64; N_RED_SLOTS],
    pub(crate) stop_requested: bool,
    pub(crate) continue_requested: bool,
}

impl EndCtx<'_> {
    /// The round that just finished.
    pub fn round(&self) -> usize {
        self.round
    }

    /// Vertices in the graph.
    pub fn num_vertices(&self) -> usize {
        self.num_vertices
    }

    /// Vertices currently activated for the next round.
    pub fn next_active(&self) -> usize {
        self.next_active
    }

    /// Messages queued for delivery next round.
    pub fn pending_msgs(&self) -> usize {
        self.pending_msgs
    }

    /// True if the engine would stop after this round (no activations, no
    /// messages) unless this hook activates something.
    pub fn quiescent(&self) -> bool {
        self.next_active == 0 && self.pending_msgs == 0
    }

    /// Activate `v` for the next round.
    pub fn activate(&self, v: VertexId) {
        self.next_bitmap.set(v as usize);
    }

    /// Merged add-reduction value for `slot` this round.
    pub fn reduction_add(&self, slot: usize) -> f64 {
        self.red_add[slot]
    }

    /// Merged max-reduction value for `slot` this round
    /// (`f64::NEG_INFINITY` when nothing was reduced).
    pub fn reduction_max(&self, slot: usize) -> f64 {
        self.red_max[slot]
    }

    /// Request the engine to stop after this round regardless of pending
    /// work.
    pub fn stop(&mut self) {
        self.stop_requested = true;
    }

    /// Run one more round even if no vertices are active and no messages
    /// pending — for multi-phase algorithms whose `run_on_iteration_end`
    /// drives phase transitions (e.g. coreness paying a real barrier for
    /// each empty k level in the unoptimized variant).
    pub fn force_continue(&mut self) {
        self.continue_requested = true;
    }
}
