//! The [`VertexProgram`] trait — the developer-facing API, mirroring
//! FlashGraph's programming interface (paper Fig. 1a).

use crate::engine::context::{EndCtx, WorkerCtx};
use crate::engine::messages::Combiner;
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::VertexId;

/// A vertex-centric program.
///
/// Implementations hold their own O(n) state (typically
/// [`crate::util::SharedVec`] arrays indexed by vertex id) — the engine
/// guarantees that for a given vertex, `run_on_vertex` and
/// `run_on_message` never run concurrently with each other or themselves,
/// so per-own-slot mutation through `SharedVec` is race-free. Reads of
/// *other* vertices' slots must follow a double-buffering or
/// stable-in-phase discipline (see `algs::pagerank` pull vs push).
pub trait VertexProgram: Send + Sync {
    /// Message type exchanged between vertices.
    type Msg: Send + Sync + Clone + 'static;

    /// Which edge lists the engine must fetch before `run_on_vertex` —
    /// the central I/O-minimization lever ("limit superfluous reads"):
    /// requesting `None` or a single direction instead of `Both` directly
    /// reduces bytes read from disk.
    ///
    /// Contract: the answer may depend only on state that is stable for
    /// the whole vertex phase of a round (the engine evaluates it one
    /// prefetch batch ahead of processing).
    fn edge_request(&self, v: VertexId) -> EdgeRequest;

    /// Optional commutative-associative message fold (the paper's
    /// "minimize message memory" principle taken to its limit).
    ///
    /// Return `Some` when messages to the same destination can be
    /// combined without loss — rank mass (`+`), min-label/min-distance
    /// (`min`), lane bitsets (`|`), decrement counts (`+`). The engine
    /// then routes sends through dense O(n) combiner lanes: no
    /// per-message allocation, no locks, and each destination receives
    /// **one** folded `run_on_message` per round instead of one per
    /// send. Programs whose messages carry non-foldable structure (BC's
    /// per-lane path counts, Louvain's pings) keep the default `None`
    /// and ride the recycled SPSC queue lanes.
    ///
    /// Contract: see [`Combiner`] — `combine` commutative + associative,
    /// `identity` neutral, and `run_on_message` must treat a folded
    /// message exactly like the equivalent message sequence.
    fn combiner(&self) -> Option<Combiner<Self::Msg>> {
        None
    }

    /// Process an activated vertex; `edges` holds the requested lists.
    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, Self::Msg>, v: VertexId, edges: &VertexEdges);

    /// Handle one message delivered to `v`. May activate `v` (or others)
    /// into the current round's vertex phase and send further messages
    /// (delivered next round).
    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, Self::Msg>, v: VertexId, msg: &Self::Msg);

    /// Runs once per round at the global barrier (single-threaded).
    /// Default: no-op.
    fn run_on_iteration_end(&self, _ctx: &mut EndCtx<'_>) {}
}
