//! The [`VertexProgram`] trait — the developer-facing API, mirroring
//! FlashGraph's programming interface (paper Fig. 1a).

use crate::engine::checkpoint::{CheckpointImage, CheckpointWriter};
use crate::engine::context::{EndCtx, WorkerCtx};
use crate::engine::messages::Combiner;
use crate::graph::format::{EdgeRequest, VertexEdges};
use crate::VertexId;

/// A vertex-centric program.
///
/// Implementations hold their own O(n) state (typically
/// [`crate::util::SharedVec`] arrays indexed by vertex id) — the engine
/// guarantees that for a given vertex, `run_on_vertex` and
/// `run_on_message` never run concurrently with each other or themselves,
/// so per-own-slot mutation through `SharedVec` is race-free. Reads of
/// *other* vertices' slots must follow a double-buffering or
/// stable-in-phase discipline (see `algs::pagerank` pull vs push).
pub trait VertexProgram: Send + Sync {
    /// Message type exchanged between vertices.
    type Msg: Send + Sync + Clone + 'static;

    /// Which edge lists the engine must fetch before `run_on_vertex` —
    /// the central I/O-minimization lever ("limit superfluous reads"):
    /// requesting `None` or a single direction instead of `Both` directly
    /// reduces bytes read from disk.
    ///
    /// Contract: the answer may depend only on state that is stable for
    /// the whole vertex phase of a round (the engine evaluates it one
    /// prefetch batch ahead of processing).
    fn edge_request(&self, v: VertexId) -> EdgeRequest;

    /// Optional commutative-associative message fold (the paper's
    /// "minimize message memory" principle taken to its limit).
    ///
    /// Return `Some` when messages to the same destination can be
    /// combined without loss — rank mass (`+`), min-label/min-distance
    /// (`min`), lane bitsets (`|`), decrement counts (`+`). The engine
    /// then routes sends through dense O(n) combiner lanes: no
    /// per-message allocation, no locks, and each destination receives
    /// **one** folded `run_on_message` per round instead of one per
    /// send. Programs whose messages carry non-foldable structure (BC's
    /// per-lane path counts, Louvain's pings) keep the default `None`
    /// and ride the recycled SPSC queue lanes.
    ///
    /// Contract: see [`Combiner`] — `combine` commutative + associative,
    /// `identity` neutral, and `run_on_message` must treat a folded
    /// message exactly like the equivalent message sequence.
    fn combiner(&self) -> Option<Combiner<Self::Msg>> {
        None
    }

    /// Process an activated vertex; `edges` holds the requested lists.
    fn run_on_vertex(&self, ctx: &mut WorkerCtx<'_, Self::Msg>, v: VertexId, edges: &VertexEdges);

    /// Handle one message delivered to `v`. May activate `v` (or others)
    /// into the current round's vertex phase and send further messages
    /// (delivered next round).
    fn run_on_message(&self, ctx: &mut WorkerCtx<'_, Self::Msg>, v: VertexId, msg: &Self::Msg);

    /// Runs once per round at the global barrier (single-threaded).
    /// Default: no-op.
    fn run_on_iteration_end(&self, _ctx: &mut EndCtx<'_>) {}

    /// Opt into pull-mode rounds (GraphMP-style dense iteration): on a
    /// dense frontier the engine iterates *destination* vertices and,
    /// for each neighboring source that is active, synthesizes the
    /// message via [`Self::pull_message`] instead of having the source
    /// push it. Default `false`: the engine never runs this program in
    /// pull mode (`mode=pull` degrades to push), which is correct for
    /// programs whose `run_on_vertex` side effects are not captured by
    /// a per-edge message function (stateful multicast masks, weighted
    /// phase logic, etc.).
    fn supports_pull(&self) -> bool {
        false
    }

    /// Which edge direction pull rounds traverse *from the
    /// destination's perspective* (program-wide, unlike the per-vertex
    /// [`Self::edge_request`]): `In` means "my in-neighbors push to me
    /// along out-edges" — the common case — while `Both` covers
    /// programs that multicast along both directions (WCC on a
    /// symmetrized view).
    fn pull_request(&self) -> EdgeRequest {
        EdgeRequest::In
    }

    /// Synthesize the message an *active* `src` would have pushed to
    /// `dst` in this round, or `None` for no message. Contract: for any
    /// frontier, delivering `pull_message(src, dst)` for every active
    /// `src` adjacent to `dst` must be observationally identical (up to
    /// combiner fold order) to the sends `run_on_vertex(src)` performs
    /// — the push/pull equivalence tests enforce this per algorithm.
    /// Only consulted when [`Self::supports_pull`] is true; reads of
    /// `src`'s state follow the same stable-in-phase discipline as
    /// `run_on_vertex`, which on pull rounds runs for active vertices
    /// *before* any pulls are evaluated (so it may stash per-vertex
    /// values — e.g. PageRank's share — that `pull_message` then reads).
    fn pull_message(&self, _src: VertexId, _dst: VertexId) -> Option<Self::Msg> {
        None
    }

    /// Opt into round-boundary checkpointing
    /// ([`crate::engine::EngineConfig::checkpoint_every`]). Default
    /// `false`: the engine silently skips snapshots for programs that
    /// have not declared their O(n) state through
    /// [`Self::checkpoint_save`] / [`Self::checkpoint_restore`].
    /// Checkpointing additionally requires the combiner transport
    /// (pending queue-lane entries are not foldable into a snapshot)
    /// and a `Copy`-like message type.
    fn checkpointable(&self) -> bool {
        false
    }

    /// Write every O(n) state array this program owns as named typed
    /// sections. Called single-threaded at the round barrier the
    /// snapshot is cut at. Default: no sections.
    fn checkpoint_save(&self, _w: &mut CheckpointWriter) {}

    /// Restore state saved by [`Self::checkpoint_save`]. Called
    /// single-threaded before any worker starts. Default: `Ok` (no
    /// sections to restore).
    fn checkpoint_restore(&self, _img: &CheckpointImage) -> crate::Result<()> {
        Ok(())
    }
}
