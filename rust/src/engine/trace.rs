//! Per-round engine traces — the time series behind the paper's
//! figures.
//!
//! A [`RoundTrace`] is a bounded ring buffer of [`RoundSample`]s,
//! recorded by worker 0 during round bookkeeping (between the
//! phase-B barrier and the final barrier, when every worker's counters
//! for the round have been merged and no new I/O is being issued).
//! Recording is **allocation-free once warm**: every sample slot and
//! its per-worker phase vector are preallocated at construction, and
//! `record` only copies plain values into them. Tracing is off by
//! default (`EngineConfig.trace`); an untraced run pays nothing.
//!
//! ## I/O attribution and the telescoping invariant
//!
//! Each sample's `io` field is the delta between consecutive round-
//! boundary snapshots of the run's [`crate::safs::IoStats`], so the
//! per-round deltas *telescope*: summed, they equal the run-level
//! snapshot delta exactly. Asynchronous prefetch I/O completing after
//! the last boundary would break that, so [`RoundTrace::finish`]
//! (called once after the workers join) recomputes the final sample's
//! delta against the post-join snapshot. Mid-run prefetch completions
//! are attributed to the round whose boundary observes them — off by
//! at most one round, never lost. The invariant holds whenever the
//! ring did not overflow (`dropped() == 0`); overflow keeps the most
//! recent [`TRACE_CAP`] rounds and gives up the exact-sum property.

use crate::safs::IoStatsSnapshot;
use crate::util::Json;

/// Ring capacity in rounds. Most algorithms converge in far fewer;
/// diameter-style multi-phase runs that exceed it keep the tail.
pub const TRACE_CAP: usize = 1024;

/// One worker's phase timings for one round, nanoseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerPhases {
    /// Phase A (message delivery) wall time.
    pub phase_a_ns: u64,
    /// Phase B (vertex phase) wall time.
    pub phase_b_ns: u64,
    /// Wait at the barrier between the phases.
    pub barrier_ns: u64,
    /// Time inside phase B spent *blocked* on edge I/O completions.
    /// `phase_b_ns - io_wait_ns` is compute that genuinely overlapped
    /// in-flight I/O — the quantity the overlap regression test pins.
    pub io_wait_ns: u64,
}

/// Everything one round did.
#[derive(Debug, Clone, Default)]
pub struct RoundSample {
    /// Round number (0-based).
    pub round: u64,
    /// Active vertices entering this round.
    pub frontier: u64,
    /// Vertices activated for the next round (post-hook recount).
    pub activations: u64,
    /// Send operations this round (p2p + multicast).
    pub sent: u64,
    /// `run_on_message` deliveries this round.
    pub delivered: u64,
    /// Sends absorbed by combiner folds this round.
    pub combined: u64,
    /// `run_on_vertex` invocations this round.
    pub vertex_runs: u64,
    /// Productive foreign chunk claims this round.
    pub steals: u64,
    /// True when the round's vertex phase ran in pull mode (dense-round
    /// in-edge iteration) instead of frontier-driven push.
    pub pull: bool,
    /// Edge blocks whose I/O was skipped by the per-block source-summary
    /// filter this round (pull rounds only; 0 on push rounds).
    pub blocks_skipped: u64,
    /// Per-worker phase timings (length = worker count).
    pub workers: Vec<WorkerPhases>,
    /// I/O attributed to this round (boundary-snapshot delta; the
    /// `latency` field carries cumulative summaries, see module docs).
    pub io: IoStatsSnapshot,
}

/// Cumulative engine counters at a round boundary — the recorder
/// differences consecutive boundaries to get per-round values.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineCum {
    pub sent: u64,
    pub delivered: u64,
    pub combined: u64,
    pub vertex_runs: u64,
    pub steals: u64,
    pub blocks_skipped: u64,
}

/// Bounded per-round trace recorder. See the module docs for the
/// recording protocol and the telescoping invariant.
#[derive(Debug, Clone)]
pub struct RoundTrace {
    /// Preallocated ring slots (`TRACE_CAP` samples, each with a
    /// worker-count phase vector).
    slots: Vec<RoundSample>,
    /// Total samples ever recorded (ring index = total % capacity).
    total: u64,
    /// Frontier size for the *next* round to be recorded.
    next_frontier: u64,
    /// Engine counters at the last recorded boundary.
    last_eng: EngineCum,
    /// I/O snapshot at the last recorded boundary.
    last_io: IoStatsSnapshot,
    /// I/O snapshot at the boundary *before* the last one — what
    /// `finish` re-differences the final sample against.
    prev_io: IoStatsSnapshot,
}

impl RoundTrace {
    /// Preallocate a trace for `workers` workers. `io_before` is the
    /// run's starting I/O snapshot (the base of the first delta).
    pub fn new(workers: usize, io_before: IoStatsSnapshot) -> Self {
        RoundTrace {
            slots: (0..TRACE_CAP)
                .map(|_| RoundSample {
                    workers: vec![WorkerPhases::default(); workers],
                    ..Default::default()
                })
                .collect(),
            total: 0,
            next_frontier: 0,
            last_eng: EngineCum::default(),
            last_io: io_before,
            prev_io: io_before,
        }
    }

    /// Set the frontier size of round 0 (the initial activation count).
    pub fn set_initial_frontier(&mut self, frontier: u64) {
        self.next_frontier = frontier;
    }

    /// Record one round. `eng` and `io_now` are *cumulative* at this
    /// boundary; `activations` is the post-hook recount of the next
    /// round's frontier; `pull` flags a pull-mode vertex phase;
    /// `phases` yields one timing quad
    /// `(phase_a_ns, phase_b_ns, barrier_ns, io_wait_ns)` per worker.
    /// Allocates nothing: the slot and its phase vector are
    /// preallocated.
    pub fn record(
        &mut self,
        round: u64,
        activations: u64,
        pull: bool,
        eng: EngineCum,
        io_now: IoStatsSnapshot,
        phases: impl Iterator<Item = (u64, u64, u64, u64)>,
    ) {
        let cap = self.slots.len();
        let slot = &mut self.slots[(self.total % cap as u64) as usize];
        slot.round = round;
        slot.frontier = self.next_frontier;
        slot.activations = activations;
        slot.sent = eng.sent.saturating_sub(self.last_eng.sent);
        slot.delivered = eng.delivered.saturating_sub(self.last_eng.delivered);
        slot.combined = eng.combined.saturating_sub(self.last_eng.combined);
        slot.vertex_runs = eng.vertex_runs.saturating_sub(self.last_eng.vertex_runs);
        slot.steals = eng.steals.saturating_sub(self.last_eng.steals);
        slot.pull = pull;
        slot.blocks_skipped =
            eng.blocks_skipped.saturating_sub(self.last_eng.blocks_skipped);
        slot.io = io_now.delta(&self.last_io);
        slot.workers.clear();
        for (a, b, bar, wait) in phases {
            slot.workers.push(WorkerPhases {
                phase_a_ns: a,
                phase_b_ns: b,
                barrier_ns: bar,
                io_wait_ns: wait,
            });
        }
        self.total += 1;
        self.next_frontier = activations;
        self.last_eng = eng;
        self.prev_io = self.last_io;
        self.last_io = io_now;
    }

    /// Close the trace against the run's final (post-join) snapshot:
    /// I/O that completed between the last round boundary and the join
    /// — asynchronous prefetch inserts, mostly — is folded into the
    /// final sample so the per-round deltas sum exactly to the
    /// run-level delta.
    pub fn finish(&mut self, io_final: IoStatsSnapshot) {
        if self.total == 0 {
            return;
        }
        let cap = self.slots.len() as u64;
        let last = &mut self.slots[((self.total - 1) % cap) as usize];
        last.io = io_final.delta(&self.prev_io);
        self.last_io = io_final;
    }

    /// Recorded rounds currently held (≤ capacity).
    pub fn len(&self) -> usize {
        (self.total).min(self.slots.len() as u64) as usize
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Total rounds ever recorded (including dropped ones).
    pub fn rounds_recorded(&self) -> u64 {
        self.total
    }

    /// Rounds evicted by ring overflow (0 = the exact-sum invariant
    /// holds).
    pub fn dropped(&self) -> u64 {
        self.total.saturating_sub(self.slots.len() as u64)
    }

    /// Samples oldest-first.
    pub fn samples(&self) -> impl Iterator<Item = &RoundSample> {
        let cap = self.slots.len() as u64;
        let first = self.total.saturating_sub(cap);
        (first..self.total).map(move |i| &self.slots[(i % cap) as usize])
    }

    /// Sum of the per-round I/O deltas — equals the run-level delta
    /// when `dropped() == 0` (the tested invariant).
    pub fn io_sum(&self) -> IoStatsSnapshot {
        let mut out = IoStatsSnapshot::default();
        for s in self.samples() {
            out.read_requests += s.io.read_requests;
            out.cache_hits += s.io.cache_hits;
            out.cache_misses += s.io.cache_misses;
            out.physical_reads += s.io.physical_reads;
            out.bytes_read += s.io.bytes_read;
            out.merged_requests += s.io.merged_requests;
            out.logical_bytes += s.io.logical_bytes;
            out.thread_waits += s.io.thread_waits;
            out.evictions += s.io.evictions;
            out.retries += s.io.retries;
        }
        out.latency = self.last_io.latency;
        out
    }

    /// Full trace as JSON (one object per round).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("rounds", Json::u(self.rounds_recorded())),
            ("dropped", Json::u(self.dropped())),
            (
                "samples",
                Json::Arr(self.samples().map(sample_to_json).collect()),
            ),
        ])
    }

    /// Compact summary for bench baselines: round count plus frontier
    /// and I/O aggregates.
    pub fn summary_json(&self) -> Json {
        let peak_frontier = self.samples().map(|s| s.frontier).max().unwrap_or(0);
        let io = self.io_sum();
        Json::obj(vec![
            ("rounds", Json::u(self.rounds_recorded())),
            ("dropped", Json::u(self.dropped())),
            ("peak_frontier", Json::u(peak_frontier)),
            ("bytes_read", Json::u(io.bytes_read)),
            ("physical_reads", Json::u(io.physical_reads)),
        ])
    }
}

fn sample_to_json(s: &RoundSample) -> Json {
    Json::obj(vec![
        ("round", Json::u(s.round)),
        ("frontier", Json::u(s.frontier)),
        ("activations", Json::u(s.activations)),
        ("sent", Json::u(s.sent)),
        ("delivered", Json::u(s.delivered)),
        ("combined", Json::u(s.combined)),
        ("vertex_runs", Json::u(s.vertex_runs)),
        ("steals", Json::u(s.steals)),
        ("pull", Json::u(s.pull as u64)),
        ("blocks_skipped", Json::u(s.blocks_skipped)),
        (
            "workers",
            Json::Arr(
                s.workers
                    .iter()
                    .map(|w| {
                        Json::Arr(vec![
                            Json::u(w.phase_a_ns),
                            Json::u(w.phase_b_ns),
                            Json::u(w.barrier_ns),
                            Json::u(w.io_wait_ns),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "io",
            Json::obj(vec![
                ("bytes_read", Json::u(s.io.bytes_read)),
                ("physical_reads", Json::u(s.io.physical_reads)),
                ("read_requests", Json::u(s.io.read_requests)),
                ("cache_hits", Json::u(s.io.cache_hits)),
                ("cache_misses", Json::u(s.io.cache_misses)),
                ("hit_ratio", Json::f(s.io.hit_ratio())),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::safs::IoStats;

    fn io_snap(bytes: u64, preads: u64) -> IoStatsSnapshot {
        let s = IoStats::new();
        s.add_bytes_read(bytes);
        s.add_physical_read(preads);
        s.snapshot()
    }

    #[test]
    fn deltas_telescope_to_the_final_snapshot() {
        let base = io_snap(100, 1);
        let mut t = RoundTrace::new(2, base);
        t.set_initial_frontier(10);
        t.record(
            0,
            4,
            false,
            EngineCum { sent: 5, delivered: 5, ..Default::default() },
            io_snap(300, 3),
            [(1, 2, 3, 1), (4, 5, 6, 2)].into_iter(),
        );
        t.record(
            1,
            0,
            true,
            EngineCum { sent: 9, delivered: 9, blocks_skipped: 3, ..Default::default() },
            io_snap(450, 5),
            [(1, 2, 3, 1), (4, 5, 6, 2)].into_iter(),
        );
        // async I/O lands after the last boundary; finish folds it in
        let fin = io_snap(500, 6);
        t.finish(fin);
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 0);
        let sum = t.io_sum();
        let run = fin.delta(&base);
        assert_eq!(sum.bytes_read, run.bytes_read);
        assert_eq!(sum.physical_reads, run.physical_reads);
        // per-round values
        let rounds: Vec<_> = t.samples().collect();
        assert_eq!(rounds[0].frontier, 10);
        assert_eq!(rounds[0].activations, 4);
        assert_eq!(rounds[1].frontier, 4);
        assert_eq!(rounds[0].sent, 5);
        assert_eq!(rounds[1].sent, 4);
        assert_eq!(rounds[0].io.bytes_read, 200);
        assert_eq!(rounds[1].io.bytes_read, 200, "finish extends the last round");
        assert_eq!(rounds[0].workers.len(), 2);
        assert_eq!(rounds[0].workers[1].phase_b_ns, 5);
        assert_eq!(rounds[0].workers[1].io_wait_ns, 2);
        assert!(!rounds[0].pull);
        assert!(rounds[1].pull);
        assert_eq!(rounds[0].blocks_skipped, 0);
        assert_eq!(rounds[1].blocks_skipped, 3, "cumulative counter differenced");
    }

    #[test]
    fn ring_overflow_keeps_the_tail_and_counts_drops() {
        let mut t = RoundTrace::new(1, IoStatsSnapshot::default());
        let rounds = TRACE_CAP as u64 + 10;
        for r in 0..rounds {
            t.record(
                r,
                1,
                false,
                EngineCum { sent: r + 1, ..Default::default() },
                IoStatsSnapshot::default(),
                std::iter::once((0, 0, 0, 0)),
            );
        }
        assert_eq!(t.len(), TRACE_CAP);
        assert_eq!(t.dropped(), 10);
        assert_eq!(t.rounds_recorded(), rounds);
        let first = t.samples().next().unwrap();
        assert_eq!(first.round, 10, "oldest surviving sample");
        let last = t.samples().last().unwrap();
        assert_eq!(last.round, rounds - 1);
    }

    #[test]
    fn json_export_shape() {
        let mut t = RoundTrace::new(1, IoStatsSnapshot::default());
        t.set_initial_frontier(3);
        t.record(
            0,
            0,
            true,
            EngineCum { blocks_skipped: 2, ..Default::default() },
            io_snap(64, 1),
            std::iter::once((10, 20, 30, 5)),
        );
        let j = t.to_json();
        assert_eq!(j.get("rounds").unwrap().as_u64(), Some(1));
        let s0 = &j.get("samples").unwrap().as_array().unwrap()[0];
        assert_eq!(s0.get("frontier").unwrap().as_u64(), Some(3));
        assert_eq!(s0.get("pull").unwrap().as_u64(), Some(1));
        assert_eq!(s0.get("blocks_skipped").unwrap().as_u64(), Some(2));
        let w0 = &s0.get("workers").unwrap().as_array().unwrap()[0];
        assert_eq!(w0.as_array().unwrap().len(), 4, "phase quad incl. io_wait");
        assert_eq!(
            s0.get("io").unwrap().get("bytes_read").unwrap().as_u64(),
            Some(64)
        );
        // roundtrips through the encoder
        assert!(Json::parse(&j.encode()).is_ok());
        let sum = t.summary_json();
        assert_eq!(sum.get("peak_frontier").unwrap().as_u64(), Some(3));
    }
}
