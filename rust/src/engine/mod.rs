//! The vertex-centric BSP engine — the FlashGraph analogue.
//!
//! Algorithms implement [`VertexProgram`] (mirroring FlashGraph's C++
//! interface, paper Fig. 1a): `run_on_vertex` processes an *activated*
//! vertex once its requested edge lists are in memory; `run_on_message`
//! handles messages from other vertices; `run_on_iteration_end` runs at
//! each global barrier.
//!
//! ## Execution model
//!
//! Processing advances in **rounds** (BSP supersteps). Within round *r*:
//!
//! 1. **Message phase** — every message sent during round *r−1* is
//!    delivered via `run_on_message` on the owner worker of its
//!    destination. Handlers may [`WorkerCtx::activate`] vertices *into the
//!    current round* (their `run_on_vertex` runs in phase 2 below) and may
//!    send messages (delivered in round *r+1*).
//! 2. **Vertex phase** — workers drain the activation bitmap in
//!    fixed-size chunks claimed through per-worker atomic cursors,
//!    **stealing** remaining chunks from other workers once their own
//!    span is empty (see [`runner`] for the scheduler). Each batch's
//!    edge requests are *submitted* asynchronously through the
//!    [`crate::graph::EdgeSource`] into per-worker
//!    [`crate::graph::source::FetchSlot`]s — up to
//!    [`runner::EngineConfig::fetch_window`] batches ride in flight
//!    while the worker processes whichever batch's pages landed first
//!    (this is where SEM I/O overlaps computation, with zero
//!    steady-state allocations), then `run_on_vertex` runs per vertex.
//!    Programs that opt in via [`VertexProgram::supports_pull`] can run
//!    dense rounds in **pull** direction instead (destinations fetch
//!    their neighbor lists and synthesize messages from active sources;
//!    per-chunk source-summary words skip I/O for chunks with no active
//!    source — see [`runner`]). Activations here land in round *r+1*;
//!    messages are delivered in round *r+1*.
//! 3. **Barrier** — per-worker functional reductions are merged,
//!    `run_on_iteration_end` runs once, and the engine stops when no
//!    activations and no messages remain.
//!
//! The paper's *asynchronous applications* principle (§4.4) falls out of
//! this model at the algorithm level: because messages for different
//! phases/sources are delivered in the same round, a program can let its
//! logical phases interleave freely (async BC) or enforce lockstep with
//! its own phase flags (sync BC) — the engine imposes no phase structure
//! beyond rounds.
//!
//! ## Messaging discipline
//!
//! Message transport is selected per program ([`messages`]):
//!
//! * Programs that declare a [`Combiner`] (commutative-associative
//!   messages: rank mass, minima, bitsets, decrement counts) ride
//!   **combiner lanes** — each send folds in place into a dense
//!   per-worker slab indexed by destination vertex. Message memory is
//!   O(n) no matter how many messages are sent, the hot path takes no
//!   locks and allocates nothing, and each destination receives one
//!   folded `run_on_message` per round (the folds appear in
//!   `EngineStats::combined_msgs`).
//! * Everything else rides **queue lanes** — per-(sender, receiver)
//!   SPSC segment queues with a recycled free list, so steady-state
//!   sends are allocation-free. Point-to-point sends enqueue one
//!   `(dst, msg)` tuple; **multicast** sends enqueue a single shared
//!   destination list per destination worker (one allocation, one queue
//!   slot), which is exactly why multicast is cheaper per destination
//!   and why the paper's hybrid switchover (§4.2 "minimize messaging")
//!   matters on this path.
//!
//! Both transports rely on lane ownership + the round barriers instead
//! of locks; [`runner::EngineConfig::transport`] can force the queue
//! baseline for oracle comparisons.

pub mod checkpoint;
pub mod context;
pub mod messages;
pub mod program;
pub mod runner;
pub mod stats;
pub mod trace;

pub use checkpoint::{CheckpointImage, CheckpointWriter};
pub use context::{EndCtx, WorkerCtx};
pub use messages::{Combiner, TransportMode};
pub use program::VertexProgram;
pub use runner::{
    frontier_summary_word, source_bucket, Engine, EngineConfig, RunMode, RunReport, CHUNK_BITS,
};
pub use stats::EngineStats;
pub use trace::{RoundSample, RoundTrace, WorkerPhases};
