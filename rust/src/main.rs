//! `graphyti` — CLI for the semi-external-memory graph library.
//!
//! Subcommands:
//! * `generate` — synthesize a graph and build its on-disk image
//!   (`--format v1|v2` selects fixed-width or delta+varint edges).
//! * `convert`  — rewrite an existing image in the other format version.
//! * `info`     — print image header + degree statistics (no edge I/O).
//! * `scrub`    — verify every page of a checksummed image offline.
//! * `run`      — run a library algorithm in SEM or in-memory mode.
//! * `verify`   — cross-check SEM PageRank against the AOT XLA/Pallas
//!   dense-block engine (requires `make artifacts`).
//! * `serve`    — run the multi-tenant job service (JSON-lines TCP).
//! * `submit`   — submit a job to a running service.
//! * `status`   — query a running service (one job or the whole table).
//! * `health`   — liveness/durability summary from a running service.
//! * `metrics`  — dump the unified metrics registry from a running
//!   service (JSON by default, Prometheus text with `--text`).
//!
//! Arguments are `--key value` pairs (clap is unavailable offline; the
//! parser below is deliberately minimal).

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use graphyti::algs::degree::degree_stats;
use graphyti::coordinator::{open_graph, run_alg, AlgSpec, GraphMode, RunConfig, Table, TraceMode};
use graphyti::engine::RoundTrace;
use graphyti::graph::builder::GraphBuilder;
use graphyti::graph::csr::Csr;
use graphyti::graph::format::GraphIndex;
use graphyti::graph::gen;
use graphyti::runtime::{PageRankXla, XlaRuntime};
use graphyti::service::protocol::Json;
use graphyti::service::{call, GraphService, ServiceConfig, ServiceServer};
use graphyti::util::fmt_bytes;

const USAGE: &str = "\
graphyti — a semi-external memory graph library (Graphyti reproduction)

USAGE:
  graphyti generate --kind rmat|er|ba|grid --scale N --out PATH
                    [--edge-factor F] [--seed S] [--undirected]
                    [--format v1|v2] [--no-checksums]
  graphyti convert  --graph SRC --out DST [--format v1|v2] [--no-checksums]
  graphyti info     --graph PATH
  graphyti scrub    --graph PATH [--rate-mb N]
  graphyti run ALG  --graph PATH [--mem] [--variant V] [--num N]
                    [--cache-mb N] [--io-threads N] [--io-delay-us N]
                    [--workers N] [--mode push|pull|auto] [--pull-density F]
                    [--fetch-window N] [--config FILE]
                    [--trace off|table|json] [--pin]
  graphyti verify   --graph PATH [--iters N]
  graphyti serve    [--port P] [--cache-mb N] [--budget-mb N]
                    [--exec-threads N] [--io-threads N] [--io-delay-us N]
                    [--workers N] [--wal-dir DIR]
                    [--scrub-every-secs N] [--scrub-rate-mb N]
  graphyti submit ALG --graph PATH [--addr HOST:PORT] [--variant V]
                    [--num N] [--priority 0-9] [--wait] [--timeout-ms N]
                    [--job-timeout-ms N]
  graphyti status   [--addr HOST:PORT] [--job ID]
  graphyti health   [--addr HOST:PORT]
  graphyti metrics  [--addr HOST:PORT] [--text]

ALG: pagerank (push|pull), coreness (graphyti|pruned|unopt),
     diameter (multi|uni), bc (async|sync|uni), triangles
     (graphyti|naive), louvain (graphyti|physical), bfs, wcc, sssp, degree

Formats: v1 stores each neighbor as a raw u32; v2 delta+varint-compresses
sorted neighbor lists (~3x smaller on real graphs, proportionally less
read I/O). Every command reads either version transparently; `convert`
rewrites v1 images as v2 (the default target) and back.

Integrity: new images carry a crc32c-per-4KiB-page checksum footer
(opt out with --no-checksums); reads verify pages on every cache miss
and quarantine persistently-bad pages, failing only the job that
touched them. `scrub` sweeps a whole image offline and exits non-zero
if any page fails; `serve --scrub-every-secs N` runs the same sweep in
the background over every open image, rate-limited by --scrub-rate-mb.
Legacy images without footers open and run unchanged.

Service mode: `serve` multiplexes concurrent jobs over one shared page
cache + I/O pool, with an admission budget on summed per-job O(n) state.
`submit`/`status`/`health`/`metrics` speak its JSON-lines TCP protocol.
With `--wal-dir` every job transition is logged durably and checkpoints
land beside the log: a restarted service re-admits queued jobs and
resumes interrupted ones; SIGINT/SIGTERM drain running jobs to a round
boundary (bounded 30 s) before exiting.

Rounds: `--mode auto` pulls along in-edges on dense frontiers (programs
that opt in) and pushes otherwise; `--fetch-window N` keeps N edge
batches in flight per worker beyond the one being processed (0 =
synchronous fetch-then-compute baseline).

Observability: `--trace table` prints a per-round table (frontier,
messages, per-phase time, I/O-wait, direction, skipped edge blocks,
exact per-round I/O deltas); `--trace json` emits the same trace as one
JSON line. `metrics --text` produces a Prometheus-style exposition for
scraping.
";

/// Parse a `--format` value ("v1"/"1"/"v2"/"2") into a version number.
fn parse_format(s: &str) -> graphyti::Result<u32> {
    match s {
        "v1" | "1" => Ok(graphyti::graph::format::VERSION_V1),
        "v2" | "2" => Ok(graphyti::graph::format::VERSION_V2),
        other => anyhow::bail!("unknown format {other} (v1|v2)"),
    }
}

/// Minimal `--key value` + positional parser.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                // boolean flags take no value when followed by another flag
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(key.to_string(), val);
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> graphyti::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    fn require(&self, key: &str) -> graphyti::Result<&str> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing required --{key}"))
    }
}

fn build_config(args: &Args) -> graphyti::Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(p) => RunConfig::load(&PathBuf::from(p))?,
        None => RunConfig::default(),
    };
    for key in [
        "cache-mb",
        "io-threads",
        "io-delay-us",
        "workers",
        "batch",
        "seed",
        "transport",
        "mode",
        "pull-density",
        "fetch-window",
        "trace",
        "pin",
    ] {
        if let Some(v) = args.get(key) {
            cfg.set(&key.replace('-', "_"), v)?;
        }
    }
    Ok(cfg)
}

fn cmd_generate(args: &Args) -> graphyti::Result<()> {
    let kind = args.require("kind")?.to_string();
    let out = PathBuf::from(args.require("out")?);
    let scale = args.get_usize("scale", 14)? as u32;
    let edge_factor = args.get_usize("edge-factor", 16)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let directed = !args.has("undirected");
    let n = 1usize << scale;
    let m = n * edge_factor;
    let edges = match kind.as_str() {
        "rmat" => gen::rmat(scale, m, seed),
        "er" => gen::erdos_renyi(n, m, seed),
        "ba" => gen::barabasi_albert(n, edge_factor.max(1), seed),
        "grid" => {
            let side = 1usize << (scale / 2);
            gen::grid_2d(side, side)
        }
        other => anyhow::bail!("unknown kind {other} (rmat|er|ba|grid)"),
    };
    let nv = match kind.as_str() {
        "grid" => {
            let side = 1usize << (scale / 2);
            side * side
        }
        _ => n,
    };
    let version = parse_format(args.get("format").unwrap_or("v1"))?;
    let checksums = !args.has("no-checksums");
    let mut b = GraphBuilder::new(nv, directed);
    b.add_edges(&edges).format_version(version).checksums(checksums);
    let (idx, adj) = b.build_files(&out)?;
    let index = GraphIndex::decode(&std::fs::read(&idx)?)?;
    println!(
        "generated {kind} scale={scale} (format v{version}{}): {} vertices, {} edges \
         ({} idx, {} adj) -> {}",
        if checksums { ", checksummed" } else { "" },
        index.num_vertices(),
        index.num_edges(),
        fmt_bytes(std::fs::metadata(&idx)?.len()),
        fmt_bytes(std::fs::metadata(&adj)?.len()),
        out.display()
    );
    Ok(())
}

fn cmd_convert(args: &Args) -> graphyti::Result<()> {
    let src = PathBuf::from(args.require("graph")?);
    let dst = PathBuf::from(args.require("out")?);
    let version = parse_format(args.get("format").unwrap_or("v2"))?;
    let checksums = !args.has("no-checksums");
    let src_adj = std::fs::metadata(src.with_extension("gy-adj"))?.len();
    let (idx, adj) =
        graphyti::graph::builder::convert_image_opts(&src, &dst, version, checksums)?;
    let dst_adj = std::fs::metadata(&adj)?.len();
    let index = GraphIndex::decode(&std::fs::read(&idx)?)?;
    println!(
        "converted {} -> {} (format v{version}{}): {} vertices, {} edges",
        src.display(),
        dst.display(),
        if checksums { ", checksummed" } else { "" },
        index.num_vertices(),
        index.num_edges(),
    );
    println!(
        "adjacency bytes: {} -> {} ({:.2}x)",
        fmt_bytes(src_adj),
        fmt_bytes(dst_adj),
        src_adj as f64 / dst_adj.max(1) as f64
    );
    Ok(())
}

fn cmd_info(args: &Args) -> graphyti::Result<()> {
    let base = PathBuf::from(args.require("graph")?);
    let idx_bytes = std::fs::read(base.with_extension("gy-idx"))?;
    let index = GraphIndex::decode(&idx_bytes)?;
    let s = degree_stats(&index);
    println!(
        "graph {}: {} vertices, {} edges, directed={}, format v{}",
        base.display(),
        index.num_vertices(),
        index.num_edges(),
        index.directed(),
        index.header().version
    );
    println!(
        "degree: mean {:.2}, max {} (vertex {}), p50 {}, p99 {}",
        s.mean,
        s.max.1,
        s.max.0,
        s.hist.quantile(0.5),
        s.hist.quantile(0.99)
    );
    println!(
        "adjacency bytes on disk: {}",
        fmt_bytes(std::fs::metadata(base.with_extension("gy-adj"))?.len())
    );
    if index.header().checksums {
        use graphyti::graph::format::{footer_len, ChecksumFooter};
        let idx_footer = ChecksumFooter::from_bytes(&idx_bytes)?;
        let adj_file = std::fs::File::open(base.with_extension("gy-adj"))?;
        let adj_len = adj_file.metadata()?.len();
        let adj_footer = ChecksumFooter::read_from(&adj_file, adj_len)?;
        println!(
            "checksums: crc32c per 4 KiB page, {} pages covered \
             ({} idx + {} adj, {} footer overhead)",
            idx_footer.npages() + adj_footer.npages(),
            idx_footer.npages(),
            adj_footer.npages(),
            fmt_bytes(footer_len(idx_footer.data_len) + footer_len(adj_footer.data_len)),
        );
    } else {
        println!("checksums: none (legacy image; `convert` re-writes with footers)");
    }
    Ok(())
}

fn cmd_scrub(args: &Args) -> graphyti::Result<()> {
    use graphyti::graph::scrub::{scrub_image, ScrubOptions};
    let base = PathBuf::from(args.require("graph")?);
    let opts = ScrubOptions {
        rate_limit_bytes_per_sec: args.get_usize("rate-mb", 0)? as u64 * 1024 * 1024,
        cancel: None,
    };
    let reports = scrub_image(&base, &opts, None)?;
    let mut bad = 0u64;
    for r in &reports {
        if r.skipped {
            println!("{}: skipped (no checksum footer)", r.path.display());
        } else if r.bad_pages.is_empty() {
            println!("{}: {} pages verified, all clean", r.path.display(), r.pages_scrubbed);
        } else {
            println!(
                "{}: {} pages verified, {} FAILED: {:?}",
                r.path.display(),
                r.pages_scrubbed,
                r.bad_pages.len(),
                r.bad_pages
            );
        }
        bad += r.checksum_failures();
    }
    anyhow::ensure!(bad == 0, "scrub found {bad} corrupt page(s)");
    Ok(())
}

fn cmd_run(args: &Args) -> graphyti::Result<()> {
    let alg = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("missing ALG positional (see --help)"))?
        .clone();
    let base = PathBuf::from(args.require("graph")?);
    let cfg = build_config(args)?;
    let variant = args.get("variant").unwrap_or("");
    let num = args.get_usize("num", 8)?;
    let spec = AlgSpec::parse(&alg, variant, num)?;
    let mode = if args.has("mem") { GraphMode::Mem } else { GraphMode::Sem };
    let source = open_graph(&base, mode, &cfg)?;
    let t = std::time::Instant::now();
    let out = run_alg(source.as_ref(), &spec, &cfg);
    let wall = t.elapsed();
    println!("{}", out.summary);
    println!("mode={mode:?} wall={}", graphyti::util::fmt_dur(wall));
    if let Some(r) = out.report {
        println!("{}", r.report());
        if let Some(tr) = &r.trace {
            match cfg.trace {
                TraceMode::Table => print_trace_table(tr),
                TraceMode::Json => println!("{}", tr.to_json().encode()),
                TraceMode::Off => {}
            }
        }
    }
    Ok(())
}

/// Render a recorded trace as one row per round. Phase columns are the
/// slowest worker's time (the critical path for that phase).
fn print_trace_table(tr: &RoundTrace) {
    let ms = |ns: u64| format!("{:.2}", ns as f64 / 1e6);
    let mut t = Table::new(&[
        "round", "dir", "frontier", "activ", "sent", "comb", "steals", "skip", "phA ms", "phB ms",
        "iow ms", "bar ms", "disk", "preads", "hit%",
    ]);
    for s in tr.samples() {
        let pa = s.workers.iter().map(|w| w.phase_a_ns).max().unwrap_or(0);
        let pb = s.workers.iter().map(|w| w.phase_b_ns).max().unwrap_or(0);
        let bar = s.workers.iter().map(|w| w.barrier_ns).max().unwrap_or(0);
        let iow = s.workers.iter().map(|w| w.io_wait_ns).max().unwrap_or(0);
        t.row(&[
            s.round.to_string(),
            if s.pull { "pull" } else { "push" }.to_string(),
            s.frontier.to_string(),
            s.activations.to_string(),
            s.sent.to_string(),
            s.combined.to_string(),
            s.steals.to_string(),
            s.blocks_skipped.to_string(),
            ms(pa),
            ms(pb),
            ms(iow),
            ms(bar),
            fmt_bytes(s.io.bytes_read),
            s.io.physical_reads.to_string(),
            format!("{:.1}", s.io.hit_ratio() * 100.0),
        ]);
    }
    t.print();
    if tr.dropped() > 0 {
        println!("(trace ring overflowed: {} oldest rounds dropped)", tr.dropped());
    }
}

fn cmd_verify(args: &Args) -> graphyti::Result<()> {
    let base = PathBuf::from(args.require("graph")?);
    let iters = args.get_usize("iters", 60)?;
    let cfg = build_config(args)?;
    let index = GraphIndex::decode(&std::fs::read(base.with_extension("gy-idx"))?)?;
    anyhow::ensure!(
        index.num_vertices() <= 512,
        "verify needs n <= 512 (dense XLA path); generate with --scale 9 or less"
    );
    // SEM run
    let source = open_graph(&base, GraphMode::Sem, &cfg)?;
    let sem = graphyti::algs::pagerank::pagerank_push(
        source.as_ref(),
        cfg.alpha,
        1e-12,
        &cfg.engine(),
    );
    // XLA dense-block run (AOT JAX + Pallas artifact via PJRT)
    let rt = Arc::new(XlaRuntime::new()?);
    println!("PJRT platform: {}", rt.platform());
    // rebuild the edge list from the image for the dense operator
    let mem = open_graph(&base, GraphMode::Mem, &cfg)?;
    let mut edges = Vec::new();
    for v in 0..index.num_vertices() as u32 {
        let e = mem.fetch(v, graphyti::graph::format::EdgeRequest::Out)?;
        for &u in &e.out_neighbors {
            edges.push((v, u));
        }
    }
    let csr = Csr::from_edges(index.num_vertices(), &edges, index.directed());
    let xla_rank = PageRankXla::new(rt).pagerank(&csr, cfg.alpha as f32, iters)?;
    let l1: f64 =
        sem.rank.iter().zip(&xla_rank).map(|(a, b)| (a - b).abs()).sum();
    println!(
        "SEM pagerank vs XLA dense-block pagerank ({iters} iters): L1 distance {l1:.2e}"
    );
    anyhow::ensure!(l1 < 1e-3, "verification FAILED: L1 {l1}");
    println!("verification OK");
    Ok(())
}

fn cmd_serve(args: &Args) -> graphyti::Result<()> {
    let port = args.get_usize("port", 7171)?;
    let d = ServiceConfig::default();
    let cfg = ServiceConfig {
        cache_mb: args.get_usize("cache-mb", d.cache_mb)?,
        io_threads: args.get_usize("io-threads", d.io_threads)?,
        io_delay_us: args.get_usize("io-delay-us", d.io_delay_us as usize)? as u64,
        max_run_pages: d.max_run_pages,
        exec_threads: args.get_usize("exec-threads", d.exec_threads)?,
        budget_bytes: args.get_usize("budget-mb", (d.budget_bytes / (1024 * 1024)) as usize)?
            as u64
            * 1024
            * 1024,
        default_workers: args.get_usize("workers", d.default_workers)?,
        wal_dir: args.get("wal-dir").map(PathBuf::from),
        fault: None,
        scrub_every_secs: args.get_usize("scrub-every-secs", 0)? as u64,
        scrub_rate_mb: args.get_usize("scrub-rate-mb", d.scrub_rate_mb as usize)? as u64,
    };
    let svc = GraphService::start(cfg.clone());
    let server = ServiceServer::start(svc.clone(), &format!("127.0.0.1:{port}"))?;
    println!(
        "graphyti service listening on {} (cache {} MiB, budget {}, {} executors{})",
        server.addr(),
        cfg.cache_mb,
        fmt_bytes(cfg.budget_bytes),
        cfg.exec_threads.max(1),
        match &cfg.wal_dir {
            Some(d) => format!(", wal {}", d.display()),
            None => String::new(),
        },
    );
    println!(
        "protocol: one JSON object per line; ops: submit status wait list cancel stats metrics health shutdown"
    );
    install_signal_drain(svc);
    server.wait();
    println!("service stopped");
    Ok(())
}

/// On SIGINT/SIGTERM, drain running jobs to a round boundary (flushing
/// final checkpoints and stamping them resumable in the WAL) instead of
/// dying mid-round. The handler only sets a flag; a watcher thread does
/// the actual shutdown, since almost nothing is async-signal-safe.
#[cfg(unix)]
fn install_signal_drain(svc: Arc<GraphService>) {
    use std::sync::atomic::{AtomicBool, Ordering};
    static SIGNALLED: AtomicBool = AtomicBool::new(false);
    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal as usize);
        signal(SIGTERM, on_signal as usize);
    }
    let _ = std::thread::Builder::new().name("gy-signal".to_string()).spawn(move || loop {
        if SIGNALLED.load(Ordering::SeqCst) {
            eprintln!("graphyti: signal received; draining jobs (bounded 30 s)");
            svc.shutdown_graceful(Duration::from_secs(30));
            eprintln!("service stopped");
            std::process::exit(0);
        }
        std::thread::sleep(Duration::from_millis(200));
    });
}

#[cfg(not(unix))]
fn install_signal_drain(_svc: Arc<GraphService>) {}

fn default_addr(args: &Args) -> String {
    args.get("addr").unwrap_or("127.0.0.1:7171").to_string()
}

fn cmd_submit(args: &Args) -> graphyti::Result<()> {
    let alg = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("missing ALG positional (see --help)"))?
        .clone();
    let graph = args.require("graph")?.to_string();
    let addr = default_addr(args);
    let timeout_ms = args.get_usize("timeout-ms", 600_000)? as u64;
    let mut fields = vec![
        ("op", Json::s("submit")),
        ("graph", Json::s(graph)),
        ("alg", Json::s(alg)),
    ];
    if let Some(v) = args.get("variant") {
        fields.push(("variant", Json::s(v)));
    }
    if args.has("num") {
        fields.push(("num", Json::u(args.get_usize("num", 8)? as u64)));
    }
    if args.has("priority") {
        fields.push(("priority", Json::u(args.get_usize("priority", 4)? as u64)));
    }
    if args.has("job-timeout-ms") {
        // per-job deadline, enforced server-side at round boundaries
        fields.push((
            "config",
            Json::obj(vec![(
                "timeout_ms",
                Json::u(args.get_usize("job-timeout-ms", 0)? as u64),
            )]),
        ));
    }
    let resp = call(&addr, &Json::obj(fields), Duration::from_millis(timeout_ms + 5000))?;
    check_ok(&resp)?;
    let id = resp
        .get("job")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
    let state = resp.get("state").and_then(Json::as_str).unwrap_or("?");
    println!("job {id} {state}");
    if args.has("wait") {
        let req = Json::obj(vec![
            ("op", Json::s("wait")),
            ("job", Json::u(id)),
            ("timeout_ms", Json::u(timeout_ms)),
        ]);
        let resp = call(&addr, &req, Duration::from_millis(timeout_ms + 5000))?;
        check_ok(&resp)?;
        let job = resp
            .get("job")
            .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
        print_job_line(job);
        // scripting contract: --wait exits non-zero unless the job
        // actually completed
        let state = job.get("state").and_then(Json::as_str).unwrap_or("?");
        anyhow::ensure!(state == "done", "job finished in state '{state}'");
    }
    Ok(())
}

fn check_ok(resp: &Json) -> graphyti::Result<()> {
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        let msg = resp.get("error").and_then(Json::as_str).unwrap_or("malformed response");
        anyhow::bail!("service error: {msg}");
    }
    Ok(())
}

fn job_field_u64(job: &Json, key: &str) -> u64 {
    job.get(key).and_then(Json::as_u64).unwrap_or(0)
}

fn print_job_line(job: &Json) {
    let state = job.get("state").and_then(Json::as_str).unwrap_or("?");
    let summary = job.get("summary").and_then(Json::as_str).unwrap_or("-");
    let error = job.get("error").and_then(Json::as_str).unwrap_or("");
    let io = job.get("io");
    let reads = io.map_or(0, |io| job_field_u64(io, "read_requests"));
    let disk = io.map_or(0, |io| job_field_u64(io, "bytes_read"));
    println!(
        "job {} {state}: {summary}{} (wall {:.1} ms, rounds {}, io: {reads} reqs, {} disk)",
        job_field_u64(job, "job"),
        if error.is_empty() { String::new() } else { format!(" [{error}]") },
        job.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0),
        job_field_u64(job, "rounds"),
        fmt_bytes(disk),
    );
}

fn cmd_status(args: &Args) -> graphyti::Result<()> {
    let addr = default_addr(args);
    if args.has("job") {
        let id = args.get_usize("job", 0)? as u64;
        let req = Json::obj(vec![("op", Json::s("status")), ("job", Json::u(id))]);
        let resp = call(&addr, &req, Duration::from_secs(30))?;
        check_ok(&resp)?;
        let job = resp
            .get("job")
            .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
        print_job_line(job);
        return Ok(());
    }
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("list"))]), Duration::from_secs(30))?;
    check_ok(&resp)?;
    let jobs = resp.get("jobs").and_then(Json::as_array).unwrap_or(&[]);
    let mut t = Table::new(&[
        "job", "state", "prio", "alg", "wall", "reads", "disk", "steals", "busy", "p99 fetch",
        "peak msg", "summary",
    ]);
    for job in jobs {
        t.row(&[
            job_field_u64(job, "job").to_string(),
            job.get("state").and_then(Json::as_str).unwrap_or("?").to_string(),
            job_field_u64(job, "priority").to_string(),
            format!(
                "{}{}",
                job.get("alg").and_then(Json::as_str).unwrap_or("?"),
                match job.get("variant").and_then(Json::as_str) {
                    Some("") | None => String::new(),
                    Some(v) => format!("/{v}"),
                }
            ),
            format!("{:.1} ms", job.get("wall_ms").and_then(Json::as_f64).unwrap_or(0.0)),
            job.get("io").map_or(0, |io| job_field_u64(io, "read_requests")).to_string(),
            fmt_bytes(job.get("io").map_or(0, |io| job_field_u64(io, "bytes_read"))),
            job_field_u64(job, "steals").to_string(),
            // null busy_ratio means "unbounded imbalance" (a worker did 0)
            match job.get("busy_ratio").and_then(Json::as_f64) {
                Some(b) => format!("{b:.2}"),
                None => "-".to_string(),
            },
            format!("{} us", job_field_u64(job, "p99_fetch_us")),
            fmt_bytes(job_field_u64(job, "peak_msg_bytes")),
            job.get("summary")
                .and_then(Json::as_str)
                .or_else(|| job.get("error").and_then(Json::as_str))
                .unwrap_or("-")
                .to_string(),
        ]);
    }
    t.print();
    let resp = call(&addr, &Json::obj(vec![("op", Json::s("stats"))]), Duration::from_secs(30))?;
    check_ok(&resp)?;
    if let (Some(io), Some(adm)) = (resp.get("io"), resp.get("admission")) {
        println!(
            "substrate: {} reqs, {} from disk, {} graphs | admission: {} / {} in use (peak {})",
            job_field_u64(io, "read_requests"),
            fmt_bytes(job_field_u64(io, "bytes_read")),
            job_field_u64(&resp, "graphs"),
            fmt_bytes(job_field_u64(adm, "in_use_bytes")),
            fmt_bytes(job_field_u64(adm, "budget_bytes")),
            fmt_bytes(job_field_u64(adm, "peak_bytes")),
        );
    }
    Ok(())
}

fn cmd_health(args: &Args) -> graphyti::Result<()> {
    let addr = default_addr(args);
    let resp =
        call(&addr, &Json::obj(vec![("op", Json::s("health"))]), Duration::from_secs(30))?;
    check_ok(&resp)?;
    let h = resp
        .get("health")
        .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
    let jobs = h.get("jobs");
    println!(
        "status: {} ({} executors, {} graphs open)",
        h.get("status").and_then(Json::as_str).unwrap_or("?"),
        job_field_u64(h, "exec_threads"),
        job_field_u64(h, "graphs_open"),
    );
    if let Some(j) = jobs {
        println!(
            "jobs: {} queued, {} running, {} done, {} failed, {} cancelled, {} rejected",
            job_field_u64(j, "queued"),
            job_field_u64(j, "running"),
            job_field_u64(j, "done"),
            job_field_u64(j, "failed"),
            job_field_u64(j, "cancelled"),
            job_field_u64(j, "rejected"),
        );
    }
    if h.get("wal_enabled").and_then(Json::as_bool) == Some(true) {
        println!(
            "wal: {} records appended, {} replayed, {} skipped, {} jobs resumed",
            job_field_u64(h, "wal_records"),
            job_field_u64(h, "wal_replayed"),
            job_field_u64(h, "wal_skipped"),
            job_field_u64(h, "resumed_jobs"),
        );
    } else {
        println!("wal: disabled (start serve with --wal-dir for durable jobs)");
    }
    println!(
        "io errors: {} transient (retried), {} permanent",
        job_field_u64(h, "io_transient_errors"),
        job_field_u64(h, "io_permanent_errors"),
    );
    Ok(())
}

fn cmd_metrics(args: &Args) -> graphyti::Result<()> {
    let addr = default_addr(args);
    let mut fields = vec![("op", Json::s("metrics"))];
    if args.has("text") {
        fields.push(("format", Json::s("text")));
    }
    let resp = call(&addr, &Json::obj(fields), Duration::from_secs(30))?;
    check_ok(&resp)?;
    if args.has("text") {
        let text = resp
            .get("text")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
        print!("{text}");
    } else {
        let m = resp
            .get("metrics")
            .ok_or_else(|| anyhow::anyhow!("malformed response: {}", resp.encode()))?;
        println!("{}", m.encode());
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        print!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    let args = Args::parse(&argv);
    let result = match argv[0].as_str() {
        "generate" => cmd_generate(&args),
        "convert" => cmd_convert(&args),
        "info" => cmd_info(&args),
        "scrub" => cmd_scrub(&args),
        "run" => cmd_run(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "status" => cmd_status(&args),
        "health" => cmd_health(&args),
        "metrics" => cmd_metrics(&args),
        other => {
            eprintln!("unknown command: {other}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}
