//! The job executor: a pool of runner threads draining a priority queue
//! of submitted jobs, gated by the [`AdmissionController`], with
//! cooperative cancellation and per-job I/O attribution.
//!
//! Scheduling policy: highest priority first, FIFO within a priority,
//! with **backfill** — if the head job does not fit the remaining
//! admission headroom, a smaller lower-priority job may run ahead of it
//! rather than idling the node. Jobs whose footprint exceeds the whole
//! budget are rejected at submit time.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{run_alg, AlgSpec, RunConfig};
use crate::graph::source::EdgeSource;
use crate::safs::{FaultPlan, IoConfig, IoStatsSnapshot};
use crate::service::admission::{
    estimate_checkpoint_bytes, estimate_state_bytes, AdmissionController, AdmissionDecision,
};
use crate::service::registry::{GraphRegistry, JobGraph};
use crate::service::wal::{JobWal, WalJob};

/// Service-wide configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Shared page-cache capacity in MiB (one cache for all graphs).
    pub cache_mb: usize,
    /// Shared I/O pool threads.
    pub io_threads: usize,
    /// Injected latency per physical read, microseconds.
    pub io_delay_us: u64,
    /// Max pages per merged physical read.
    pub max_run_pages: usize,
    /// Concurrent job-runner threads.
    pub exec_threads: usize,
    /// Admission budget for summed per-job vertex-state bytes.
    pub budget_bytes: u64,
    /// Engine worker threads per job (0 = one per core; keep small so
    /// concurrent jobs share cores rather than oversubscribing).
    pub default_workers: usize,
    /// Durability directory: when set, job lifecycle transitions go to
    /// a write-ahead log under it (`jobs.wal`) and checkpointing jobs
    /// park their snapshots there (`job-<id>.ckpt`). `None` = the
    /// pre-WAL volatile scheduler.
    pub wal_dir: Option<PathBuf>,
    /// I/O fault injection forwarded to the shared pool (tests/chaos
    /// runs only).
    pub fault: Option<FaultPlan>,
    /// Background scrub interval in seconds (0 = no scrubber). When
    /// set, a dedicated thread sweeps every open checksummed image this
    /// often, feeding `pages_scrubbed`/`checksum_failures` into the
    /// substrate stats.
    pub scrub_every_secs: u64,
    /// Scrub rate limit in MiB/s (0 = unthrottled). Keeps a sweep from
    /// competing with job I/O for bandwidth.
    pub scrub_rate_mb: u64,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            cache_mb: 64,
            io_threads: 4,
            io_delay_us: 0,
            max_run_pages: 256,
            exec_threads: 2,
            budget_bytes: 1 << 30,
            default_workers: 2,
            wal_dir: None,
            fault: None,
            scrub_every_secs: 0,
            scrub_rate_mb: 8,
        }
    }
}

/// A job submission.
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// Image base path (`<base>.gy-idx` / `<base>.gy-adj`).
    pub graph: PathBuf,
    /// Algorithm name (as accepted by [`AlgSpec::parse`]).
    pub alg: String,
    /// Algorithm variant ("" = default).
    pub variant: String,
    /// Numeric parameter (source vertex, #sources, #sweeps — per alg).
    pub num: usize,
    /// Priority 0 (lowest) ..= 9 (highest); default 4.
    pub priority: u8,
    /// `RunConfig` `key=value` overrides applied to this job only.
    pub overrides: Vec<(String, String)>,
}

impl JobRequest {
    /// A default-shaped request for `alg` on `graph`.
    pub fn new(graph: impl Into<PathBuf>, alg: impl Into<String>) -> Self {
        JobRequest {
            graph: graph.into(),
            alg: alg.into(),
            variant: String::new(),
            num: 8,
            priority: 4,
            overrides: Vec::new(),
        }
    }
}

/// Lifecycle of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for an executor slot + admission headroom.
    Queued,
    /// Executing on a runner thread.
    Running,
    /// Finished successfully; `summary` holds the result.
    Done,
    /// Errored or panicked; `error` holds the reason.
    Failed,
    /// Cancelled (before start, or cooperatively at a round boundary).
    Cancelled,
    /// Footprint exceeds the admission budget; never ran.
    Rejected,
}

impl JobState {
    /// True once the job can no longer change state.
    pub fn is_terminal(self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }

    /// Wire/spelled-out name.
    pub fn as_str(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
            JobState::Rejected => "rejected",
        }
    }
}

/// Point-in-time public view of a job.
#[derive(Debug, Clone)]
pub struct JobStatus {
    /// Job id (1-based, unique per service instance).
    pub id: u64,
    /// Current lifecycle state.
    pub state: JobState,
    /// Graph image base path.
    pub graph: String,
    /// Algorithm name.
    pub alg: String,
    /// Algorithm variant.
    pub variant: String,
    /// Priority 0..=9.
    pub priority: u8,
    /// Admission-accounted vertex-state footprint estimate (bytes).
    pub state_bytes: u64,
    /// Result summary (set on `Done`; may hold a partial result on
    /// `Cancelled`).
    pub summary: Option<String>,
    /// Failure/cancellation/rejection reason.
    pub error: Option<String>,
    /// Engine rounds executed.
    pub rounds: u64,
    /// Frontier chunks stolen between engine workers during the run —
    /// nonzero means the work-stealing scheduler rebalanced a skewed
    /// frontier for this job.
    pub steals: u64,
    /// Max/min per-worker busy-time ratio (1.0 = perfectly balanced;
    /// `f64::INFINITY` if a worker recorded no busy time).
    pub busy_ratio: f64,
    /// Sends absorbed by combiner-lane folds — nonzero means the job's
    /// program ran on the dense O(n) message transport.
    pub combined_msgs: u64,
    /// Peak message-transport bytes for the run (O(n)-bounded on the
    /// combiner path; useful next to `state_bytes` when budgeting).
    pub peak_msg_bytes: u64,
    /// Wall time of the run (zero unless it ran).
    pub wall: Duration,
    /// This job's own I/O, disjointly attributed via its private
    /// [`crate::safs::IoStats`] (snapshot delta over the run).
    pub io: IoStatsSnapshot,
    /// Full engine counters for the run (zeroed until it finishes) —
    /// the source the metrics export enumerates per job.
    pub engine: crate::engine::stats::EngineStatsSnapshot,
    /// Monotonic completion order (1-based; 0 = not finished). Lets
    /// callers audit scheduling order without wall-clock comparisons.
    pub finish_seq: u64,
}

struct Job {
    status: JobStatus,
    req: JobRequest,
    spec: AlgSpec,
    cost: u64,
    seq: u64,
    cancel: Arc<AtomicBool>,
    /// Replayed from a WAL `running`/`interrupted` record: try to
    /// resume from the job's checkpoint instead of starting fresh.
    resume: bool,
}

#[derive(Default)]
struct Inner {
    jobs: HashMap<u64, Job>,
    /// Ids of jobs in `Queued` state, unordered (scheduling sorts).
    queue: Vec<u64>,
    shutdown: bool,
}

/// Per-state job counts, for the `stats` protocol op and the CLI.
#[derive(Debug, Clone, Copy, Default)]
pub struct JobCounts {
    pub queued: usize,
    pub running: usize,
    pub done: usize,
    pub failed: usize,
    pub cancelled: usize,
    pub rejected: usize,
}

/// Service liveness summary, for the `health` protocol op and the
/// `graphyti health` CLI subcommand.
#[derive(Debug, Clone)]
pub struct Health {
    /// `"ok"`, or `"draining"` once shutdown has begun.
    pub status: String,
    /// Executor threads serving the queue.
    pub exec_threads: usize,
    /// Graph images currently open in the registry.
    pub graphs_open: usize,
    /// Per-state job counts.
    pub jobs: JobCounts,
    /// Whether a write-ahead job log is configured.
    pub wal_enabled: bool,
    /// WAL records appended since start.
    pub wal_records: u64,
    /// WAL records replayed at start.
    pub wal_replayed: u64,
    /// Torn/corrupt WAL lines skipped at start.
    pub wal_skipped: u64,
    /// Jobs re-queued with resume-from-checkpoint at start.
    pub resumed_jobs: u64,
    /// Substrate I/O errors that retried and then succeeded or failed.
    pub io_transient_errors: u64,
    /// Substrate I/O errors that exhausted retries or were permanent.
    pub io_permanent_errors: u64,
    /// Page checksum verifications that failed (verify-on-read + scrub).
    pub checksum_failures: u64,
    /// Pages quarantined after a persistent checksum failure.
    pub quarantined_pages: u64,
    /// Pages verified by scrub sweeps (CLI or background).
    pub pages_scrubbed: u64,
    /// Completed background scrub sweeps (0 when the scrubber is off).
    pub scrub_sweeps: u64,
}

/// The multi-tenant graph service: registry + admission + executor.
pub struct GraphService {
    cfg: ServiceConfig,
    registry: Arc<GraphRegistry>,
    admission: AdmissionController,
    inner: Mutex<Inner>,
    cv: Condvar,
    next_id: AtomicU64,
    next_seq: AtomicU64,
    next_finish: AtomicU64,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
    /// Durable job log (None without `wal_dir`).
    wal: Option<JobWal>,
    /// Jobs re-queued with resume-from-checkpoint at this start.
    resumed_jobs: AtomicU64,
    /// Graceful-shutdown flag: running jobs winding down at a round
    /// boundary are stamped `interrupted` (resumable), not `cancelled`.
    draining: AtomicBool,
    /// Cooperative stop flag for the background scrubber thread.
    scrub_stop: Arc<AtomicBool>,
    /// The background scrubber thread (None when `scrub_every_secs` is 0).
    scrubber: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Completed background scrub sweeps.
    scrub_sweeps: AtomicU64,
}

impl GraphService {
    /// Start the service: build the shared substrate, replay the WAL
    /// (when configured) and spawn the executor threads.
    pub fn start(cfg: ServiceConfig) -> Arc<Self> {
        let io = IoConfig {
            threads: cfg.io_threads,
            io_delay_us: cfg.io_delay_us,
            max_run_pages: cfg.max_run_pages,
            fault: cfg.fault.clone(),
        };
        let registry = Arc::new(GraphRegistry::new(cfg.cache_mb * 1024 * 1024, io));
        let admission = AdmissionController::new(cfg.budget_bytes);
        let (wal, replayed) = match &cfg.wal_dir {
            Some(dir) => match JobWal::open(dir) {
                Ok((w, jobs)) => (Some(w), jobs),
                Err(e) => {
                    eprintln!("graphyti: WAL unusable ({e:#}); running without durability");
                    (None, Vec::new())
                }
            },
            None => (None, Vec::new()),
        };
        let svc = Arc::new(GraphService {
            registry,
            admission,
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            next_id: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
            next_finish: AtomicU64::new(0),
            workers: Mutex::new(Vec::new()),
            wal,
            resumed_jobs: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            scrub_stop: Arc::new(AtomicBool::new(false)),
            scrubber: Mutex::new(None),
            scrub_sweeps: AtomicU64::new(0),
            cfg,
        });
        // replay before the executors exist, so re-queued jobs are
        // re-admitted exactly once and in WAL id order
        svc.replay_wal_jobs(replayed);
        let nthreads = svc.cfg.exec_threads.max(1);
        let mut handles = Vec::with_capacity(nthreads);
        for i in 0..nthreads {
            let s = svc.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("gy-exec-{i}"))
                    .spawn(move || s.worker_loop())
                    .expect("spawn executor thread"),
            );
        }
        *svc.workers.lock().unwrap() = handles;
        if svc.cfg.scrub_every_secs > 0 {
            let s = svc.clone();
            let h = std::thread::Builder::new()
                .name("gy-scrub".to_string())
                .spawn(move || s.scrub_loop())
                .expect("spawn scrubber thread");
            *svc.scrubber.lock().unwrap() = Some(h);
        }
        svc
    }

    /// Background scrubber: every `scrub_every_secs`, sweep all open
    /// images through [`crate::graph::scrub::scrub_image`], rate-limited
    /// and cancellable, feeding counters into the substrate stats so
    /// health/metrics show latent corruption without waiting for a job
    /// to stumble over it.
    fn scrub_loop(&self) {
        let interval = Duration::from_secs(self.cfg.scrub_every_secs);
        let opts = crate::graph::scrub::ScrubOptions {
            rate_limit_bytes_per_sec: self.cfg.scrub_rate_mb * 1024 * 1024,
            cancel: Some(self.scrub_stop.clone()),
        };
        loop {
            // sleep in small slices so shutdown never waits a full interval
            let wake = Instant::now() + interval;
            while Instant::now() < wake {
                if self.scrub_stop.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50).min(interval));
            }
            let mut cancelled = false;
            for base in self.registry.open_image_bases() {
                if self.scrub_stop.load(Ordering::Relaxed) {
                    return;
                }
                match crate::graph::scrub::scrub_image(
                    &base,
                    &opts,
                    Some(self.registry.stats()),
                ) {
                    Ok(reports) => {
                        cancelled |= reports.iter().any(|r| r.cancelled);
                        for r in &reports {
                            if !r.bad_pages.is_empty() {
                                eprintln!(
                                    "graphyti: scrub found {} bad page(s) in {}: {:?}",
                                    r.bad_pages.len(),
                                    r.path.display(),
                                    r.bad_pages
                                );
                            }
                        }
                    }
                    // an unreadable image is a scrub finding, not a
                    // reason to kill the scrubber
                    Err(e) => eprintln!(
                        "graphyti: scrub of {} failed: {e:#}",
                        base.display()
                    ),
                }
            }
            if !cancelled {
                self.scrub_sweeps.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Fold the WAL's replayed job table back into the scheduler:
    /// terminal jobs become queryable history, non-terminal ones are
    /// re-queued exactly once (no new `submitted` record — the log
    /// already holds theirs), and jobs caught mid-run are flagged to
    /// resume from their checkpoint. Jobs whose graph or spec no longer
    /// validates are marked `Failed` rather than crashing the start.
    fn replay_wal_jobs(&self, recs: Vec<WalJob>) {
        if recs.is_empty() {
            return;
        }
        let max_id = recs.iter().map(|w| w.id).max().unwrap_or(0);
        self.next_id.fetch_max(max_id, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        for w in recs {
            let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
            let priority = w.priority.min(9) as u8;
            let req = JobRequest {
                graph: PathBuf::from(&w.graph),
                alg: w.alg.clone(),
                variant: w.variant.clone(),
                num: w.num as usize,
                priority,
                overrides: w.overrides.clone(),
            };
            let mut status = JobStatus {
                id: w.id,
                state: JobState::Queued,
                graph: w.graph.clone(),
                alg: w.alg.clone(),
                variant: w.variant.clone(),
                priority,
                state_bytes: 0,
                summary: None,
                error: w.error.clone(),
                rounds: 0,
                steals: 0,
                busy_ratio: 1.0,
                combined_msgs: 0,
                peak_msg_bytes: 0,
                wall: Duration::ZERO,
                io: IoStatsSnapshot::default(),
                engine: Default::default(),
                finish_seq: 0,
            };
            // placeholder spec for entries that will never execute
            let mut spec = AlgSpec::Degree;
            let mut cost = 0u64;
            let mut queued = false;
            let resume = w.needs_resume();
            if w.is_terminal() {
                status.state = match w.state.as_str() {
                    "done" => JobState::Done,
                    "cancelled" => JobState::Cancelled,
                    "rejected" => JobState::Rejected,
                    _ => JobState::Failed,
                };
                status.finish_seq = self.next_finish.fetch_add(1, Ordering::Relaxed) + 1;
            } else {
                match AlgSpec::parse(&w.alg, &w.variant, w.num as usize)
                    .and_then(|s| self.replay_cost(&req, &s).map(|c| (s, c)))
                {
                    Ok((s, c)) => {
                        spec = s;
                        cost = c;
                        status.state_bytes = c;
                        status.error = None;
                        queued = true;
                        if resume {
                            self.resumed_jobs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Err(e) => {
                        status.state = JobState::Failed;
                        status.error = Some(format!("replay: {e:#}"));
                        status.finish_seq =
                            self.next_finish.fetch_add(1, Ordering::Relaxed) + 1;
                        if let Some(wal) = &self.wal {
                            wal.record_state(w.id, "failed", status.error.as_deref());
                        }
                    }
                }
            }
            let id = w.id;
            let job = Job {
                status,
                req,
                spec,
                cost,
                seq,
                cancel: Arc::new(AtomicBool::new(false)),
                resume: queued && resume,
            };
            inner.jobs.insert(id, job);
            if queued {
                inner.queue.push(id);
            }
        }
    }

    /// Recompute a replayed job's admission cost the way [`Self::submit`]
    /// would, revalidating its overrides and graph image.
    fn replay_cost(&self, req: &JobRequest, spec: &AlgSpec) -> crate::Result<u64> {
        let mut rc = RunConfig { workers: self.cfg.default_workers, ..Default::default() };
        for (k, v) in &req.overrides {
            rc.set(k, v)?;
        }
        let g = self.registry.open(&req.graph)?;
        let n = g.index().num_vertices() as u64;
        let workers = (rc.engine().workers as u64).min(n.max(1));
        let mut cost = estimate_state_bytes(spec, n, workers, rc.fetch_window as u64);
        if rc.checkpoint_every > 0 {
            cost += estimate_checkpoint_bytes(spec, n);
        }
        Ok(cost)
    }

    /// Submit a job. Validates the algorithm spec, the config overrides
    /// and the graph image immediately, so bad requests fail here
    /// rather than asynchronously. Returns the job id; jobs whose
    /// footprint exceeds the whole admission budget come back
    /// `Rejected`.
    pub fn submit(&self, req: JobRequest) -> crate::Result<u64> {
        let priority = req.priority.min(9);
        let spec = AlgSpec::parse(&req.alg, &req.variant, req.num)?;
        // substrate knobs are sized once at serve time and shared by all
        // jobs; accepting them per job would silently do nothing, so
        // reject them loudly. Everything else is validated now rather
        // than when the job eventually runs.
        const SUBSTRATE_KEYS: [&str; 4] =
            ["cache_mb", "io_threads", "io_delay_us", "max_run_pages"];
        // validate overrides by applying them to a config shaped the way
        // the executor will build it — one resolution path, so the
        // worker count admission charges is the worker count the engine
        // will actually run with (combiner-lane message memory is per
        // worker, so a per-job `workers` override changes the footprint
        // being reserved)
        let mut rc = RunConfig { workers: self.cfg.default_workers, ..Default::default() };
        for (k, v) in &req.overrides {
            let key = k.trim();
            anyhow::ensure!(
                !SUBSTRATE_KEYS.contains(&key),
                "config '{key}' sizes the shared substrate and is fixed at service \
                 start; set it via the `serve` flags instead"
            );
            rc.set(key, v)?;
        }
        let g = self.registry.open(&req.graph)?;
        let n = g.index().num_vertices() as u64;
        // rc.engine() resolves 0 => one worker per core, exactly as the
        // run will; Engine::run additionally clamps to n
        let workers = (rc.engine().workers as u64).min(n.max(1));
        let mut cost = estimate_state_bytes(&spec, n, workers, rc.fetch_window as u64);
        if rc.checkpoint_every > 0 {
            // the checkpoint staging buffer is a real O(n) allocation at
            // every cut; charge it only for jobs that opt in
            cost += estimate_checkpoint_bytes(&spec, n);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let rejected = cost > self.admission.budget();
        let mut status = JobStatus {
            id,
            state: if rejected { JobState::Rejected } else { JobState::Queued },
            graph: req.graph.display().to_string(),
            alg: req.alg.clone(),
            variant: req.variant.clone(),
            priority,
            state_bytes: cost,
            summary: None,
            error: None,
            rounds: 0,
            steals: 0,
            busy_ratio: 1.0,
            combined_msgs: 0,
            peak_msg_bytes: 0,
            wall: Duration::ZERO,
            io: IoStatsSnapshot::default(),
            engine: Default::default(),
            finish_seq: 0,
        };
        if rejected {
            status.error = Some(format!(
                "admission: estimated state footprint {cost} B exceeds budget {} B",
                self.admission.budget()
            ));
        }
        let queued = status.state == JobState::Queued;
        // write-ahead: the submission is durable before it is visible
        if let Some(w) = &self.wal {
            w.record_submitted(&WalJob {
                id,
                graph: status.graph.clone(),
                alg: req.alg.clone(),
                variant: req.variant.clone(),
                num: req.num as u64,
                priority: priority as u64,
                overrides: req.overrides.clone(),
                state: String::new(), // forced to "queued" by the WAL
                error: None,
                ckpt_round: 0,
            });
            if rejected {
                w.record_state(id, "rejected", status.error.as_deref());
            }
        }
        let job = Job {
            status,
            req,
            spec,
            cost,
            seq,
            cancel: Arc::new(AtomicBool::new(false)),
            resume: false,
        };
        {
            let mut inner = self.inner.lock().unwrap();
            anyhow::ensure!(!inner.shutdown, "service is shutting down");
            inner.jobs.insert(id, job);
            if queued {
                inner.queue.push(id);
            }
        }
        self.cv.notify_all();
        Ok(id)
    }

    /// Current status of a job.
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.inner.lock().unwrap().jobs.get(&id).map(|j| j.status.clone())
    }

    /// All jobs, ordered by id.
    pub fn list(&self) -> Vec<JobStatus> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<JobStatus> = inner.jobs.values().map(|j| j.status.clone()).collect();
        out.sort_by_key(|s| s.id);
        out
    }

    /// Per-state job counts.
    pub fn job_counts(&self) -> JobCounts {
        let inner = self.inner.lock().unwrap();
        let mut c = JobCounts::default();
        for j in inner.jobs.values() {
            match j.status.state {
                JobState::Queued => c.queued += 1,
                JobState::Running => c.running += 1,
                JobState::Done => c.done += 1,
                JobState::Failed => c.failed += 1,
                JobState::Cancelled => c.cancelled += 1,
                JobState::Rejected => c.rejected += 1,
            }
        }
        c
    }

    /// Cancel a job. Queued jobs flip to `Cancelled` immediately;
    /// running jobs get their token set and wind down cooperatively at
    /// the next engine round boundary. Returns false for unknown or
    /// already-terminal jobs.
    pub fn cancel(&self, id: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let state = match inner.jobs.get(&id) {
            Some(j) => j.status.state,
            None => return false,
        };
        match state {
            JobState::Queued => {
                inner.queue.retain(|&q| q != id);
                let j = inner.jobs.get_mut(&id).unwrap();
                j.status.state = JobState::Cancelled;
                j.status.error = Some("cancelled before start".to_string());
                j.status.finish_seq = self.next_finish.fetch_add(1, Ordering::Relaxed) + 1;
                drop(inner);
                if let Some(w) = &self.wal {
                    w.record_state(id, "cancelled", Some("cancelled before start"));
                }
                self.cv.notify_all();
                true
            }
            JobState::Running => {
                inner.jobs[&id].cancel.store(true, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// Block until the job reaches a terminal state or `timeout`
    /// elapses. Returns `None` for unknown jobs; on timeout the (still
    /// non-terminal) current status is returned — check
    /// [`JobState::is_terminal`].
    pub fn wait(&self, id: u64, timeout: Duration) -> Option<JobStatus> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            match inner.jobs.get(&id) {
                None => return None,
                Some(j) if j.status.state.is_terminal() => return Some(j.status.clone()),
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                return inner.jobs.get(&id).map(|j| j.status.clone());
            }
            let (guard, _) = self.cv.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
        }
    }

    /// Substrate-wide I/O counters (all jobs, all graphs).
    pub fn substrate_stats(&self) -> IoStatsSnapshot {
        self.registry.stats().snapshot()
    }

    /// Enumerate the whole service — SAFS substrate, cache, admission,
    /// scheduler and per-job engine counters — into one
    /// [`MetricsRegistry`], the source for both the `{"op":"metrics"}`
    /// protocol op (JSON) and the Prometheus-style text dump.
    pub fn metrics(&self) -> crate::util::MetricsRegistry {
        let mut m = crate::util::MetricsRegistry::new();

        // SAFS substrate: every counter + the four hot-path histograms
        let io = self.substrate_stats();
        m.counter("io_read_requests", io.read_requests);
        m.counter("io_logical_bytes", io.logical_bytes);
        m.counter("io_bytes_read", io.bytes_read);
        m.counter("io_physical_reads", io.physical_reads);
        m.counter("io_cache_hits", io.cache_hits);
        m.counter("io_cache_misses", io.cache_misses);
        m.counter("io_merged_requests", io.merged_requests);
        m.counter("io_thread_waits", io.thread_waits);
        m.counter("io_evictions", io.evictions);
        m.counter("io_retries", io.retries);
        m.counter("io_transient_errors", io.transient_errors);
        m.counter("io_permanent_errors", io.permanent_errors);
        m.counter("io_backoff_waits", io.backoff_waits);
        m.counter("io_backoff_us", io.backoff_us);
        m.counter("io_checksum_failures", io.checksum_failures);
        m.counter("io_quarantined_pages", io.quarantined_pages);
        m.counter("io_pages_scrubbed", io.pages_scrubbed);
        m.counter("scrub_sweeps", self.scrub_sweeps.load(Ordering::Relaxed));
        m.hist("io_fetch_latency_us", io.latency.fetch);
        m.hist("io_wait_latency_us", io.latency.wait);
        m.hist("io_pread_latency_us", io.latency.pread);
        m.hist("io_run_pages", io.latency.run_pages);

        // cache + admission + registry
        let cache = self.registry.cache();
        m.gauge("cache_occupancy", cache.occupancy());
        m.gauge("cache_resident_pages", cache.resident_pages() as f64);
        m.gauge("cache_capacity_pages", cache.capacity_pages() as f64);
        m.gauge("admission_budget_bytes", self.admission.budget() as f64);
        m.gauge("admission_in_use_bytes", self.admission.in_use() as f64);
        m.gauge("admission_peak_bytes", self.admission.peak() as f64);
        m.gauge("graphs_open", self.registry.num_graphs() as f64);
        m.gauge("resident_index_bytes", self.registry.resident_index_bytes() as f64);

        // scheduler
        let counts = self.job_counts();
        m.gauge("jobs_queued", counts.queued as f64);
        m.gauge("jobs_running", counts.running as f64);
        m.counter("jobs_done", counts.done as u64);
        m.counter("jobs_failed", counts.failed as u64);
        m.counter("jobs_cancelled", counts.cancelled as u64);
        m.counter("jobs_rejected", counts.rejected as u64);
        m.counter("resumed_jobs", self.resumed_jobs.load(Ordering::Relaxed));

        // durability
        if let Some(w) = &self.wal {
            m.counter("wal_records", w.records());
            m.counter("wal_replays", w.replayed());
            m.counter("wal_skipped", w.skipped());
            m.counter("wal_compactions", w.compactions());
            m.gauge("wal_bytes", w.size() as f64);
        }

        // engine counters: service-wide aggregates over every job that
        // ran, then a labeled per-job breakdown
        let jobs = self.list();
        let mut agg = crate::engine::stats::EngineStatsSnapshot::default();
        for st in &jobs {
            agg.p2p_msgs += st.engine.p2p_msgs;
            agg.multicast_msgs += st.engine.multicast_msgs;
            agg.deliveries += st.engine.deliveries;
            agg.combined_msgs += st.engine.combined_msgs;
            agg.peak_msg_bytes = agg.peak_msg_bytes.max(st.engine.peak_msg_bytes);
            agg.msg_allocs += st.engine.msg_allocs;
            agg.phase_a_ns += st.engine.phase_a_ns;
            agg.phase_b_ns += st.engine.phase_b_ns;
            agg.io_wait_ns += st.engine.io_wait_ns;
            agg.vertex_runs += st.engine.vertex_runs;
            agg.rounds += st.engine.rounds;
            agg.pull_rounds += st.engine.pull_rounds;
            agg.blocks_skipped += st.engine.blocks_skipped;
            agg.steals += st.engine.steals;
            agg.fetch_allocs += st.engine.fetch_allocs;
            agg.checkpoints += st.engine.checkpoints;
            agg.checkpoint_bytes += st.engine.checkpoint_bytes;
            agg.park_ns += st.engine.park_ns;
            agg.backoff_events += st.engine.backoff_events;
        }
        m.counter("engine_p2p_msgs", agg.p2p_msgs);
        m.counter("engine_multicast_msgs", agg.multicast_msgs);
        m.counter("engine_deliveries", agg.deliveries);
        m.counter("engine_combined_msgs", agg.combined_msgs);
        m.gauge("engine_peak_msg_bytes", agg.peak_msg_bytes as f64);
        m.counter("engine_msg_allocs", agg.msg_allocs);
        m.counter("engine_phase_a_ns", agg.phase_a_ns);
        m.counter("engine_phase_b_ns", agg.phase_b_ns);
        m.counter("engine_io_wait_ns", agg.io_wait_ns);
        m.counter("engine_vertex_runs", agg.vertex_runs);
        m.counter("engine_rounds", agg.rounds);
        m.counter("engine_pull_rounds", agg.pull_rounds);
        m.counter("engine_blocks_skipped", agg.blocks_skipped);
        m.counter("engine_steals", agg.steals);
        m.counter("engine_fetch_allocs", agg.fetch_allocs);
        m.counter("engine_checkpoints", agg.checkpoints);
        m.counter("engine_checkpoint_bytes", agg.checkpoint_bytes);
        m.counter("engine_park_ns", agg.park_ns);
        m.counter("engine_backoff_events", agg.backoff_events);
        m.gauge("engine_overlap_ratio", agg.overlap_ratio());
        for st in &jobs {
            let labels = format!("{{job=\"{}\",alg=\"{}\"}}", st.id, st.alg);
            m.counter(format!("job_rounds{labels}"), st.rounds);
            m.counter(format!("job_pull_rounds{labels}"), st.engine.pull_rounds);
            m.counter(format!("job_blocks_skipped{labels}"), st.engine.blocks_skipped);
            m.counter(format!("job_steals{labels}"), st.steals);
            m.counter(format!("job_bytes_read{labels}"), st.io.bytes_read);
            m.gauge(format!("job_busy_ratio{labels}"), st.busy_ratio);
            m.gauge(format!("job_overlap_ratio{labels}"), st.engine.overlap_ratio());
            m.hist(format!("job_fetch_latency_us{labels}"), st.io.latency.fetch);
        }
        m
    }

    /// The admission controller (budget/in-use/peak introspection).
    pub fn admission(&self) -> &AdmissionController {
        &self.admission
    }

    /// The graph registry.
    pub fn registry(&self) -> &Arc<GraphRegistry> {
        &self.registry
    }

    /// Service configuration.
    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Stop accepting work, cancel running jobs cooperatively, and join
    /// the executor threads. Queued jobs are left `Queued` (reported by
    /// status, never run — though with a WAL they replay next start).
    pub fn shutdown(&self) {
        self.stop_scrubber();
        {
            let mut inner = self.inner.lock().unwrap();
            inner.shutdown = true;
            for j in inner.jobs.values() {
                if j.status.state == JobState::Running {
                    j.cancel.store(true, Ordering::Relaxed);
                }
            }
        }
        self.cv.notify_all();
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Stop and join the background scrubber (idempotent; sweeps in
    /// flight stop within one chunk).
    fn stop_scrubber(&self) {
        self.scrub_stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.scrubber.lock().unwrap().take() {
            let _ = h.join();
        }
    }

    /// Graceful shutdown: stop accepting work, let running jobs drain
    /// to their next round boundary (writing a final checkpoint when
    /// enabled), bounded by `drain`. Jobs that wind down in time are
    /// stamped `interrupted` in the WAL — as are any stragglers still
    /// running at the deadline — so the next start resumes them from
    /// their checkpoint instead of redoing the work.
    pub fn shutdown_graceful(&self, drain: Duration) {
        self.stop_scrubber();
        self.draining.store(true, Ordering::SeqCst);
        {
            let mut inner = self.inner.lock().unwrap();
            inner.shutdown = true;
            for j in inner.jobs.values() {
                if j.status.state == JobState::Running {
                    j.cancel.store(true, Ordering::Relaxed);
                }
            }
        }
        self.cv.notify_all();
        let deadline = Instant::now() + drain;
        let stragglers: Vec<u64> = {
            let mut inner = self.inner.lock().unwrap();
            loop {
                let running: Vec<u64> = inner
                    .jobs
                    .values()
                    .filter(|j| j.status.state == JobState::Running)
                    .map(|j| j.status.id)
                    .collect();
                if running.is_empty() {
                    break running;
                }
                let now = Instant::now();
                if now >= deadline {
                    break running;
                }
                let (guard, _) = self.cv.wait_timeout(inner, deadline - now).unwrap();
                inner = guard;
            }
        };
        if let Some(w) = &self.wal {
            // deadline elapsed mid-run: durably mark the jobs resumable
            // now, in case the process dies before they reach their
            // round boundary (a later record supersedes this one)
            for id in stragglers {
                w.record_state(id, "interrupted", Some("shutdown deadline elapsed mid-run"));
            }
        }
        let handles: Vec<_> = self.workers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }

    /// Liveness/readiness summary for the `health` protocol op and CLI
    /// subcommand.
    pub fn health(&self) -> Health {
        let io = self.substrate_stats();
        let draining =
            self.draining.load(Ordering::Relaxed) || self.inner.lock().unwrap().shutdown;
        Health {
            status: if draining { "draining" } else { "ok" }.to_string(),
            exec_threads: self.cfg.exec_threads.max(1),
            graphs_open: self.registry.num_graphs(),
            jobs: self.job_counts(),
            wal_enabled: self.wal.is_some(),
            wal_records: self.wal.as_ref().map(|w| w.records()).unwrap_or(0),
            wal_replayed: self.wal.as_ref().map(|w| w.replayed()).unwrap_or(0),
            wal_skipped: self.wal.as_ref().map(|w| w.skipped()).unwrap_or(0),
            resumed_jobs: self.resumed_jobs.load(Ordering::Relaxed),
            io_transient_errors: io.transient_errors,
            io_permanent_errors: io.permanent_errors,
            checksum_failures: io.checksum_failures,
            quarantined_pages: io.quarantined_pages,
            pages_scrubbed: io.pages_scrubbed,
            scrub_sweeps: self.scrub_sweeps.load(Ordering::Relaxed),
        }
    }

    /// The durable job log, when configured.
    pub fn wal(&self) -> Option<&JobWal> {
        self.wal.as_ref()
    }

    /// Jobs re-queued with resume-from-checkpoint at this start.
    pub fn resumed_jobs(&self) -> u64 {
        self.resumed_jobs.load(Ordering::Relaxed)
    }

    // ---------------------------------------------------- internals --

    fn worker_loop(&self) {
        loop {
            let id = {
                let mut inner = self.inner.lock().unwrap();
                loop {
                    if inner.shutdown {
                        return;
                    }
                    if let Some(id) = self.pick_and_admit(&mut inner) {
                        break id;
                    }
                    inner = self.cv.wait(inner).unwrap();
                }
            };
            self.run_one(id);
        }
    }

    /// Pick the best runnable job: priority desc, then submission order,
    /// skipping (backfilling past) jobs that exceed the current
    /// admission headroom. Reserves the winner's footprint and flips it
    /// to `Running`.
    fn pick_and_admit(&self, inner: &mut Inner) -> Option<u64> {
        let mut order: Vec<u64> = inner.queue.clone();
        order.sort_by_key(|id| {
            let j = &inner.jobs[id];
            (std::cmp::Reverse(j.status.priority), j.seq)
        });
        for id in order {
            let cost = inner.jobs[&id].cost;
            match self.admission.try_admit(cost) {
                AdmissionDecision::Admitted => {
                    inner.queue.retain(|&q| q != id);
                    let j = inner.jobs.get_mut(&id).unwrap();
                    j.status.state = JobState::Running;
                    return Some(id);
                }
                AdmissionDecision::Deferred => continue,
                AdmissionDecision::Rejected => {
                    // unreachable with a static budget (submit pre-rejects),
                    // but terminal-ize defensively rather than spin
                    inner.queue.retain(|&q| q != id);
                    let j = inner.jobs.get_mut(&id).unwrap();
                    j.status.state = JobState::Rejected;
                    j.status.error = Some(format!(
                        "admission: footprint {cost} B exceeds budget {} B",
                        self.admission.budget()
                    ));
                }
            }
        }
        None
    }

    fn run_one(&self, id: u64) {
        let (req, spec, cancel, cost, resume) = {
            let inner = self.inner.lock().unwrap();
            let j = match inner.jobs.get(&id) {
                Some(j) => j,
                None => return,
            };
            (j.req.clone(), j.spec.clone(), j.cancel.clone(), j.cost, j.resume)
        };
        if let Some(w) = &self.wal {
            w.record_state(id, "running", None);
        }
        let t0 = Instant::now();
        // a panicking job must not take the executor thread down with it
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.execute(id, &req, &spec, cancel.clone(), resume)
        }));
        let wall = t0.elapsed();
        self.admission.release(cost);
        let draining = self.draining.load(Ordering::Relaxed);
        let mut wal_state: Option<&'static str> = None;
        let mut wal_error: Option<String> = None;
        let mut wal_ckpt: Option<u64> = None;
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(j) = inner.jobs.get_mut(&id) {
                j.status.wall = wall;
                j.status.finish_seq = self.next_finish.fetch_add(1, Ordering::Relaxed) + 1;
                match result {
                    Ok(Ok((summary, report, io))) => {
                        if let Some(r) = &report {
                            j.status.rounds = r.rounds;
                            j.status.steals = r.engine.steals;
                            j.status.busy_ratio = r.engine.busy_ratio();
                            j.status.combined_msgs = r.engine.combined_msgs;
                            j.status.peak_msg_bytes = r.engine.peak_msg_bytes;
                            j.status.engine = r.engine.clone();
                            if r.engine.checkpoints > 0 {
                                wal_ckpt = Some(r.rounds);
                            }
                        }
                        j.status.io = io;
                        j.status.summary = Some(summary);
                        if cancel.load(Ordering::Relaxed) {
                            j.status.state = JobState::Cancelled;
                            if draining {
                                // graceful shutdown: resumable, not dead
                                j.status.error = Some(
                                    "interrupted by shutdown; resumes on restart".to_string(),
                                );
                                wal_state = Some("interrupted");
                            } else {
                                j.status.error =
                                    Some("cancelled at a round boundary".to_string());
                                wal_state = Some("cancelled");
                            }
                        } else {
                            j.status.state = JobState::Done;
                            wal_state = Some("done");
                        }
                    }
                    Ok(Err(e)) => {
                        j.status.state = JobState::Failed;
                        j.status.error = Some(format!("{e:#}"));
                        wal_state = Some("failed");
                        wal_error = j.status.error.clone();
                    }
                    Err(payload) => {
                        // surface the panic message, not just the fact
                        let msg = if let Some(s) = payload.downcast_ref::<&str>() {
                            (*s).to_string()
                        } else if let Some(s) = payload.downcast_ref::<String>() {
                            s.clone()
                        } else {
                            "non-string panic payload".to_string()
                        };
                        j.status.state = JobState::Failed;
                        j.status.error = Some(format!("job panicked: {msg}"));
                        wal_state = Some("failed");
                        wal_error = j.status.error.clone();
                    }
                }
            }
        }
        if let Some(w) = &self.wal {
            if let Some(round) = wal_ckpt {
                w.record_checkpoint(id, round);
            }
            if let Some(state) = wal_state {
                w.record_state(id, state, wal_error.as_deref());
            }
        }
        self.cv.notify_all();
    }

    fn execute(
        &self,
        id: u64,
        req: &JobRequest,
        spec: &AlgSpec,
        cancel: Arc<AtomicBool>,
        resume: bool,
    ) -> crate::Result<(String, Option<crate::engine::RunReport>, IoStatsSnapshot)> {
        let shared = self.registry.open(&req.graph)?;
        let jg = JobGraph::new(shared);
        let mut rc = RunConfig {
            cache_mb: self.cfg.cache_mb,
            io_threads: self.cfg.io_threads,
            io_delay_us: self.cfg.io_delay_us,
            max_run_pages: self.cfg.max_run_pages,
            workers: self.cfg.default_workers,
            ..Default::default()
        };
        for (k, v) in &req.overrides {
            rc.set(k, v)?;
        }
        rc.cancel = Some(cancel);
        // durable services park per-job checkpoints next to the WAL;
        // an explicit checkpoint_path override wins
        if rc.checkpoint_path.is_none() && (rc.checkpoint_every > 0 || resume) {
            if let Some(dir) = &self.cfg.wal_dir {
                rc.checkpoint_path = Some(dir.join(format!("job-{id}.ckpt")));
            }
        }
        rc.resume = rc.resume || resume;
        let out = run_alg(&jg, spec, &rc);
        let io = jg.job_stats().snapshot();
        // an engine-recorded failure (e.g. a permanent I/O error) is a
        // clean per-job failure, never a wedge or a panic
        if let Some(f) = out.report.as_ref().and_then(|r| r.failure.clone()) {
            anyhow::bail!("{f}");
        }
        Ok((out.summary, out.report, io))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn build(tag: &str) -> PathBuf {
        let base = std::env::temp_dir()
            .join(format!("graphyti-exec-{}-{tag}", std::process::id()));
        let edges = gen::rmat(8, 1500, 17);
        let mut b = GraphBuilder::new(256, true);
        b.add_edges(&edges);
        b.build_files(&base).unwrap();
        base
    }

    fn cleanup(base: &PathBuf) {
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn submit_run_and_report() {
        let base = build("basic");
        let svc = GraphService::start(ServiceConfig {
            cache_mb: 1,
            exec_threads: 2,
            ..Default::default()
        });
        let id = svc.submit(JobRequest::new(base.clone(), "wcc")).unwrap();
        let st = svc.wait(id, Duration::from_secs(60)).expect("known job");
        assert_eq!(st.state, JobState::Done, "{st:?}");
        assert!(st.summary.as_deref().unwrap_or("").starts_with("wcc:"), "{st:?}");
        assert!(st.io.read_requests > 0, "SEM job must do I/O: {st:?}");
        assert!(st.rounds > 0);
        assert_eq!(svc.admission().in_use(), 0, "footprint released");
        svc.shutdown();
        cleanup(&base);
    }

    #[test]
    fn bad_submissions_fail_fast() {
        let base = build("badsub");
        let svc = GraphService::start(ServiceConfig::default());
        assert!(svc.submit(JobRequest::new(base.clone(), "no-such-alg")).is_err());
        assert!(svc
            .submit(JobRequest::new("/nonexistent/image", "pagerank"))
            .is_err());
        // unknown and substrate-level config overrides are rejected at
        // submit time, not when the job eventually runs
        let mut bad_cfg = JobRequest::new(base.clone(), "pagerank");
        bad_cfg.overrides.push(("bogus_key".into(), "1".into()));
        let e = svc.submit(bad_cfg).unwrap_err();
        assert!(format!("{e:#}").contains("bogus_key"), "{e:#}");
        let mut substrate = JobRequest::new(base.clone(), "pagerank");
        substrate.overrides.push(("cache_mb".into(), "512".into()));
        let e = svc.submit(substrate).unwrap_err();
        assert!(format!("{e:#}").contains("fixed at service start"), "{e:#}");
        // valid per-job overrides still work
        let mut ok = JobRequest::new(base.clone(), "pagerank");
        ok.overrides.push(("workers".into(), "1".into()));
        let id = svc.submit(ok).unwrap();
        let st = svc.wait(id, Duration::from_secs(60)).unwrap();
        assert_eq!(st.state, JobState::Done, "{st:?}");
        svc.shutdown();
        cleanup(&base);
    }

    #[test]
    fn deadline_fails_exactly_the_overrunning_job() {
        let base = build("deadline");
        let svc = GraphService::start(ServiceConfig {
            cache_mb: 1,
            exec_threads: 2,
            ..Default::default()
        });
        // negative threshold => never converges; only the deadline stops it
        let mut runaway = JobRequest::new(base.clone(), "pagerank");
        runaway.overrides.push(("threshold".into(), "-1".into()));
        runaway.overrides.push(("timeout_ms".into(), "300".into()));
        let runaway_id = svc.submit(runaway).unwrap();
        let ok_id = svc.submit(JobRequest::new(base.clone(), "wcc")).unwrap();
        let r = svc.wait(runaway_id, Duration::from_secs(120)).unwrap();
        assert_eq!(r.state, JobState::Failed, "{r:?}");
        assert!(
            r.error.as_deref().unwrap_or("").contains("deadline exceeded"),
            "{r:?}"
        );
        let ok = svc.wait(ok_id, Duration::from_secs(120)).unwrap();
        assert_eq!(ok.state, JobState::Done, "co-tenant unaffected: {ok:?}");
        svc.shutdown();
        cleanup(&base);
    }

    #[test]
    fn background_scrubber_sweeps_open_images() {
        let base = build("scrub");
        let svc = GraphService::start(ServiceConfig {
            cache_mb: 1,
            scrub_every_secs: 1,
            scrub_rate_mb: 0, // unthrottled: the image is tiny
            ..Default::default()
        });
        // open the image by running a job against it
        let id = svc.submit(JobRequest::new(base.clone(), "degree")).unwrap();
        let st = svc.wait(id, Duration::from_secs(60)).unwrap();
        assert_eq!(st.state, JobState::Done, "{st:?}");
        let t0 = Instant::now();
        loop {
            let h = svc.health();
            if h.scrub_sweeps >= 1 {
                assert!(h.pages_scrubbed > 0, "{h:?}");
                assert_eq!(h.checksum_failures, 0, "clean image: {h:?}");
                break;
            }
            assert!(t0.elapsed() < Duration::from_secs(30), "no sweep: {h:?}");
            std::thread::sleep(Duration::from_millis(50));
        }
        svc.shutdown();
        cleanup(&base);
    }

    #[test]
    fn priority_order_with_single_executor() {
        let base = build("prio");
        let svc = GraphService::start(ServiceConfig {
            cache_mb: 1,
            exec_threads: 1,
            ..Default::default()
        });
        // blocker: negative threshold => residual push never converges,
        // so it runs until cancelled — deterministic occupancy
        let mut blocker = JobRequest::new(base.clone(), "pagerank");
        blocker.overrides.push(("threshold".into(), "-1".into()));
        let blocker_id = svc.submit(blocker).unwrap();
        // queue three more while the single executor is busy
        let mut lo = JobRequest::new(base.clone(), "wcc");
        lo.priority = 1;
        let mut hi = JobRequest::new(base.clone(), "bfs");
        hi.priority = 9;
        let mut mid = JobRequest::new(base.clone(), "degree");
        mid.priority = 5;
        let lo_id = svc.submit(lo).unwrap();
        let hi_id = svc.submit(hi).unwrap();
        let mid_id = svc.submit(mid).unwrap();
        assert!(svc.cancel(blocker_id));
        let b = svc.wait(blocker_id, Duration::from_secs(120)).unwrap();
        assert_eq!(b.state, JobState::Cancelled, "{b:?}");
        let lo = svc.wait(lo_id, Duration::from_secs(120)).unwrap();
        let hi = svc.wait(hi_id, Duration::from_secs(120)).unwrap();
        let mid = svc.wait(mid_id, Duration::from_secs(120)).unwrap();
        assert_eq!(lo.state, JobState::Done);
        assert_eq!(hi.state, JobState::Done);
        assert_eq!(mid.state, JobState::Done);
        assert!(
            hi.finish_seq < mid.finish_seq && mid.finish_seq < lo.finish_seq,
            "priority order violated: hi={} mid={} lo={}",
            hi.finish_seq,
            mid.finish_seq,
            lo.finish_seq
        );
        svc.shutdown();
        cleanup(&base);
    }
}
