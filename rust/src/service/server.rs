//! TCP front end: a JSON-lines server over [`GraphService`] plus the
//! one-shot client used by the CLI (`graphyti submit` / `status`).
//!
//! One thread per connection; each request line is dispatched against
//! the shared service and answered with one response line. The
//! `shutdown` op drains the service (cancelling running jobs
//! cooperatively) and stops the accept loop.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Context;

use crate::service::exec::GraphService;
use crate::service::protocol::{
    err_obj, health_to_json, job_request_from_json, ok_obj, snapshot_to_json, status_to_json, Json,
};

/// A running JSON-lines server bound to a local address.
pub struct ServiceServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServiceServer {
    /// Bind `bind_addr` (e.g. `"127.0.0.1:7171"`, port 0 for ephemeral)
    /// and start accepting connections against `svc`.
    pub fn start(svc: Arc<GraphService>, bind_addr: &str) -> crate::Result<ServiceServer> {
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("bind service address {bind_addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("gy-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let svc = svc.clone();
                    let stop = stop2.clone();
                    let _ = std::thread::Builder::new()
                        .name("gy-conn".to_string())
                        .spawn(move || {
                            let _ = handle_conn(&svc, stream, &stop, addr);
                        });
                }
            })
            .expect("spawn accept thread");
        Ok(ServiceServer { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the server stops (via the `shutdown` op or
    /// [`Self::stop`]).
    pub fn wait(mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }

    /// Stop the accept loop (idempotent). Does not shut the service
    /// down — callers own that.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        // poke the blocking accept so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for ServiceServer {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    svc: &Arc<GraphService>,
    stream: TcpStream,
    stop: &AtomicBool,
    addr: SocketAddr,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let (resp, shutdown) = dispatch(svc, line.trim());
        writeln!(writer, "{}", resp.encode())?;
        writer.flush()?;
        if shutdown {
            stop.store(true, Ordering::Release);
            // graceful: running jobs drain to a round boundary (flushing
            // a final checkpoint when enabled) and are stamped
            // resumable in the WAL, bounded by the drain deadline
            svc.shutdown_graceful(Duration::from_secs(30));
            // poke the accept loop awake so it exits
            let _ = TcpStream::connect(addr);
            break;
        }
    }
    Ok(())
}

/// Dispatch one request line. Returns the response and whether the
/// server should shut down.
pub fn dispatch(svc: &Arc<GraphService>, line: &str) -> (Json, bool) {
    match dispatch_inner(svc, line) {
        Ok(out) => out,
        Err(e) => (err_obj(&format!("{e:#}")), false),
    }
}

fn job_id(req: &Json) -> crate::Result<u64> {
    req.get("job")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing integer field 'job'"))
}

fn dispatch_inner(svc: &Arc<GraphService>, line: &str) -> crate::Result<(Json, bool)> {
    let req = Json::parse(line)?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing string field 'op'"))?;
    Ok(match op {
        "submit" => {
            let jr = job_request_from_json(&req)?;
            let id = svc.submit(jr)?;
            let st = svc.status(id).expect("submitted job must have a status");
            (
                ok_obj(vec![
                    ("job", Json::u(id)),
                    ("state", Json::s(st.state.as_str())),
                    ("state_bytes", Json::u(st.state_bytes)),
                ]),
                false,
            )
        }
        "status" => {
            let id = job_id(&req)?;
            match svc.status(id) {
                Some(st) => (ok_obj(vec![("job", status_to_json(&st))]), false),
                None => (err_obj(&format!("unknown job {id}")), false),
            }
        }
        "wait" => {
            let id = job_id(&req)?;
            let timeout_ms =
                req.get("timeout_ms").and_then(Json::as_u64).unwrap_or(600_000);
            match svc.wait(id, Duration::from_millis(timeout_ms)) {
                Some(st) => (ok_obj(vec![("job", status_to_json(&st))]), false),
                None => (err_obj(&format!("unknown job {id}")), false),
            }
        }
        "list" => {
            let jobs: Vec<Json> = svc.list().iter().map(status_to_json).collect();
            (ok_obj(vec![("jobs", Json::Arr(jobs))]), false)
        }
        "cancel" => {
            let id = job_id(&req)?;
            (ok_obj(vec![("cancelled", Json::b(svc.cancel(id)))]), false)
        }
        "stats" => {
            let counts = svc.job_counts();
            let cache = svc.registry().cache();
            (
                ok_obj(vec![
                    ("io", snapshot_to_json(&svc.substrate_stats())),
                    (
                        "cache",
                        Json::obj(vec![
                            ("resident_pages", Json::u(cache.resident_pages())),
                            ("capacity_pages", Json::u(cache.capacity_pages() as u64)),
                        ]),
                    ),
                    (
                        "admission",
                        Json::obj(vec![
                            ("budget_bytes", Json::u(svc.admission().budget())),
                            ("in_use_bytes", Json::u(svc.admission().in_use())),
                            ("peak_bytes", Json::u(svc.admission().peak())),
                        ]),
                    ),
                    ("graphs", Json::u(svc.registry().num_graphs() as u64)),
                    (
                        "jobs",
                        Json::obj(vec![
                            ("queued", Json::u(counts.queued as u64)),
                            ("running", Json::u(counts.running as u64)),
                            ("done", Json::u(counts.done as u64)),
                            ("failed", Json::u(counts.failed as u64)),
                            ("cancelled", Json::u(counts.cancelled as u64)),
                            ("rejected", Json::u(counts.rejected as u64)),
                        ]),
                    ),
                ]),
                false,
            )
        }
        "metrics" => {
            let m = svc.metrics();
            match req.get("format").and_then(Json::as_str) {
                // Prometheus-style exposition, shipped as one JSON
                // string field (the transport stays JSON-lines)
                Some("text") => (ok_obj(vec![("text", Json::s(m.to_prometheus("graphyti")))]), false),
                _ => (ok_obj(vec![("metrics", m.to_json())]), false),
            }
        }
        "health" => (ok_obj(vec![("health", health_to_json(&svc.health()))]), false),
        "shutdown" => (ok_obj(vec![]), true),
        other => (err_obj(&format!("unknown op '{other}'")), false),
    })
}

/// One-shot client: connect, send one request line, read one response
/// line. `timeout` bounds the read (server-side `wait` ops should pass
/// a shorter `timeout_ms`).
pub fn call(addr: &str, request: &Json, timeout: Duration) -> crate::Result<Json> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connect to graphyti service at {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    let mut writer = stream.try_clone()?;
    writeln!(writer, "{}", request.encode())?;
    writer.flush()?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .with_context(|| format!("read response from {addr}"))?;
    anyhow::ensure!(!line.trim().is_empty(), "empty response from service at {addr}");
    Json::parse(line.trim())
}
