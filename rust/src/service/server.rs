//! TCP front end: a JSON-lines server over [`GraphService`] plus the
//! one-shot client used by the CLI (`graphyti submit` / `status`).
//!
//! One thread per connection; each request line is dispatched against
//! the shared service and answered with one response line. The
//! `shutdown` op drains the service (cancelling running jobs
//! cooperatively) and stops the accept loop.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Context;

use crate::service::exec::GraphService;
use crate::service::protocol::{
    err_obj, health_to_json, job_request_from_json, ok_obj, snapshot_to_json, status_to_json, Json,
};

/// A running JSON-lines server bound to a local address.
pub struct ServiceServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl ServiceServer {
    /// Bind `bind_addr` (e.g. `"127.0.0.1:7171"`, port 0 for ephemeral)
    /// and start accepting connections against `svc`.
    pub fn start(svc: Arc<GraphService>, bind_addr: &str) -> crate::Result<ServiceServer> {
        let listener = TcpListener::bind(bind_addr)
            .with_context(|| format!("bind service address {bind_addr}"))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let accept_thread = std::thread::Builder::new()
            .name("gy-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if stop2.load(Ordering::Acquire) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let svc = svc.clone();
                    let stop = stop2.clone();
                    let _ = std::thread::Builder::new()
                        .name("gy-conn".to_string())
                        .spawn(move || {
                            let _ = handle_conn(&svc, stream, &stop, addr);
                        });
                }
            })
            .expect("spawn accept thread");
        Ok(ServiceServer { addr, stop, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the server stops (via the `shutdown` op or
    /// [`Self::stop`]).
    pub fn wait(mut self) {
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }

    /// Stop the accept loop (idempotent). Does not shut the service
    /// down — callers own that.
    pub fn stop(&self) {
        self.stop.store(true, Ordering::Release);
        // poke the blocking accept so the loop observes the flag
        let _ = TcpStream::connect(self.addr);
    }
}

impl Drop for ServiceServer {
    fn drop(&mut self) {
        self.stop();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Longest request line the server will buffer. Every legitimate
/// request is well under this; an unbounded `read_line` would let one
/// newline-free connection grow the buffer without limit.
pub const MAX_REQUEST_LINE: usize = 1 << 20;

enum LineRead {
    /// Connection closed cleanly.
    Eof,
    /// One complete line (newline stripped) in the buffer.
    Line,
    /// Line exceeded the cap; the remainder was drained to its newline
    /// (or EOF) so the stream is re-synchronized for the next request.
    Oversized,
}

/// Read one newline-terminated line of at most `max` bytes into `buf`.
fn read_bounded_line<R: BufRead>(
    r: &mut R,
    buf: &mut Vec<u8>,
    max: usize,
) -> std::io::Result<LineRead> {
    buf.clear();
    loop {
        let budget = (max + 1).saturating_sub(buf.len()) as u64;
        let n = (&mut *r).take(budget).read_until(b'\n', buf)?;
        if buf.last() == Some(&b'\n') {
            buf.pop();
            if buf.last() == Some(&b'\r') {
                buf.pop();
            }
            return Ok(LineRead::Line);
        }
        if n == 0 {
            // EOF with no newline: a nonempty tail still dispatches
            return Ok(if buf.is_empty() { LineRead::Eof } else { LineRead::Line });
        }
        if buf.len() > max {
            // over the cap: skip ahead to the next newline so one huge
            // request poisons only itself, not the rest of the stream
            loop {
                let available = r.fill_buf()?;
                if available.is_empty() {
                    return Ok(LineRead::Oversized);
                }
                match available.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        r.consume(i + 1);
                        return Ok(LineRead::Oversized);
                    }
                    None => {
                        let len = available.len();
                        r.consume(len);
                    }
                }
            }
        }
    }
}

fn handle_conn(
    svc: &Arc<GraphService>,
    stream: TcpStream,
    stop: &AtomicBool,
    addr: SocketAddr,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf = Vec::with_capacity(1024);
    loop {
        match read_bounded_line(&mut reader, &mut buf, MAX_REQUEST_LINE)? {
            LineRead::Eof => break,
            LineRead::Oversized => {
                // structured refusal, connection stays usable
                let resp =
                    err_obj(&format!("request line exceeds {MAX_REQUEST_LINE} bytes"));
                writeln!(writer, "{}", resp.encode())?;
                writer.flush()?;
                continue;
            }
            LineRead::Line => {}
        }
        // malformed (non-UTF-8 or non-JSON) input falls through to
        // dispatch, which answers with a structured error
        let line = String::from_utf8_lossy(&buf);
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (resp, shutdown) = dispatch(svc, line);
        writeln!(writer, "{}", resp.encode())?;
        writer.flush()?;
        if shutdown {
            stop.store(true, Ordering::Release);
            // graceful: running jobs drain to a round boundary (flushing
            // a final checkpoint when enabled) and are stamped
            // resumable in the WAL, bounded by the drain deadline
            svc.shutdown_graceful(Duration::from_secs(30));
            // poke the accept loop awake so it exits
            let _ = TcpStream::connect(addr);
            break;
        }
    }
    Ok(())
}

/// Dispatch one request line. Returns the response and whether the
/// server should shut down.
pub fn dispatch(svc: &Arc<GraphService>, line: &str) -> (Json, bool) {
    match dispatch_inner(svc, line) {
        Ok(out) => out,
        Err(e) => (err_obj(&format!("{e:#}")), false),
    }
}

fn job_id(req: &Json) -> crate::Result<u64> {
    req.get("job")
        .and_then(Json::as_u64)
        .ok_or_else(|| anyhow::anyhow!("missing integer field 'job'"))
}

fn dispatch_inner(svc: &Arc<GraphService>, line: &str) -> crate::Result<(Json, bool)> {
    let req = Json::parse(line)?;
    let op = req
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing string field 'op'"))?;
    Ok(match op {
        "submit" => {
            let jr = job_request_from_json(&req)?;
            let id = svc.submit(jr)?;
            let st = svc.status(id).expect("submitted job must have a status");
            (
                ok_obj(vec![
                    ("job", Json::u(id)),
                    ("state", Json::s(st.state.as_str())),
                    ("state_bytes", Json::u(st.state_bytes)),
                ]),
                false,
            )
        }
        "status" => {
            let id = job_id(&req)?;
            match svc.status(id) {
                Some(st) => (ok_obj(vec![("job", status_to_json(&st))]), false),
                None => (err_obj(&format!("unknown job {id}")), false),
            }
        }
        "wait" => {
            let id = job_id(&req)?;
            let timeout_ms =
                req.get("timeout_ms").and_then(Json::as_u64).unwrap_or(600_000);
            match svc.wait(id, Duration::from_millis(timeout_ms)) {
                Some(st) => (ok_obj(vec![("job", status_to_json(&st))]), false),
                None => (err_obj(&format!("unknown job {id}")), false),
            }
        }
        "list" => {
            let jobs: Vec<Json> = svc.list().iter().map(status_to_json).collect();
            (ok_obj(vec![("jobs", Json::Arr(jobs))]), false)
        }
        "cancel" => {
            let id = job_id(&req)?;
            (ok_obj(vec![("cancelled", Json::b(svc.cancel(id)))]), false)
        }
        "stats" => {
            let counts = svc.job_counts();
            let cache = svc.registry().cache();
            (
                ok_obj(vec![
                    ("io", snapshot_to_json(&svc.substrate_stats())),
                    (
                        "cache",
                        Json::obj(vec![
                            ("resident_pages", Json::u(cache.resident_pages())),
                            ("capacity_pages", Json::u(cache.capacity_pages() as u64)),
                        ]),
                    ),
                    (
                        "admission",
                        Json::obj(vec![
                            ("budget_bytes", Json::u(svc.admission().budget())),
                            ("in_use_bytes", Json::u(svc.admission().in_use())),
                            ("peak_bytes", Json::u(svc.admission().peak())),
                        ]),
                    ),
                    ("graphs", Json::u(svc.registry().num_graphs() as u64)),
                    (
                        "jobs",
                        Json::obj(vec![
                            ("queued", Json::u(counts.queued as u64)),
                            ("running", Json::u(counts.running as u64)),
                            ("done", Json::u(counts.done as u64)),
                            ("failed", Json::u(counts.failed as u64)),
                            ("cancelled", Json::u(counts.cancelled as u64)),
                            ("rejected", Json::u(counts.rejected as u64)),
                        ]),
                    ),
                ]),
                false,
            )
        }
        "metrics" => {
            let m = svc.metrics();
            match req.get("format").and_then(Json::as_str) {
                // Prometheus-style exposition, shipped as one JSON
                // string field (the transport stays JSON-lines)
                Some("text") => (ok_obj(vec![("text", Json::s(m.to_prometheus("graphyti")))]), false),
                _ => (ok_obj(vec![("metrics", m.to_json())]), false),
            }
        }
        "health" => (ok_obj(vec![("health", health_to_json(&svc.health()))]), false),
        "shutdown" => (ok_obj(vec![]), true),
        other => (err_obj(&format!("unknown op '{other}'")), false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::exec::ServiceConfig;

    fn roundtrip_line(
        writer: &mut TcpStream,
        reader: &mut BufReader<TcpStream>,
        send: &[u8],
    ) -> Json {
        writer.write_all(send).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    }

    #[test]
    fn malformed_and_oversized_requests_get_structured_errors() {
        let svc = GraphService::start(ServiceConfig::default());
        let server = ServiceServer::start(svc.clone(), "127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(server.addr()).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut writer = stream.try_clone().unwrap();
        let mut reader = BufReader::new(stream);

        // not JSON at all => structured error, connection survives
        let j = roundtrip_line(&mut writer, &mut reader, b"this is not json");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert!(j.get("error").and_then(Json::as_str).is_some(), "{j:?}");

        // over the line cap => structured refusal, stream re-syncs
        let huge = vec![b'x'; MAX_REQUEST_LINE + 4096];
        let j = roundtrip_line(&mut writer, &mut reader, &huge);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert!(
            j.get("error").and_then(Json::as_str).unwrap_or("").contains("exceeds"),
            "{j:?}"
        );

        // the very next request on the same connection still works
        let j = roundtrip_line(&mut writer, &mut reader, br#"{"op":"health"}"#);
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(true), "{j:?}");
        assert_eq!(
            j.get("health").and_then(|h| h.get("status")).and_then(Json::as_str),
            Some("ok")
        );

        server.stop();
        svc.shutdown();
    }

    #[test]
    fn bounded_line_reader_edges() {
        use std::io::Cursor;
        let mut buf = Vec::new();
        // exact-cap line is accepted
        let mut r = BufReader::new(Cursor::new([vec![b'a'; 10], b"\n".to_vec()].concat()));
        assert!(matches!(read_bounded_line(&mut r, &mut buf, 10).unwrap(), LineRead::Line));
        assert_eq!(buf.len(), 10);
        // one byte over drains to the newline and reports oversized,
        // leaving the following line intact
        let mut r =
            BufReader::new(Cursor::new([vec![b'a'; 11], b"\nok\n".to_vec()].concat()));
        assert!(matches!(
            read_bounded_line(&mut r, &mut buf, 10).unwrap(),
            LineRead::Oversized
        ));
        assert!(matches!(read_bounded_line(&mut r, &mut buf, 10).unwrap(), LineRead::Line));
        assert_eq!(buf, b"ok");
        assert!(matches!(read_bounded_line(&mut r, &mut buf, 10).unwrap(), LineRead::Eof));
        // CRLF stripped; EOF-without-newline tail still yields the line
        let mut r = BufReader::new(Cursor::new(b"hi\r\nbye".to_vec()));
        assert!(matches!(read_bounded_line(&mut r, &mut buf, 10).unwrap(), LineRead::Line));
        assert_eq!(buf, b"hi");
        assert!(matches!(read_bounded_line(&mut r, &mut buf, 10).unwrap(), LineRead::Line));
        assert_eq!(buf, b"bye");
    }
}

/// One-shot client: connect, send one request line, read one response
/// line. `timeout` bounds the read (server-side `wait` ops should pass
/// a shorter `timeout_ms`).
pub fn call(addr: &str, request: &Json, timeout: Duration) -> crate::Result<Json> {
    let stream = TcpStream::connect(addr)
        .with_context(|| format!("connect to graphyti service at {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    let mut writer = stream.try_clone()?;
    writeln!(writer, "{}", request.encode())?;
    writer.flush()?;
    let mut line = String::new();
    BufReader::new(stream)
        .read_line(&mut line)
        .with_context(|| format!("read response from {addr}"))?;
    anyhow::ensure!(!line.trim().is_empty(), "empty response from service at {addr}");
    Json::parse(line.trim())
}
