//! Admission control — bound concurrent jobs' O(n) memory.
//!
//! SEM's contract is O(n) memory per algorithm and O(m) on disk; a
//! multi-tenant node therefore has a hard resource to protect: the sum
//! of admitted jobs' vertex-state footprints. The controller accounts an
//! estimated footprint per job against a configurable budget:
//!
//! * a job whose footprint alone exceeds the budget is **rejected** at
//!   submit time (it could never run);
//! * a job that fits the budget but not the *remaining* headroom is
//!   **deferred** — it stays queued until running jobs release enough;
//! * otherwise it is **admitted** and its footprint reserved until the
//!   job reaches a terminal state.
//!
//! The shared page cache is budgeted separately (it is sized once at
//! service start); this controller covers only per-job state.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::AlgSpec;

/// Outcome of an admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Reserved: the job may run now. Pair with [`AdmissionController::release`].
    Admitted,
    /// Over the remaining headroom: keep the job queued.
    Deferred,
    /// Over the whole budget: the job can never run at this budget.
    Rejected,
}

/// Budgeted reservation ledger for job vertex-state bytes.
#[derive(Debug)]
pub struct AdmissionController {
    budget: u64,
    in_use: AtomicU64,
    peak: AtomicU64,
}

impl AdmissionController {
    /// New controller with a budget in bytes.
    pub fn new(budget_bytes: u64) -> Self {
        AdmissionController {
            budget: budget_bytes,
            in_use: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    /// Total budget in bytes.
    pub fn budget(&self) -> u64 {
        self.budget
    }

    /// Currently reserved bytes.
    pub fn in_use(&self) -> u64 {
        self.in_use.load(Ordering::Relaxed)
    }

    /// High-water mark of reserved bytes over the controller's lifetime.
    pub fn peak(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Try to reserve `cost` bytes.
    pub fn try_admit(&self, cost: u64) -> AdmissionDecision {
        if cost > self.budget {
            return AdmissionDecision::Rejected;
        }
        let mut cur = self.in_use.load(Ordering::Relaxed);
        loop {
            if cur + cost > self.budget {
                return AdmissionDecision::Deferred;
            }
            match self.in_use.compare_exchange_weak(
                cur,
                cur + cost,
                Ordering::AcqRel,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.peak.fetch_max(cur + cost, Ordering::Relaxed);
                    return AdmissionDecision::Admitted;
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// Release a prior reservation.
    pub fn release(&self, cost: u64) {
        let prev = self.in_use.fetch_sub(cost, Ordering::AcqRel);
        debug_assert!(prev >= cost, "released more than reserved");
    }
}

/// Upper bound on one in-flight fetch slot's buffers (request vector +
/// assembled edge bytes for a batch). Slots are recycled per worker, so
/// a run holds `workers × (fetch_window + 1)` of them at peak.
pub const FETCH_SLOT_BYTES: u64 = 64 * 1024;

/// Estimated in-memory vertex-state footprint of a job, in bytes, for
/// an engine run at `workers` worker threads with `fetch_window` extra
/// edge batches kept in flight per worker.
///
/// Per-vertex constants approximate what each algorithm's program holds
/// (rank/residual floats, level/label words, per-source BC state, …)
/// plus the engine's two activation bitmaps and message headroom. On
/// top of that, algorithms with a declared message combiner run on the
/// dense combiner lanes, whose slabs are a real O(n) allocation **per
/// worker per parity** (`2 × workers × n` message slots plus bitmaps)
/// — at service worker counts that term dominates the program state,
/// so it must be admission-accounted or the budget stops bounding
/// actual memory. These are deliberately round over-estimates:
/// admission control needs a stable upper bound, not an exact census.
pub fn estimate_state_bytes(spec: &AlgSpec, n: u64, workers: u64, fetch_window: u64) -> u64 {
    let per_vertex: u64 = match spec {
        // rank + residual f64s, message slack
        AlgSpec::PageRankPush | AlgSpec::PageRankPull => 32,
        // core value + degree counter + scheduling state
        AlgSpec::Coreness(_) => 24,
        // level per sweep batch + visited marks
        AlgSpec::Diameter { .. } => 24,
        // per-source distance/sigma/delta state dominates
        AlgSpec::Bc { num_sources, .. } => 24 + 16 * (*num_sources as u64).min(64),
        // neighbor-ordinal state + per-vertex counts
        AlgSpec::Triangles(_) => 24,
        // community label + degree sums + modularity accumulators
        AlgSpec::Louvain(_) => 48,
        AlgSpec::Bfs { .. } => 16,
        AlgSpec::Wcc => 16,
        AlgSpec::Sssp { .. } => 24,
        // index-resident only
        AlgSpec::Degree => 16,
        AlgSpec::ScanStat => 24,
    };
    // Combiner-lane transport: message size per slot for the algorithms
    // that declare a combiner (0 = queue-lane algorithms, whose
    // in-flight entries are covered by the per-vertex message slack
    // above). The term is charged by algorithm, not by the job's
    // transport override: a combinable job forced onto `transport=queue`
    // keeps this reservation as message headroom. Queue-lane segment
    // memory is proportional to per-round in-flight traffic, which has
    // no useful a-priori bound short of O(m) — charging that would
    // reject every BC/Louvain job on a dense graph — so the budget is a
    // hard bound for combiner-path jobs and a best-effort estimate for
    // queue-path ones (as it was before combiner lanes existed).
    let msg_bytes: u64 = match spec {
        AlgSpec::PageRankPush | AlgSpec::PageRankPull => 8, // f64 shares
        AlgSpec::Bfs { .. } | AlgSpec::Diameter { .. } => 8, // i64 / u64 lanes
        AlgSpec::Sssp { .. } => 8,                          // u64 distances
        AlgSpec::Wcc => 4,                                  // u32 labels
        AlgSpec::Coreness(_) => 4,                          // u32 counts
        _ => 0,
    };
    // +1 B/slot rounds up the touched + summary bitmaps
    let transport = if msg_bytes == 0 { 0 } else { 2 * workers.max(1) * n * (msg_bytes + 1) };
    // Overlapped fetch pipeline: each worker cycles window+1 slots whose
    // buffers stabilize at roughly one batch of edge data apiece.
    let fetch = workers.max(1) * (fetch_window + 1) * FETCH_SLOT_BYTES;
    n * per_vertex + transport + fetch + n / 4 + 4096
}

/// Extra footprint a job with round-boundary checkpointing enabled
/// ([`crate::engine::EngineConfig::checkpoint_every`]) holds at a cut:
/// the serialized snapshot is staged in one contiguous buffer before the
/// atomic tmp-file write — program O(n) sections plus worst-case folded
/// pending messages (destination + payload per vertex) plus the frontier
/// bitmap. Charged additively on top of [`estimate_state_bytes`] only
/// for jobs that opt in, so checkpoint-off admission costs are
/// byte-identical to before the feature existed.
pub fn estimate_checkpoint_bytes(spec: &AlgSpec, n: u64) -> u64 {
    // per-vertex section bytes the program snapshots (PageRank: three
    // f64 arrays; WCC: one u32 label array; conservative default for
    // anything that opts in later)
    let state: u64 = match spec {
        AlgSpec::PageRankPush | AlgSpec::PageRankPull => 24,
        AlgSpec::Wcc => 4,
        _ => 16,
    };
    // worst-case folded message entry: 4 B destination + payload
    let msg: u64 = match spec {
        AlgSpec::PageRankPush | AlgSpec::PageRankPull => 4 + 8,
        AlgSpec::Wcc => 4 + 4,
        _ => 4 + 8,
    };
    // +1 B/vertex rounds up the frontier bitmap; 4 KiB header slack
    n * (state + msg + 1) + 4096
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_defer_reject() {
        let c = AdmissionController::new(100);
        assert_eq!(c.try_admit(101), AdmissionDecision::Rejected);
        assert_eq!(c.try_admit(60), AdmissionDecision::Admitted);
        assert_eq!(c.in_use(), 60);
        assert_eq!(c.try_admit(50), AdmissionDecision::Deferred);
        assert_eq!(c.try_admit(40), AdmissionDecision::Admitted);
        assert_eq!(c.in_use(), 100);
        c.release(60);
        assert_eq!(c.try_admit(50), AdmissionDecision::Admitted);
        c.release(40);
        c.release(50);
        assert_eq!(c.in_use(), 0);
        assert_eq!(c.peak(), 100);
    }

    #[test]
    fn concurrent_reservations_respect_budget() {
        let c = std::sync::Arc::new(AdmissionController::new(10_000));
        let mut hs = vec![];
        for _ in 0..8 {
            let c = c.clone();
            hs.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    if c.try_admit(1_000) == AdmissionDecision::Admitted {
                        assert!(c.in_use() <= 10_000);
                        c.release(1_000);
                    }
                }
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.in_use(), 0);
        assert!(c.peak() <= 10_000);
    }

    #[test]
    fn estimates_scale_with_n_sources_and_workers() {
        let n = 1 << 20;
        let pr = estimate_state_bytes(&AlgSpec::PageRankPush, n, 2, 0);
        // program state + 2×2×n combiner slots (8 B + bitmap round-up)
        assert!(pr >= (32 + 36) * n && pr < 96 * n, "pr = {pr}");
        // the combiner slabs scale with the worker count; queue-lane
        // algorithms (BC) don't pay the transport term
        let pr8 = estimate_state_bytes(&AlgSpec::PageRankPush, n, 8, 0);
        assert!(pr8 > pr, "more workers ⇒ more lane memory");
        let bc = |num_sources, workers, window| {
            estimate_state_bytes(
                &AlgSpec::Bc {
                    num_sources,
                    variant: crate::algs::bc::BcVariant::MultiSourceAsync,
                },
                n,
                workers,
                window,
            )
        };
        assert!(bc(32, 2, 0) > bc(1, 2, 0), "more sources must cost more");
        // at fetch_window=0 only the serial slot is charged, so the
        // per-worker delta is exactly one slot per extra worker
        assert_eq!(
            bc(1, 8, 0) - bc(1, 2, 0),
            6 * FETCH_SLOT_BYTES,
            "queue-lane algorithms pay no per-worker transport term beyond fetch slots"
        );
        // the in-flight window charges window+1 slots per worker
        assert_eq!(
            bc(1, 2, 4) - bc(1, 2, 0),
            2 * 4 * FETCH_SLOT_BYTES,
            "fetch window must be admission-accounted per worker"
        );
    }

    #[test]
    fn checkpoint_cost_is_additive_and_scales_with_n() {
        let n = 1 << 20;
        // PageRank stages 3×8 B of sections + 12 B of message entry +
        // 1 B of bitmap per vertex; WCC only 4+8+1
        assert_eq!(estimate_checkpoint_bytes(&AlgSpec::PageRankPush, n), 37 * n + 4096);
        assert_eq!(estimate_checkpoint_bytes(&AlgSpec::Wcc, n), 9 * n + 4096);
        assert!(
            estimate_checkpoint_bytes(&AlgSpec::Wcc, 2 * n)
                > estimate_checkpoint_bytes(&AlgSpec::Wcc, n)
        );
        // the base estimate is untouched by the checkpoint feature:
        // exact values are pinned by estimates_scale_with_n_sources_and
        // _workers and the service-mode budget tests
    }
}
