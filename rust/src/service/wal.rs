//! Write-ahead job log: durable job lifecycle for the service tier.
//!
//! The scheduler's queue and job table live in memory, so before this
//! module a process crash forgot every queued job and the fate of every
//! running one. The WAL is an append-only JSON-lines file of lifecycle
//! transitions — each line `{"ck":"<fnv64 hex>","rec":{...}}` carries
//! its own checksum so replay can skip a torn tail (a crash mid-append)
//! without losing the intact prefix. Record kinds:
//!
//! * `submitted` — full job request (graph, alg, variant, num,
//!   priority, overrides) under its assigned id;
//! * `state` — transition to `running` / `done` / `failed` /
//!   `cancelled` / `rejected` / `interrupted` (+ error text);
//! * `checkpoint` — the job published an engine checkpoint at a round;
//! * `snapshot` — compaction record: the whole live job table in one
//!   line (replay replaces its state with it, so the log before the
//!   snapshot is dead weight and compaction can drop it).
//!
//! Appends are a single `write(2)` (they survive a process crash);
//! terminal and `interrupted` transitions additionally `fsync` so an
//! acknowledged outcome survives power loss. When the log outgrows
//! [`JobWal::COMPACT_BYTES`] it is rewritten as one snapshot record via
//! tmp + rename. [`GraphService::start`] replays the log to re-admit
//! queued jobs exactly once and to resume interrupted ones; see
//! ARCHITECTURE.md §"Durability & recovery".
//!
//! [`GraphService::start`]: crate::service::GraphService::start

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Context;

use crate::engine::checkpoint::fnv1a;
use crate::util::json::Json;

/// One job as the WAL knows it — both the replay result handed to the
/// service at start and the unit of the compaction snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct WalJob {
    /// Service job id (replay seeds the id counter past the max).
    pub id: u64,
    /// Graph image path as submitted.
    pub graph: String,
    /// Algorithm name.
    pub alg: String,
    /// Algorithm variant ("" when none).
    pub variant: String,
    /// Numeric argument (sources, iterations, …).
    pub num: u64,
    /// Scheduling priority.
    pub priority: u64,
    /// `key=value` config overrides.
    pub overrides: Vec<(String, String)>,
    /// Last known state: `queued`/`running`/`done`/`failed`/
    /// `cancelled`/`rejected`/`interrupted`.
    pub state: String,
    /// Error text for failed jobs.
    pub error: Option<String>,
    /// Highest engine checkpoint round recorded for this job.
    pub ckpt_round: u64,
}

impl WalJob {
    /// Terminal states need no replay action beyond remembering them.
    pub fn is_terminal(&self) -> bool {
        matches!(self.state.as_str(), "done" | "failed" | "cancelled" | "rejected")
    }

    /// A job that was mid-run when the service stopped: re-queue with
    /// resume-from-checkpoint rather than from scratch. A bare
    /// `running` state means the process died without ceremony; an
    /// explicit `interrupted` record means a graceful shutdown marked
    /// it on the way out — both resume.
    pub fn needs_resume(&self) -> bool {
        matches!(self.state.as_str(), "running" | "interrupted")
    }

    fn to_json(&self) -> Json {
        let overrides = Json::Arr(
            self.overrides
                .iter()
                .map(|(k, v)| Json::Arr(vec![Json::s(k.clone()), Json::s(v.clone())]))
                .collect(),
        );
        let mut pairs = vec![
            ("id", Json::u(self.id)),
            ("graph", Json::s(self.graph.clone())),
            ("alg", Json::s(self.alg.clone())),
            ("variant", Json::s(self.variant.clone())),
            ("num", Json::u(self.num)),
            ("priority", Json::u(self.priority)),
            ("overrides", overrides),
            ("state", Json::s(self.state.clone())),
            ("ckpt_round", Json::u(self.ckpt_round)),
        ];
        if let Some(e) = &self.error {
            pairs.push(("error", Json::s(e.clone())));
        }
        Json::obj(pairs)
    }

    fn from_json(v: &Json) -> Option<WalJob> {
        let overrides = v
            .get("overrides")?
            .as_array()?
            .iter()
            .filter_map(|p| {
                let p = p.as_array()?;
                Some((p.first()?.as_str()?.to_string(), p.get(1)?.as_str()?.to_string()))
            })
            .collect();
        Some(WalJob {
            id: v.get("id")?.as_u64()?,
            graph: v.get("graph")?.as_str()?.to_string(),
            alg: v.get("alg")?.as_str()?.to_string(),
            variant: v.get("variant")?.as_str()?.to_string(),
            num: v.get("num")?.as_u64()?,
            priority: v.get("priority")?.as_u64()?,
            overrides,
            state: v.get("state")?.as_str()?.to_string(),
            error: v.get("error").and_then(|e| e.as_str()).map(str::to_string),
            ckpt_round: v.get("ckpt_round").and_then(|r| r.as_u64()).unwrap_or(0),
        })
    }
}

struct WalInner {
    file: File,
    size: u64,
    table: BTreeMap<u64, WalJob>,
}

/// Append-only, checksummed, self-compacting job log.
pub struct JobWal {
    path: PathBuf,
    inner: Mutex<WalInner>,
    records: AtomicU64,
    replayed: AtomicU64,
    skipped: AtomicU64,
    compactions: AtomicU64,
    compact_bytes: u64,
}

impl JobWal {
    /// Compaction threshold: once the log exceeds this, rewrite it as
    /// one snapshot record.
    pub const COMPACT_BYTES: u64 = 1 << 20;

    /// Open (or create) `dir/jobs.wal`, replay it, and return the WAL
    /// plus the replayed job table in id order. Torn or corrupt lines
    /// are counted and skipped, never fatal.
    pub fn open(dir: &Path) -> crate::Result<(JobWal, Vec<WalJob>)> {
        Self::open_with_threshold(dir, Self::COMPACT_BYTES)
    }

    /// [`JobWal::open`] with an explicit compaction threshold (tests).
    pub fn open_with_threshold(
        dir: &Path,
        compact_bytes: u64,
    ) -> crate::Result<(JobWal, Vec<WalJob>)> {
        std::fs::create_dir_all(dir).with_context(|| format!("create {}", dir.display()))?;
        let path = dir.join("jobs.wal");
        let mut table = BTreeMap::new();
        let mut replayed = 0u64;
        let mut skipped = 0u64;
        if let Ok(text) = std::fs::read_to_string(&path) {
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                match Self::decode_line(line) {
                    Some(rec) => {
                        replayed += 1;
                        Self::apply(&mut table, &rec);
                    }
                    None => skipped += 1,
                }
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open {}", path.display()))?;
        let size = file.metadata().map(|m| m.len()).unwrap_or(0);
        let jobs: Vec<WalJob> = table.values().cloned().collect();
        let wal = JobWal {
            path,
            inner: Mutex::new(WalInner { file, size, table }),
            records: AtomicU64::new(0),
            replayed: AtomicU64::new(replayed),
            skipped: AtomicU64::new(skipped),
            compactions: AtomicU64::new(0),
            compact_bytes,
        };
        Ok((wal, jobs))
    }

    /// Verify one line's checksum and parse its record.
    fn decode_line(line: &str) -> Option<Json> {
        let v = Json::parse(line).ok()?;
        let ck = v.get("ck")?.as_str()?;
        let rec = v.get("rec")?.clone();
        let want = format!("{:016x}", fnv1a(rec.encode().as_bytes()));
        if ck != want {
            return None;
        }
        Some(rec)
    }

    /// Fold one record into the job table.
    fn apply(table: &mut BTreeMap<u64, WalJob>, rec: &Json) {
        match rec.get("kind").and_then(|k| k.as_str()) {
            Some("submitted") => {
                if let Some(job) = WalJob::from_json(rec) {
                    table.insert(job.id, job);
                }
            }
            Some("state") => {
                let (Some(id), Some(state)) = (
                    rec.get("id").and_then(|v| v.as_u64()),
                    rec.get("state").and_then(|v| v.as_str()),
                ) else {
                    return;
                };
                if let Some(job) = table.get_mut(&id) {
                    job.state = state.to_string();
                    job.error =
                        rec.get("error").and_then(|e| e.as_str()).map(str::to_string);
                }
            }
            Some("checkpoint") => {
                let (Some(id), Some(round)) = (
                    rec.get("id").and_then(|v| v.as_u64()),
                    rec.get("round").and_then(|v| v.as_u64()),
                ) else {
                    return;
                };
                if let Some(job) = table.get_mut(&id) {
                    job.ckpt_round = job.ckpt_round.max(round);
                }
            }
            Some("snapshot") => {
                table.clear();
                if let Some(jobs) = rec.get("jobs").and_then(|j| j.as_array()) {
                    for j in jobs {
                        if let Some(job) = WalJob::from_json(j) {
                            table.insert(job.id, job);
                        }
                    }
                }
            }
            _ => {}
        }
    }

    fn encode_line(rec: &Json) -> String {
        let body = rec.encode();
        let ck = format!("{:016x}", fnv1a(body.as_bytes()));
        format!("{{\"ck\":\"{ck}\",\"rec\":{body}}}\n")
    }

    /// Append one record; `sync` forces the line to stable storage.
    fn append(&self, rec: Json, sync: bool) {
        let line = Self::encode_line(&rec);
        let mut inner = self.inner.lock().unwrap();
        // best-effort: a full disk must not take the scheduler down
        if inner.file.write_all(line.as_bytes()).is_ok() {
            inner.size += line.len() as u64;
            self.records.fetch_add(1, Ordering::Relaxed);
            if sync {
                let _ = inner.file.sync_all();
            }
        }
        Self::apply(&mut inner.table, &rec);
        if inner.size > self.compact_bytes {
            self.compact_locked(&mut inner);
        }
    }

    /// Rewrite the log as a single snapshot record (tmp + rename).
    fn compact_locked(&self, inner: &mut WalInner) {
        let jobs = Json::Arr(inner.table.values().map(|j| j.to_json()).collect());
        let rec = Json::obj(vec![("kind", Json::s("snapshot")), ("jobs", jobs)]);
        let line = Self::encode_line(&rec);
        let tmp = self.path.with_extension("wal-tmp");
        let ok = (|| -> std::io::Result<()> {
            let mut f = File::create(&tmp)?;
            f.write_all(line.as_bytes())?;
            f.sync_all()?;
            std::fs::rename(&tmp, &self.path)?;
            // the rename itself is durable only once the parent
            // directory entry is synced
            crate::util::fsync_parent_dir(&self.path);
            Ok(())
        })();
        if ok.is_ok() {
            if let Ok(f) = OpenOptions::new().append(true).open(&self.path) {
                inner.file = f;
                inner.size = line.len() as u64;
                self.compactions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Log a fresh submission (state forced to `queued`).
    pub fn record_submitted(&self, job: &WalJob) {
        let mut job = job.clone();
        job.state = "queued".to_string();
        let mut rec = match job.to_json() {
            Json::Obj(pairs) => pairs,
            _ => unreachable!(),
        };
        rec.insert(0, ("kind".to_string(), Json::s("submitted")));
        self.append(Json::Obj(rec), false);
    }

    /// Log a state transition; terminal and `interrupted` transitions
    /// are fsync'd.
    pub fn record_state(&self, id: u64, state: &str, error: Option<&str>) {
        let sync = matches!(state, "done" | "failed" | "cancelled" | "rejected" | "interrupted");
        let mut pairs = vec![
            ("kind", Json::s("state")),
            ("id", Json::u(id)),
            ("state", Json::s(state)),
        ];
        if let Some(e) = error {
            pairs.push(("error", Json::s(e)));
        }
        self.append(Json::obj(pairs), sync);
    }

    /// Log a published engine checkpoint for a job.
    pub fn record_checkpoint(&self, id: u64, round: u64) {
        self.append(
            Json::obj(vec![
                ("kind", Json::s("checkpoint")),
                ("id", Json::u(id)),
                ("round", Json::u(round)),
            ]),
            false,
        );
    }

    /// Log file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Records appended since open (excludes replayed history).
    pub fn records(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }

    /// Valid records replayed at open.
    pub fn replayed(&self) -> u64 {
        self.replayed.load(Ordering::Relaxed)
    }

    /// Torn or corrupt lines skipped at open.
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }

    /// Compactions performed since open.
    pub fn compactions(&self) -> u64 {
        self.compactions.load(Ordering::Relaxed)
    }

    /// Current log size in bytes.
    pub fn size(&self) -> u64 {
        self.inner.lock().unwrap().size
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("graphyti-wal-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn job(id: u64) -> WalJob {
        WalJob {
            id,
            graph: format!("/tmp/g{id}"),
            alg: "pagerank".to_string(),
            variant: "push".to_string(),
            num: 8,
            priority: 4,
            overrides: vec![("workers".to_string(), "2".to_string())],
            state: "queued".to_string(),
            error: None,
            ckpt_round: 0,
        }
    }

    #[test]
    fn replay_roundtrips_lifecycle() {
        let dir = tmpdir("rt");
        {
            let (wal, jobs) = JobWal::open(&dir).unwrap();
            assert!(jobs.is_empty());
            wal.record_submitted(&job(1));
            wal.record_submitted(&job(2));
            wal.record_state(1, "running", None);
            wal.record_checkpoint(1, 4);
            wal.record_state(2, "running", None);
            wal.record_state(2, "done", None);
            wal.record_state(3, "done", None); // unknown id: ignored
            assert_eq!(wal.records(), 7);
            assert_eq!(wal.skipped(), 0);
        }
        let (wal, jobs) = JobWal::open(&dir).unwrap();
        assert_eq!(wal.replayed(), 7);
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].id, 1);
        assert_eq!(jobs[0].state, "running");
        assert!(jobs[0].needs_resume(), "a job left 'running' resumes");
        assert_eq!(jobs[0].ckpt_round, 4);
        assert_eq!(jobs[0].overrides, vec![("workers".to_string(), "2".to_string())]);
        assert_eq!(jobs[1].id, 2);
        assert_eq!(jobs[1].state, "done");
        assert!(jobs[1].is_terminal());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_skipped_not_fatal() {
        let dir = tmpdir("torn");
        {
            let (wal, _) = JobWal::open(&dir).unwrap();
            wal.record_submitted(&job(1));
            wal.record_state(1, "done", None);
        }
        // simulate a crash mid-append: valid prefix + truncated line
        let path = dir.join("jobs.wal");
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"{\"ck\":\"00ff\",\"rec\":{\"kind\":\"sta").unwrap();
        drop(f);
        let (wal, jobs) = JobWal::open(&dir).unwrap();
        assert_eq!(wal.replayed(), 2);
        assert_eq!(wal.skipped(), 1, "torn tail is counted, not fatal");
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].state, "done");
        // a checksum-valid prefix with a corrupted byte is also skipped
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        let good = JobWal::encode_line(&Json::obj(vec![
            ("kind", Json::s("state")),
            ("id", Json::u(1)),
            ("state", Json::s("failed")),
        ]));
        let bad = good.replace("failed", "fAiled"); // checksum now stale
        f.write_all(bad.as_bytes()).unwrap();
        drop(f);
        let (wal, jobs) = JobWal::open(&dir).unwrap();
        assert_eq!(wal.skipped(), 2);
        assert_eq!(jobs[0].state, "done", "corrupt transition must not apply");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_snapshots_the_table() {
        let dir = tmpdir("compact");
        let (wal, _) = JobWal::open_with_threshold(&dir, 600).unwrap();
        for id in 1..=6 {
            wal.record_submitted(&job(id));
            wal.record_state(id, "done", None);
        }
        assert!(wal.compactions() > 0, "tiny threshold must have compacted");
        assert!(wal.size() <= 4096);
        drop(wal);
        let (wal, jobs) = JobWal::open_with_threshold(&dir, 600).unwrap();
        assert_eq!(jobs.len(), 6, "snapshot preserves the whole table");
        assert!(jobs.iter().all(|j| j.state == "done"));
        assert_eq!(wal.skipped(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
