//! JSON-lines wire protocol for the service.
//!
//! One request per line, one response per line, both JSON objects. The
//! JSON value type itself lives in [`crate::util::json`] (re-exported
//! here) so the coordinator and benchkit can emit JSON without
//! depending on the service layer; this module owns the protocol
//! *shaping* — how job statuses, I/O snapshots and metrics become wire
//! objects.
//!
//! Requests (`"op"` selects the action):
//!
//! ```json
//! {"op":"submit","graph":"/data/twitter","alg":"pagerank","variant":"push",
//!  "num":8,"priority":7,"config":{"workers":4,"threshold":1e-8}}
//! {"op":"status","job":3}
//! {"op":"wait","job":3,"timeout_ms":60000}
//! {"op":"list"}
//! {"op":"cancel","job":3}
//! {"op":"stats"}
//! {"op":"metrics"}
//! {"op":"metrics","format":"text"}
//! {"op":"health"}
//! {"op":"shutdown"}
//! ```
//!
//! Responses always carry `"ok"`; failures add `"error"`. Job objects
//! expose state, summary, priority, the admission footprint and the
//! job's disjointly-attributed I/O counters.

use anyhow::{bail, ensure};

use crate::safs::IoStatsSnapshot;
use crate::service::exec::{Health, JobRequest, JobStatus};
use crate::util::HistSummary;

pub use crate::util::json::Json;

// ------------------------------------------------ protocol shaping --

/// `{"ok":true, ...fields}`.
pub fn ok_obj(fields: Vec<(&str, Json)>) -> Json {
    let mut pairs = vec![("ok", Json::Bool(true))];
    pairs.extend(fields);
    Json::obj(pairs)
}

/// `{"ok":false,"error":msg}`.
pub fn err_obj(msg: &str) -> Json {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::s(msg))])
}

/// Encode a histogram summary (integer stats; quantiles are bucket
/// upper bounds).
pub fn hist_to_json(h: &HistSummary) -> Json {
    Json::obj(vec![
        ("count", Json::u(h.count)),
        ("mean", Json::u(h.mean)),
        ("p50", Json::u(h.p50)),
        ("p99", Json::u(h.p99)),
    ])
}

/// Encode an I/O snapshot (latency summaries included when recorded).
pub fn snapshot_to_json(io: &IoStatsSnapshot) -> Json {
    Json::obj(vec![
        ("read_requests", Json::u(io.read_requests)),
        ("logical_bytes", Json::u(io.logical_bytes)),
        ("bytes_read", Json::u(io.bytes_read)),
        ("physical_reads", Json::u(io.physical_reads)),
        ("cache_hits", Json::u(io.cache_hits)),
        ("cache_misses", Json::u(io.cache_misses)),
        ("merged_requests", Json::u(io.merged_requests)),
        ("thread_waits", Json::u(io.thread_waits)),
        ("evictions", Json::u(io.evictions)),
        ("retries", Json::u(io.retries)),
        ("transient_errors", Json::u(io.transient_errors)),
        ("permanent_errors", Json::u(io.permanent_errors)),
        ("backoff_waits", Json::u(io.backoff_waits)),
        ("backoff_us", Json::u(io.backoff_us)),
        ("checksum_failures", Json::u(io.checksum_failures)),
        ("quarantined_pages", Json::u(io.quarantined_pages)),
        ("pages_scrubbed", Json::u(io.pages_scrubbed)),
        (
            "latency",
            Json::obj(vec![
                ("fetch_us", hist_to_json(&io.latency.fetch)),
                ("wait_us", hist_to_json(&io.latency.wait)),
                ("pread_us", hist_to_json(&io.latency.pread)),
                ("run_pages", hist_to_json(&io.latency.run_pages)),
            ]),
        ),
    ])
}

/// Encode a job status.
pub fn status_to_json(st: &JobStatus) -> Json {
    Json::obj(vec![
        ("job", Json::u(st.id)),
        ("state", Json::s(st.state.as_str())),
        ("graph", Json::s(st.graph.clone())),
        ("alg", Json::s(st.alg.clone())),
        ("variant", Json::s(st.variant.clone())),
        ("priority", Json::u(st.priority as u64)),
        ("state_bytes", Json::u(st.state_bytes)),
        (
            "summary",
            st.summary.clone().map_or(Json::Null, Json::Str),
        ),
        ("error", st.error.clone().map_or(Json::Null, Json::Str)),
        ("rounds", Json::u(st.rounds)),
        ("steals", Json::u(st.steals)),
        ("combined_msgs", Json::u(st.combined_msgs)),
        ("peak_msg_bytes", Json::u(st.peak_msg_bytes)),
        // JSON has no Infinity; an unbounded imbalance encodes as null
        (
            "busy_ratio",
            if st.busy_ratio.is_finite() { Json::f(st.busy_ratio) } else { Json::Null },
        ),
        ("p99_fetch_us", Json::u(st.io.latency.fetch.p99)),
        ("wall_ms", Json::f(st.wall.as_secs_f64() * 1e3)),
        ("finish_seq", Json::u(st.finish_seq)),
        ("io", snapshot_to_json(&st.io)),
    ])
}

/// Encode a service health summary.
pub fn health_to_json(h: &Health) -> Json {
    Json::obj(vec![
        ("status", Json::s(h.status.clone())),
        ("exec_threads", Json::u(h.exec_threads as u64)),
        ("graphs_open", Json::u(h.graphs_open as u64)),
        (
            "jobs",
            Json::obj(vec![
                ("queued", Json::u(h.jobs.queued as u64)),
                ("running", Json::u(h.jobs.running as u64)),
                ("done", Json::u(h.jobs.done as u64)),
                ("failed", Json::u(h.jobs.failed as u64)),
                ("cancelled", Json::u(h.jobs.cancelled as u64)),
                ("rejected", Json::u(h.jobs.rejected as u64)),
            ]),
        ),
        ("wal_enabled", Json::Bool(h.wal_enabled)),
        ("wal_records", Json::u(h.wal_records)),
        ("wal_replayed", Json::u(h.wal_replayed)),
        ("wal_skipped", Json::u(h.wal_skipped)),
        ("resumed_jobs", Json::u(h.resumed_jobs)),
        ("io_transient_errors", Json::u(h.io_transient_errors)),
        ("io_permanent_errors", Json::u(h.io_permanent_errors)),
        ("checksum_failures", Json::u(h.checksum_failures)),
        ("quarantined_pages", Json::u(h.quarantined_pages)),
        ("pages_scrubbed", Json::u(h.pages_scrubbed)),
        ("scrub_sweeps", Json::u(h.scrub_sweeps)),
    ])
}

/// Decode a `submit` request body into a [`JobRequest`].
pub fn job_request_from_json(j: &Json) -> crate::Result<JobRequest> {
    let graph = j
        .get("graph")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("submit: missing string field 'graph'"))?;
    let alg = j
        .get("alg")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("submit: missing string field 'alg'"))?;
    let mut req = JobRequest::new(graph, alg);
    if let Some(v) = j.get("variant") {
        req.variant = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("submit: 'variant' must be a string"))?
            .to_string();
    }
    if let Some(v) = j.get("num") {
        req.num = v
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("submit: 'num' must be a non-negative integer"))?
            as usize;
    }
    if let Some(v) = j.get("priority") {
        let p = v
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("submit: 'priority' must be 0..=9"))?;
        ensure!(p <= 9, "submit: 'priority' must be 0..=9, got {p}");
        req.priority = p as u8;
    }
    if let Some(cfg) = j.get("config") {
        let Json::Obj(pairs) = cfg else {
            bail!("submit: 'config' must be an object");
        };
        for (k, v) in pairs {
            let value = match v {
                Json::Str(s) => s.clone(),
                Json::Num(_) => v.encode(),
                Json::Bool(b) => b.to_string(),
                _ => bail!("submit: config '{k}' must be a scalar"),
            };
            req.overrides.push((k.clone(), value));
        }
    }
    Ok(req)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submit_decoding() {
        let j = Json::parse(
            r#"{"op":"submit","graph":"/tmp/g","alg":"pagerank","variant":"push",
                "num":4,"priority":9,"config":{"workers":2,"threshold":1e-8,"seed":7}}"#,
        )
        .unwrap();
        let req = job_request_from_json(&j).unwrap();
        assert_eq!(req.graph.display().to_string(), "/tmp/g");
        assert_eq!(req.alg, "pagerank");
        assert_eq!(req.variant, "push");
        assert_eq!(req.num, 4);
        assert_eq!(req.priority, 9);
        assert!(req.overrides.contains(&("workers".to_string(), "2".to_string())));
        assert!(req
            .overrides
            .iter()
            .any(|(k, v)| k == "threshold" && v.parse::<f64>().unwrap() == 1e-8));

        // missing fields rejected
        assert!(job_request_from_json(&Json::parse(r#"{"op":"submit"}"#).unwrap()).is_err());
        let bad = Json::parse(r#"{"graph":"/g","alg":"x","priority":12}"#).unwrap();
        assert!(job_request_from_json(&bad).is_err());
    }

    #[test]
    fn snapshot_json_carries_latency() {
        let s = crate::safs::IoStats::new();
        s.fetch_latency_us.record(100);
        s.fetch_latency_us.record(300);
        let j = snapshot_to_json(&s.snapshot());
        let fetch = j.get("latency").and_then(|l| l.get("fetch_us")).unwrap();
        assert_eq!(fetch.get("count").unwrap().as_u64(), Some(2));
        assert_eq!(fetch.get("mean").unwrap().as_u64(), Some(200));
        assert!(fetch.get("p99").unwrap().as_u64().unwrap() >= 300);
    }
}
