//! Graph registry — the shared SEM substrate.
//!
//! The registry owns exactly one [`PageCache`] and one [`IoPool`] for
//! the whole process and opens each on-disk graph image **once**; every
//! job running against the same image shares its `Arc<SemGraph>` and
//! therefore the same cached pages and I/O threads. This is the
//! shared-substrate design the multi-tenant service is built on: the
//! page cache and I/O pool are the scarce resources, and multiplexing
//! many queries over one cached graph image is where SEM beats
//! process-per-query (GraphMP, Sun et al. 2017).
//!
//! Page-key namespacing: the cache keys pages by number only, so each
//! file gets a disjoint key range (`file_seq << 44`) — images up to
//! 64 PiB cannot alias.
//!
//! Per-job attribution: [`JobGraph`] wraps the shared graph with a
//! private [`IoStats`]; every fetch is recorded into both the job's
//! stats and the substrate-wide ones, so concurrent jobs' snapshots are
//! disjoint and sum to the global counters.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::graph::format::{EdgeRequest, GraphIndex, VertexEdges};
use crate::graph::source::{EdgeSource, FetchArena, FetchSlot, SemGraph};
use crate::safs::{IoConfig, IoPool, IoStats, PageCache};
use crate::VertexId;

/// Disjoint page-key namespaces: file *i* keys pages from `i << 44`.
const KEY_SHIFT: u32 = 44;

/// One shared substrate + the set of open graph images.
pub struct GraphRegistry {
    cache: Arc<PageCache>,
    pool: Arc<IoPool>,
    stats: Arc<IoStats>,
    graphs: Mutex<HashMap<PathBuf, Arc<SemGraph>>>,
    /// Monotonic file sequence for cache-key namespaces. Allocated
    /// outside the map lock; abandoned ids (lost open races) just skip
    /// a namespace, which is harmless.
    next_file: AtomicU64,
}

impl GraphRegistry {
    /// Build the substrate: one page cache of `cache_bytes` and one I/O
    /// pool, shared by every graph opened through this registry.
    pub fn new(cache_bytes: usize, io: IoConfig) -> Self {
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(cache_bytes, stats.clone()));
        let pool = Arc::new(IoPool::new(io, stats.clone()));
        GraphRegistry {
            cache,
            pool,
            stats,
            graphs: Mutex::new(HashMap::new()),
            next_file: AtomicU64::new(0),
        }
    }

    /// Open (or reuse) the image at `<base>.gy-idx` / `<base>.gy-adj`.
    /// Identical paths — after canonicalization — share one `SemGraph`.
    /// Either format version (v1 fixed-width or v2 delta+varint) opens
    /// transparently; the image header selects the decode path.
    pub fn open(&self, base: &Path) -> crate::Result<Arc<SemGraph>> {
        // canonicalize through the index file (the base itself usually
        // does not exist as a file); fall back to the raw path so open
        // errors surface from SemGraph::open_shared with context
        let key = std::fs::canonicalize(base.with_extension("gy-idx"))
            .unwrap_or_else(|_| base.to_path_buf());
        if let Some(g) = self.graphs.lock().unwrap().get(&key) {
            return Ok(g.clone());
        }
        // do the expensive part — file reads + O(n) index decode —
        // OUTSIDE the map lock, so a cold open of a huge image never
        // stalls submits or job starts against already-open graphs.
        // Concurrent openers of the same image race benignly: the first
        // insert wins, later ones drop their copy.
        let key_base = (self.next_file.fetch_add(1, Ordering::Relaxed) + 1) << KEY_SHIFT;
        let g = Arc::new(SemGraph::open_shared(
            base,
            self.cache.clone(),
            self.pool.clone(),
            key_base,
        )?);
        let mut graphs = self.graphs.lock().unwrap();
        Ok(graphs.entry(key).or_insert(g).clone())
    }

    /// Substrate-wide I/O stats (aggregates every job on every graph).
    pub fn stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    /// The shared page cache.
    pub fn cache(&self) -> &Arc<PageCache> {
        &self.cache
    }

    /// Number of distinct open graph images.
    pub fn num_graphs(&self) -> usize {
        self.graphs.lock().unwrap().len()
    }

    /// Total O(n) index bytes held in memory across open images — the
    /// resident footprint the registry itself contributes.
    pub fn resident_index_bytes(&self) -> u64 {
        self.graphs.lock().unwrap().values().map(|g| g.resident_bytes()).sum()
    }

    /// Base paths (no `.gy-idx` / `.gy-adj` extension) of every open
    /// image, sorted for deterministic iteration. The background
    /// scrubber sweeps this set; entries registered under a raw path
    /// (failed canonicalization) are returned as-is.
    pub fn open_image_bases(&self) -> Vec<PathBuf> {
        let mut bases: Vec<PathBuf> = self
            .graphs
            .lock()
            .unwrap()
            .keys()
            .map(|k| {
                if k.extension().is_some_and(|e| e == "gy-idx") {
                    k.with_extension("")
                } else {
                    k.clone()
                }
            })
            .collect();
        bases.sort();
        bases
    }
}

/// A job's view of a shared [`SemGraph`]: same data plane, private
/// [`IoStats`]. The engine reads `io_stats()` for its per-run report, so
/// a job's [`crate::engine::RunReport`] only ever contains its own I/O
/// even when many jobs hammer the same cache concurrently.
pub struct JobGraph {
    inner: Arc<SemGraph>,
    stats: Arc<IoStats>,
}

impl JobGraph {
    /// Wrap a shared graph with fresh per-job counters.
    pub fn new(inner: Arc<SemGraph>) -> Self {
        JobGraph { inner, stats: Arc::new(IoStats::new()) }
    }

    /// The job's private stats handle.
    pub fn job_stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    /// The underlying shared graph.
    pub fn shared(&self) -> &Arc<SemGraph> {
        &self.inner
    }
}

impl EdgeSource for JobGraph {
    fn index(&self) -> &GraphIndex {
        self.inner.index()
    }

    fn fetch_batch(&self, reqs: &[(VertexId, EdgeRequest)]) -> crate::Result<Vec<VertexEdges>> {
        self.inner.fetch_batch_tracked(reqs, Some(&self.stats))
    }

    fn fetch_batch_into(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        arena: &mut FetchArena,
    ) -> crate::Result<()> {
        // the zero-copy arena path preserves exact per-job attribution:
        // every counter the batch moves lands in this job's stats too
        self.inner.fetch_batch_tracked_into(reqs, Some(&self.stats), arena)
    }

    fn submit_batch(&self, slot: &mut FetchSlot) -> crate::Result<()> {
        // the overlapped pipeline attributes like the sync path: cache
        // probes and merges at submit, physical I/O as completions land
        self.inner.submit_batch_tracked(slot, Some(&self.stats))
    }

    fn poll_batch(&self, slot: &mut FetchSlot) -> bool {
        self.inner.poll_batch_tracked(slot, Some(&self.stats))
    }

    fn finish_batch(&self, slot: &mut FetchSlot) -> crate::Result<()> {
        self.inner.finish_batch_tracked(slot, Some(&self.stats))
    }

    fn prefetch(&self, reqs: &[(VertexId, EdgeRequest)]) {
        // prefetch I/O is deliberately unattributed: it is speculative
        // and may be consumed by any job sharing the cache
        self.inner.prefetch(reqs);
    }

    fn io_stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    fn resident_bytes(&self) -> u64 {
        self.inner.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn build(tag: &str) -> PathBuf {
        let base = std::env::temp_dir()
            .join(format!("graphyti-registry-{}-{tag}", std::process::id()));
        let edges = gen::rmat(8, 1500, 3);
        let mut b = GraphBuilder::new(256, true);
        b.add_edges(&edges);
        b.build_files(&base).unwrap();
        base
    }

    fn cleanup(base: &PathBuf) {
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn same_path_opens_once() {
        let base = build("dedup");
        let reg = GraphRegistry::new(64 * 4096, IoConfig::default());
        let a = reg.open(&base).unwrap();
        let b = reg.open(&base).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same image must share one SemGraph");
        assert_eq!(reg.num_graphs(), 1);
        assert!(reg.open(Path::new("/nonexistent/graph")).is_err());
        cleanup(&base);
    }

    #[test]
    fn arena_path_attributes_identically_to_owned_path() {
        // two jobs on one shared graph, one using the owned fetch, one
        // the zero-copy arena fetch: with a cache big enough that both
        // see identical hit patterns after warm-up, their attributed
        // counters for the same request set must match exactly
        let base = build("arena-attrib");
        let reg = GraphRegistry::new(4096 * 4096, IoConfig::default());
        let shared = reg.open(&base).unwrap();
        let reqs: Vec<_> = (0..256u32).map(|v| (v, EdgeRequest::Both)).collect();
        // warm the shared cache so both jobs below are pure-hit
        shared.fetch_batch(&reqs).unwrap();
        let owned_job = JobGraph::new(shared.clone());
        let arena_job = JobGraph::new(shared);
        owned_job.fetch_batch(&reqs).unwrap();
        let mut arena = FetchArena::new();
        arena_job.fetch_batch_into(&reqs, &mut arena).unwrap();
        let mut a = owned_job.job_stats().snapshot();
        let mut b = arena_job.job_stats().snapshot();
        // wall-clock latency summaries legitimately differ between the
        // two jobs; the attribution contract is about the counters
        assert_eq!(a.latency.fetch.count, 1);
        assert_eq!(b.latency.fetch.count, 1);
        a.latency = Default::default();
        b.latency = Default::default();
        assert_eq!(a, b, "arena path must attribute exactly like the owned path");
        assert_eq!(a.read_requests, 256);
        assert!(a.cache_hits > 0 && a.cache_misses == 0, "warm run: {a:?}");
    }

    #[test]
    fn job_graphs_attribute_disjointly() {
        let base = build("attrib");
        let reg = GraphRegistry::new(256 * 4096, IoConfig::default());
        let shared = reg.open(&base).unwrap();
        let j1 = JobGraph::new(shared.clone());
        let j2 = JobGraph::new(shared);
        let reqs1: Vec<_> = (0..100u32).map(|v| (v, EdgeRequest::Out)).collect();
        let reqs2: Vec<_> = (100..256u32).map(|v| (v, EdgeRequest::Out)).collect();
        j1.fetch_batch(&reqs1).unwrap();
        j2.fetch_batch(&reqs2).unwrap();
        let s1 = j1.job_stats().snapshot();
        let s2 = j2.job_stats().snapshot();
        let g = reg.stats().snapshot();
        assert_eq!(s1.read_requests, 100);
        assert_eq!(s2.read_requests, 156);
        assert_eq!(s1.read_requests + s2.read_requests, g.read_requests);
        assert_eq!(s1.logical_bytes + s2.logical_bytes, g.logical_bytes);
        assert!(s1.logical_bytes > 0 && s2.logical_bytes > 0);
        cleanup(&base);
    }
}
