//! Service mode — the multi-tenant SEM graph daemon.
//!
//! The library's batch path (`coordinator::jobs`) runs one algorithm at
//! a time against a privately-owned substrate. This module turns the
//! node into a **concurrent multi-tenant service**: one shared page
//! cache and I/O pool, many graphs, many jobs in flight, with the O(n)
//! memory contract enforced across tenants. The pieces:
//!
//! * [`registry::GraphRegistry`] — opens each on-disk image **once**
//!   and shares a single `PageCache`/`IoPool` across all jobs; pages of
//!   different files get disjoint cache-key namespaces.
//! * [`admission::AdmissionController`] — accounts each job's estimated
//!   O(n) vertex-state footprint against a configurable budget; jobs
//!   that do not fit the remaining headroom queue, jobs that could
//!   never fit are rejected.
//! * [`exec::GraphService`] — executor threads draining a priority
//!   queue (highest priority first, FIFO within, backfill past jobs
//!   that do not fit), cooperative cancellation plumbed to engine round
//!   boundaries, and per-job I/O attribution: every job gets a private
//!   [`crate::safs::IoStats`] via [`registry::JobGraph`], so concurrent
//!   jobs' counters are disjoint and sum to the substrate totals.
//! * [`protocol`] / [`server`] — a JSON-lines TCP protocol (no serde
//!   needed) with `submit`, `status`, `wait`, `list`, `cancel`,
//!   `stats`, `metrics`, `health` and `shutdown` ops.
//! * [`wal::JobWal`] — optional write-ahead job log (`--wal-dir`):
//!   every lifecycle transition is appended durably, and a restarted
//!   service replays it to re-admit queued jobs exactly once and
//!   resume interrupted ones from their last engine checkpoint.
//!
//! # Quickstart
//!
//! Generate an image, start the daemon, submit jobs from another shell:
//!
//! ```text
//! $ graphyti generate --kind rmat --scale 16 --out /tmp/rmat16
//! $ graphyti serve --port 7171 --cache-mb 256 --budget-mb 512 --exec-threads 4
//! graphyti service listening on 127.0.0.1:7171
//!
//! # elsewhere:
//! $ graphyti submit pagerank --graph /tmp/rmat16 --priority 7 --wait
//! job 1 done: pagerank(push): top5 [...]  (io: reqs=..., disk=...)
//! $ graphyti submit wcc --graph /tmp/rmat16 &
//! $ graphyti submit triangles --graph /tmp/rmat16 --num 1 &
//! $ graphyti status
//! job  state  prio  alg        wall      reads     summary
//! ...
//! ```
//!
//! Or over the wire, one JSON object per line:
//!
//! ```text
//! {"op":"submit","graph":"/tmp/rmat16","alg":"pagerank","priority":7}
//! {"ok":true,"job":1,"state":"queued","state_bytes":2101248}
//! {"op":"wait","job":1,"timeout_ms":60000}
//! {"ok":true,"job":{"job":1,"state":"done","summary":"pagerank(push): ...","io":{...}}}
//! ```
//!
//! In-process embedding (what the integration tests drive):
//!
//! ```no_run
//! use graphyti::service::{GraphService, JobRequest, ServiceConfig};
//! let svc = GraphService::start(ServiceConfig::default());
//! let id = svc.submit(JobRequest::new("/tmp/rmat16", "pagerank")).unwrap();
//! let done = svc.wait(id, std::time::Duration::from_secs(60)).unwrap();
//! println!("{:?}: {:?}", done.state, done.summary);
//! svc.shutdown();
//! ```

pub mod admission;
pub mod exec;
pub mod protocol;
pub mod registry;
pub mod server;
pub mod wal;

pub use admission::{
    estimate_checkpoint_bytes, estimate_state_bytes, AdmissionController, AdmissionDecision,
};
pub use exec::{GraphService, Health, JobCounts, JobRequest, JobState, JobStatus, ServiceConfig};
pub use registry::{GraphRegistry, JobGraph};
pub use server::{call, dispatch, ServiceServer};
pub use wal::{JobWal, WalJob};
