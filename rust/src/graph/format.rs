//! On-disk graph image format (versions 1 and 2).
//!
//! A graph image is two files (full byte-level spec: `docs/FORMAT.md`):
//!
//! * `<name>.gy-idx` — header + per-vertex index. The index is the O(n)
//!   state SEM keeps in memory: byte offset of the vertex's adjacency
//!   record, its in-degree and out-degree, and (v2 only) the compressed
//!   byte lengths of its two edge sections.
//! * `<name>.gy-adj` — packed adjacency records, O(m), never held in
//!   memory in full. Directed record: `[in-section][out-section]`;
//!   undirected record: one section holding all neighbors (stored as
//!   `out`). Neighbor lists are sorted ascending — the triangle-counting
//!   optimizations (§4.5) rely on this.
//!
//! Per-version section encoding ([`EdgeEncoding`]):
//!
//! * **v1** ([`EdgeEncoding::FixedU32`]): each neighbor is a raw
//!   little-endian `u32`; a section is exactly `4 × degree` bytes.
//! * **v2** ([`EdgeEncoding::DeltaVarint`]): each section is the sorted
//!   list delta-coded and LEB128-varint-packed ([`super::varint`]) —
//!   first neighbor verbatim, then successive gaps. Section byte lengths
//!   become data-dependent, so the v2 index carries them per vertex
//!   (24-byte entries vs v1's 16).
//!
//! All fixed-width integers are little-endian. v1 images keep working
//! unchanged: the header's version field selects the decode path
//! everywhere ([`GraphIndex::byte_range`], [`VertexEdges::decode`]).

use std::fmt;

use anyhow::ensure;

use crate::graph::varint;
use crate::VertexId;

/// Magic bytes at the start of the index file.
pub const MAGIC: &[u8; 8] = b"GRAPHYTI";
/// Format version 1: fixed-width `u32` neighbors.
pub const VERSION_V1: u32 = 1;
/// Format version 2: delta + LEB128-varint neighbor sections.
pub const VERSION_V2: u32 = 2;
/// Header length in bytes (identical for all versions).
pub const HEADER_LEN: usize = 40;
/// Bytes per v1 index entry (offset u64, in_deg u32, out_deg u32).
pub const IDX_ENTRY_LEN_V1: usize = 16;
/// Bytes per v2 index entry (v1 fields + in_bytes u32, out_bytes u32).
pub const IDX_ENTRY_LEN_V2: usize = 24;

/// Magic bytes opening the checksum-footer trailer (`docs/FORMAT.md` §5).
pub const FOOTER_MAGIC: &[u8; 8] = b"GYCRC32C";
/// Trailer length: magic (8) + data_len u64 + npages u32 + table_crc u32.
pub const FOOTER_TRAILER_LEN: usize = 24;
/// Checksum granularity: one crc32c per this many data bytes. Matches
/// the SAFS page size so verify-on-read checks exactly the pages the
/// cache moves.
pub const CHECKSUM_PAGE: usize = 4096;

/// Total footer bytes appended to a file of `data_len` data bytes:
/// one `u32` crc per (possibly partial) 4 KiB page, plus the trailer.
pub fn footer_len(data_len: u64) -> u64 {
    data_len.div_ceil(CHECKSUM_PAGE as u64) * 4 + FOOTER_TRAILER_LEN as u64
}

/// Typed image-format error. Returned (wrapped in [`anyhow::Error`], so
/// `downcast_ref::<FormatError>()` recovers it) by the header/index
/// decoders; callers that care which way an image is invalid — notably
/// version negotiation — match on this instead of parsing messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FormatError {
    /// The first 8 bytes are not [`MAGIC`] — not a graphyti image.
    BadMagic,
    /// The header names a version this build cannot read.
    UnsupportedVersion {
        /// Version field found in the image.
        found: u32,
    },
}

impl fmt::Display for FormatError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FormatError::BadMagic => write!(f, "bad magic: not a graphyti image"),
            FormatError::UnsupportedVersion { found } => write!(
                f,
                "unsupported image version {found} (this build reads \
                 {VERSION_V1} and {VERSION_V2})"
            ),
        }
    }
}

impl std::error::Error for FormatError {}

/// How a vertex's edge sections are encoded on disk; decided by the
/// image version and threaded through every decode call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeEncoding {
    /// v1: raw little-endian `u32` per neighbor.
    FixedU32,
    /// v2: sorted deltas, LEB128 varints ([`super::varint`]).
    DeltaVarint,
}

/// Image header: the first [`HEADER_LEN`] bytes of the `.gy-idx` file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphHeader {
    /// Number of vertices.
    pub num_vertices: u64,
    /// Number of (directed) edges stored; an undirected edge counts twice.
    pub num_edges: u64,
    /// Directed graph?
    pub directed: bool,
    /// Format version ([`VERSION_V1`] or [`VERSION_V2`]).
    pub version: u32,
    /// Both image files carry a per-page crc32c checksum footer
    /// ([`ChecksumFooter`]). Header flag bit 1; legacy images without
    /// it keep opening unchanged (no footer is sought or verified).
    pub checksums: bool,
}

impl GraphHeader {
    /// Edge-section encoding implied by the version.
    #[inline]
    pub fn encoding(&self) -> EdgeEncoding {
        if self.version >= VERSION_V2 {
            EdgeEncoding::DeltaVarint
        } else {
            EdgeEncoding::FixedU32
        }
    }

    /// Index entry size implied by the version.
    #[inline]
    pub fn entry_len(&self) -> usize {
        if self.version >= VERSION_V2 {
            IDX_ENTRY_LEN_V2
        } else {
            IDX_ENTRY_LEN_V1
        }
    }

    /// Serialize to the fixed-size on-disk layout.
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[..8].copy_from_slice(MAGIC);
        out[8..12].copy_from_slice(&self.version.to_le_bytes());
        let flags: u32 = self.directed as u32 | (self.checksums as u32) << 1;
        out[12..16].copy_from_slice(&flags.to_le_bytes());
        out[16..24].copy_from_slice(&self.num_vertices.to_le_bytes());
        out[24..32].copy_from_slice(&self.num_edges.to_le_bytes());
        // bytes 32..40 reserved
        out
    }

    /// Parse and validate a header. Images whose version field is
    /// neither [`VERSION_V1`] nor [`VERSION_V2`] are rejected with
    /// [`FormatError::UnsupportedVersion`] naming the found version.
    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        ensure!(bytes.len() >= HEADER_LEN, "index file too short for header");
        if &bytes[..8] != MAGIC {
            return Err(anyhow::Error::new(FormatError::BadMagic));
        }
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION_V1 && version != VERSION_V2 {
            return Err(anyhow::Error::new(FormatError::UnsupportedVersion {
                found: version,
            }));
        }
        let flags = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
        Ok(GraphHeader {
            num_vertices: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
            num_edges: u64::from_le_bytes(bytes[24..32].try_into().unwrap()),
            directed: flags & 1 != 0,
            checksums: flags & 2 != 0,
            version,
        })
    }
}

// ---------------------------------------------- checksum footer -----

/// Streaming per-page crc32c accumulator: feed data in arbitrary-sized
/// chunks, get one crc per 4 KiB page (final page possibly partial).
/// The streaming image converter uses this to checksum adjacency bytes
/// it writes vertex-at-a-time and never holds in memory at once.
#[derive(Debug, Default)]
pub struct PageCrcAccumulator {
    crcs: Vec<u32>,
    cur: u32,
    filled: usize,
    len: u64,
}

impl PageCrcAccumulator {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Feed the next chunk of data bytes.
    pub fn update(&mut self, mut bytes: &[u8]) {
        use crate::util::crc32c::crc32c_update;
        self.len += bytes.len() as u64;
        while !bytes.is_empty() {
            let room = CHECKSUM_PAGE - self.filled;
            let take = room.min(bytes.len());
            self.cur = crc32c_update(self.cur, &bytes[..take]);
            self.filled += take;
            bytes = &bytes[take..];
            if self.filled == CHECKSUM_PAGE {
                self.crcs.push(self.cur);
                self.cur = 0;
                self.filled = 0;
            }
        }
    }

    /// Flush the trailing partial page and return `(data_len, crcs)`.
    pub fn finish(mut self) -> (u64, Vec<u32>) {
        if self.filled > 0 {
            self.crcs.push(self.cur);
        }
        (self.len, self.crcs)
    }
}

/// Per-page crc32c footer of one image file (`docs/FORMAT.md` §5).
///
/// On disk the footer is appended after the data bytes:
/// `[crc32c u32 × npages][magic 8B][data_len u64][npages u32][table_crc u32]`
/// where `npages = ceil(data_len / 4096)`, each crc covers
/// `min(4096, data_len − page·4096)` data bytes (no padding), and
/// `table_crc` is the crc32c of the table bytes themselves, so a torn
/// or rotted footer is detected rather than trusted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChecksumFooter {
    /// Data bytes covered (the file's length without the footer).
    pub data_len: u64,
    crcs: Vec<u32>,
}

impl ChecksumFooter {
    /// Compute a footer over in-memory data.
    pub fn compute(data: &[u8]) -> Self {
        let mut acc = PageCrcAccumulator::new();
        acc.update(data);
        let (data_len, crcs) = acc.finish();
        ChecksumFooter { data_len, crcs }
    }

    /// Assemble from a finished [`PageCrcAccumulator`].
    pub fn from_parts(data_len: u64, crcs: Vec<u32>) -> Self {
        debug_assert_eq!(crcs.len() as u64, data_len.div_ceil(CHECKSUM_PAGE as u64));
        ChecksumFooter { data_len, crcs }
    }

    /// Number of checksummed pages.
    pub fn npages(&self) -> u64 {
        self.crcs.len() as u64
    }

    /// Stored crc for page `p` (`None` past the end).
    pub fn page_crc(&self, p: u64) -> Option<u32> {
        self.crcs.get(p as usize).copied()
    }

    /// Decompose into `(data_len, per-page crcs)` — the parts
    /// [`crate::safs::PageChecksums`] installs into a [`crate::safs::SemFile`].
    pub fn into_parts(self) -> (u64, Vec<u32>) {
        (self.data_len, self.crcs)
    }

    /// Verify page `p` against `bytes`, which must start at data offset
    /// `p * 4096` and hold at least the page's covered length
    /// (`min(4096, data_len − p·4096)`); surplus bytes are ignored.
    /// Pages past the end fail verification.
    pub fn page_ok(&self, p: u64, bytes: &[u8]) -> bool {
        let Some(want) = self.page_crc(p) else { return false };
        let covered = (self.data_len - p * CHECKSUM_PAGE as u64).min(CHECKSUM_PAGE as u64);
        let covered = covered as usize;
        if bytes.len() < covered {
            return false;
        }
        crate::util::crc32c::crc32c(&bytes[..covered]) == want
    }

    /// Serialize to the on-disk footer bytes (table + trailer).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.crcs.len() * 4 + FOOTER_TRAILER_LEN);
        for &c in &self.crcs {
            out.extend_from_slice(&c.to_le_bytes());
        }
        let table_crc = crate::util::crc32c::crc32c(&out);
        out.extend_from_slice(FOOTER_MAGIC);
        out.extend_from_slice(&self.data_len.to_le_bytes());
        out.extend_from_slice(&(self.crcs.len() as u32).to_le_bytes());
        out.extend_from_slice(&table_crc.to_le_bytes());
        out
    }

    /// Parse and validate the footer of a whole file held in memory
    /// (the `.gy-idx` path). Rejects a missing magic, an inconsistent
    /// page count or file length, and a table whose own crc disagrees.
    pub fn from_bytes(file: &[u8]) -> crate::Result<Self> {
        Self::decode_parts(file.len() as u64, |off, buf| {
            let off = off as usize;
            ensure!(off + buf.len() <= file.len(), "footer read out of bounds");
            buf.copy_from_slice(&file[off..off + buf.len()]);
            Ok(())
        })
    }

    /// Parse and validate the footer of an on-disk file via positioned
    /// reads (the `.gy-adj` path — the data body is never loaded).
    pub fn read_from(f: &std::fs::File, file_len: u64) -> crate::Result<Self> {
        use std::os::unix::fs::FileExt;
        Self::decode_parts(file_len, |off, buf| {
            f.read_exact_at(buf, off)?;
            Ok(())
        })
    }

    fn decode_parts(
        file_len: u64,
        mut read_at: impl FnMut(u64, &mut [u8]) -> crate::Result<()>,
    ) -> crate::Result<Self> {
        ensure!(
            file_len >= FOOTER_TRAILER_LEN as u64,
            "file too short ({file_len} bytes) for a checksum footer"
        );
        let mut trailer = [0u8; FOOTER_TRAILER_LEN];
        read_at(file_len - FOOTER_TRAILER_LEN as u64, &mut trailer)?;
        ensure!(
            &trailer[..8] == FOOTER_MAGIC,
            "checksum footer missing: trailer magic mismatch \
             (image header claims checksums but the file has no footer)"
        );
        let data_len = u64::from_le_bytes(trailer[8..16].try_into().unwrap());
        let npages = u32::from_le_bytes(trailer[16..20].try_into().unwrap()) as u64;
        let table_crc = u32::from_le_bytes(trailer[20..24].try_into().unwrap());
        ensure!(
            npages == data_len.div_ceil(CHECKSUM_PAGE as u64),
            "checksum footer corrupt: {npages} page crcs for {data_len} data bytes"
        );
        ensure!(
            file_len == data_len + footer_len(data_len),
            "checksum footer corrupt: file is {file_len} bytes, \
             footer claims {data_len} data bytes"
        );
        let mut table = vec![0u8; npages as usize * 4];
        read_at(data_len, &mut table)?;
        ensure!(
            crate::util::crc32c::crc32c(&table) == table_crc,
            "checksum footer corrupt: crc table fails its own checksum"
        );
        let crcs = table
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(ChecksumFooter { data_len, crcs })
    }
}

/// In-memory per-vertex index: the O(n) SEM state.
///
/// Kept in struct-of-arrays form. v1: 16 bytes/vertex on disk and in
/// memory. v2: 24 bytes/vertex — the two extra `u32`s are the
/// compressed byte lengths of the vertex's in- and out-sections, which
/// [`Self::byte_range`] needs because varint sections are not
/// degree-computable.
#[derive(Debug, Clone)]
pub struct GraphIndex {
    header: GraphHeader,
    /// Byte offset of each vertex's adjacency record in the adj file.
    offsets: Vec<u64>,
    in_degs: Vec<u32>,
    out_degs: Vec<u32>,
    /// v2 only: compressed byte length of each in-section (empty for v1).
    in_bytes: Vec<u32>,
    /// v2 only: compressed byte length of each out-section (empty for v1).
    out_bytes: Vec<u32>,
}

impl GraphIndex {
    /// Assemble a v1 index (used by the builder and tests).
    ///
    /// Panics if `header.version` is not [`VERSION_V1`] or the column
    /// lengths disagree with `header.num_vertices`.
    pub fn new(
        header: GraphHeader,
        offsets: Vec<u64>,
        in_degs: Vec<u32>,
        out_degs: Vec<u32>,
    ) -> Self {
        assert_eq!(header.version, VERSION_V1, "use new_v2 for v2 indexes");
        assert_eq!(offsets.len() as u64, header.num_vertices);
        assert_eq!(in_degs.len(), offsets.len());
        assert_eq!(out_degs.len(), offsets.len());
        GraphIndex {
            header,
            offsets,
            in_degs,
            out_degs,
            in_bytes: Vec::new(),
            out_bytes: Vec::new(),
        }
    }

    /// Assemble a v2 index: degree columns plus the per-vertex
    /// compressed section lengths the builder measured while packing.
    pub fn new_v2(
        header: GraphHeader,
        offsets: Vec<u64>,
        in_degs: Vec<u32>,
        out_degs: Vec<u32>,
        in_bytes: Vec<u32>,
        out_bytes: Vec<u32>,
    ) -> Self {
        assert_eq!(header.version, VERSION_V2, "use new for v1 indexes");
        assert_eq!(offsets.len() as u64, header.num_vertices);
        assert_eq!(in_degs.len(), offsets.len());
        assert_eq!(out_degs.len(), offsets.len());
        assert_eq!(in_bytes.len(), offsets.len());
        assert_eq!(out_bytes.len(), offsets.len());
        GraphIndex { header, offsets, in_degs, out_degs, in_bytes, out_bytes }
    }

    /// Image header.
    pub fn header(&self) -> &GraphHeader {
        &self.header
    }

    /// Edge-section encoding of this image.
    #[inline]
    pub fn encoding(&self) -> EdgeEncoding {
        self.header.encoding()
    }

    /// Bytes per index entry for this image's version (16 or 24).
    #[inline]
    pub fn entry_len(&self) -> usize {
        self.header.entry_len()
    }

    /// Vertex count.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len()
    }

    /// Stored edge count (undirected edges count twice).
    pub fn num_edges(&self) -> u64 {
        self.header.num_edges
    }

    /// Directed?
    pub fn directed(&self) -> bool {
        self.header.directed
    }

    /// In-degree (0 for undirected images).
    #[inline]
    pub fn in_deg(&self, v: VertexId) -> u32 {
        self.in_degs[v as usize]
    }

    /// Out-degree (== degree for undirected images).
    #[inline]
    pub fn out_deg(&self, v: VertexId) -> u32 {
        self.out_degs[v as usize]
    }

    /// Total degree.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        self.in_degs[v as usize] + self.out_degs[v as usize]
    }

    /// On-disk byte length of a vertex's in-section.
    #[inline]
    fn in_section_len(&self, v: VertexId) -> usize {
        match self.header.encoding() {
            EdgeEncoding::FixedU32 => self.in_degs[v as usize] as usize * 4,
            EdgeEncoding::DeltaVarint => self.in_bytes[v as usize] as usize,
        }
    }

    /// On-disk byte length of a vertex's out-section.
    #[inline]
    fn out_section_len(&self, v: VertexId) -> usize {
        match self.header.encoding() {
            EdgeEncoding::FixedU32 => self.out_degs[v as usize] as usize * 4,
            EdgeEncoding::DeltaVarint => self.out_bytes[v as usize] as usize,
        }
    }

    /// Byte length of a vertex's full adjacency record on disk.
    #[inline]
    pub fn record_len(&self, v: VertexId) -> usize {
        self.in_section_len(v) + self.out_section_len(v)
    }

    /// Byte range in the adj file for the given request — the SEM read
    /// path's translation from "which lists" to "which bytes". For v2
    /// the lengths come from the stored compressed section sizes, so
    /// every request reads exactly the compressed bytes it needs.
    #[inline]
    pub fn byte_range(&self, v: VertexId, req: EdgeRequest) -> (u64, usize) {
        let off = self.offsets[v as usize];
        match req {
            EdgeRequest::None => (off, 0),
            EdgeRequest::In => (off, self.in_section_len(v)),
            EdgeRequest::Out => {
                (off + self.in_section_len(v) as u64, self.out_section_len(v))
            }
            EdgeRequest::Both => (off, self.in_section_len(v) + self.out_section_len(v)),
        }
    }

    /// Serialize header + entries to the `.gy-idx` byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let entry = self.entry_len();
        let mut out = Vec::with_capacity(HEADER_LEN + self.offsets.len() * entry);
        out.extend_from_slice(&self.header.encode());
        for i in 0..self.offsets.len() {
            out.extend_from_slice(&self.offsets[i].to_le_bytes());
            out.extend_from_slice(&self.in_degs[i].to_le_bytes());
            out.extend_from_slice(&self.out_degs[i].to_le_bytes());
            if self.header.version >= VERSION_V2 {
                out.extend_from_slice(&self.in_bytes[i].to_le_bytes());
                out.extend_from_slice(&self.out_bytes[i].to_le_bytes());
            }
        }
        out
    }

    /// Parse a `.gy-idx` byte image (either version; the header's
    /// version field selects the entry layout).
    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let header = GraphHeader::decode(bytes)?;
        let n = header.num_vertices as usize;
        let entry = header.entry_len();
        // checked arithmetic: a corrupt vertex count must yield this
        // clean error, not a wrapped bound that passes and then aborts
        // on a huge allocation
        let need = n
            .checked_mul(entry)
            .and_then(|b| b.checked_add(HEADER_LEN))
            .ok_or_else(|| anyhow::anyhow!("implausible vertex count {n} in header"))?;
        ensure!(
            bytes.len() >= need,
            "index file truncated: {n} vertices need {need} bytes, have {}",
            bytes.len()
        );
        let v2 = header.version >= VERSION_V2;
        let mut offsets = Vec::with_capacity(n);
        let mut in_degs = Vec::with_capacity(n);
        let mut out_degs = Vec::with_capacity(n);
        let mut in_bytes = Vec::with_capacity(if v2 { n } else { 0 });
        let mut out_bytes = Vec::with_capacity(if v2 { n } else { 0 });
        for i in 0..n {
            let e = HEADER_LEN + i * entry;
            offsets.push(u64::from_le_bytes(bytes[e..e + 8].try_into().unwrap()));
            in_degs.push(u32::from_le_bytes(bytes[e + 8..e + 12].try_into().unwrap()));
            out_degs.push(u32::from_le_bytes(bytes[e + 12..e + 16].try_into().unwrap()));
            if v2 {
                in_bytes.push(u32::from_le_bytes(bytes[e + 16..e + 20].try_into().unwrap()));
                out_bytes.push(u32::from_le_bytes(bytes[e + 20..e + 24].try_into().unwrap()));
            }
        }
        Ok(GraphIndex { header, offsets, in_degs, out_degs, in_bytes, out_bytes })
    }
}

/// Which edge lists an algorithm needs for a vertex — the paper's central
/// I/O-minimization lever ("limit superfluous reads", §4.1): PR-push
/// requests only `Out`, PR-pull only `In`, triangle counting `Both`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeRequest {
    /// No edge data (vertex computes on state/messages alone).
    None,
    /// In-edge list only.
    In,
    /// Out-edge list only.
    Out,
    /// Both lists.
    Both,
}

/// Decoded edge data for one vertex, as fetched by the engine.
///
/// The neighbor vectors double as scratch buffers: [`Self::decode_into`]
/// clears and refills them in place, so a caller looping over many
/// records reuses one allocation instead of constructing fresh vectors
/// per vertex. This is the engine's hot path: every batch decodes via
/// `decode_into` over the slots of a per-worker
/// [`crate::graph::source::FetchArena`], whose vector capacities
/// converge to the largest record seen — steady-state decoding
/// allocates nothing. The streaming image converter
/// ([`crate::graph::builder::convert_image`]) uses the same mechanism
/// with a single scratch value. One-off lookups use [`Self::decode`],
/// which performs exactly one exact-capacity allocation per requested
/// list with no varint-decode temporaries.
#[derive(Debug, Clone, Default)]
pub struct VertexEdges {
    /// In-neighbors (empty unless requested; undirected graphs use `out`).
    pub in_neighbors: Vec<VertexId>,
    /// Out-neighbors (or all neighbors for undirected graphs).
    pub out_neighbors: Vec<VertexId>,
}

impl VertexEdges {
    /// Decode a record byte slice (per the request that produced it)
    /// into a fresh value. `enc` must match the image the bytes came
    /// from — [`GraphIndex::encoding`] supplies it.
    pub fn decode(
        bytes: &[u8],
        in_deg: u32,
        out_deg: u32,
        req: EdgeRequest,
        enc: EdgeEncoding,
    ) -> Self {
        let mut out = VertexEdges::default();
        out.decode_into(bytes, in_deg, out_deg, req, enc);
        out
    }

    /// Decode in place, reusing this value's vectors as scratch: both
    /// lists are cleared, then the requested ones refilled. Use this
    /// when looping over many records without keeping them (the
    /// streaming converter does) to amortize the two allocations away.
    pub fn decode_into(
        &mut self,
        bytes: &[u8],
        in_deg: u32,
        out_deg: u32,
        req: EdgeRequest,
        enc: EdgeEncoding,
    ) {
        self.in_neighbors.clear();
        self.out_neighbors.clear();
        match enc {
            EdgeEncoding::FixedU32 => self.decode_fixed(bytes, in_deg, out_deg, req),
            EdgeEncoding::DeltaVarint => self.decode_varint(bytes, in_deg, out_deg, req),
        }
    }

    /// v1 section decode: `4 × degree` raw little-endian words.
    fn decode_fixed(&mut self, bytes: &[u8], in_deg: u32, out_deg: u32, req: EdgeRequest) {
        let word = |b: &[u8], i: usize| {
            VertexId::from_le_bytes(b[i * 4..i * 4 + 4].try_into().unwrap())
        };
        match req {
            EdgeRequest::None => {}
            EdgeRequest::In => {
                debug_assert_eq!(bytes.len(), in_deg as usize * 4);
                self.in_neighbors.extend((0..in_deg as usize).map(|i| word(bytes, i)));
            }
            EdgeRequest::Out => {
                debug_assert_eq!(bytes.len(), out_deg as usize * 4);
                self.out_neighbors.extend((0..out_deg as usize).map(|i| word(bytes, i)));
            }
            EdgeRequest::Both => {
                debug_assert_eq!(bytes.len(), (in_deg + out_deg) as usize * 4);
                let ind = in_deg as usize;
                self.in_neighbors.extend((0..ind).map(|i| word(bytes, i)));
                self.out_neighbors
                    .extend((0..out_deg as usize).map(|i| word(bytes, ind + i)));
            }
        }
    }

    /// v2 section decode: delta+varint streams, `[in][out]` when both
    /// are present. The in-stream's end is found by decoding it (varint
    /// sections are self-delimiting given the count), so `Both` needs no
    /// stored split point.
    fn decode_varint(&mut self, bytes: &[u8], in_deg: u32, out_deg: u32, req: EdgeRequest) {
        let mut pos = 0usize;
        match req {
            EdgeRequest::None => {}
            EdgeRequest::In => {
                varint::decode_deltas(bytes, in_deg as usize, &mut pos, &mut self.in_neighbors);
                debug_assert_eq!(pos, bytes.len());
            }
            EdgeRequest::Out => {
                varint::decode_deltas(bytes, out_deg as usize, &mut pos, &mut self.out_neighbors);
                debug_assert_eq!(pos, bytes.len());
            }
            EdgeRequest::Both => {
                varint::decode_deltas(bytes, in_deg as usize, &mut pos, &mut self.in_neighbors);
                varint::decode_deltas(bytes, out_deg as usize, &mut pos, &mut self.out_neighbors);
                debug_assert_eq!(pos, bytes.len());
            }
        }
    }

    /// All neighbors for an undirected fetch.
    pub fn neighbors(&self) -> &[VertexId] {
        &self.out_neighbors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn header_v1(n: u64, m: u64, directed: bool) -> GraphHeader {
        GraphHeader {
            num_vertices: n,
            num_edges: m,
            directed,
            version: VERSION_V1,
            checksums: false,
        }
    }

    #[test]
    fn header_roundtrip_both_versions() {
        for version in [VERSION_V1, VERSION_V2] {
            for checksums in [false, true] {
                let h = GraphHeader {
                    num_vertices: 42,
                    num_edges: 99,
                    directed: true,
                    version,
                    checksums,
                };
                let enc = h.encode();
                assert_eq!(GraphHeader::decode(&enc).unwrap(), h);
            }
        }
        let h2 = header_v1(0, 0, false);
        assert_eq!(GraphHeader::decode(&h2.encode()).unwrap(), h2);
    }

    #[test]
    fn header_rejects_garbage() {
        assert!(GraphHeader::decode(b"short").is_err());
        let mut bad = header_v1(1, 1, true).encode();
        bad[0] = b'X';
        let err = GraphHeader::decode(&bad).unwrap_err();
        assert_eq!(err.downcast_ref::<FormatError>(), Some(&FormatError::BadMagic));
    }

    #[test]
    fn header_rejects_unknown_version_with_typed_error() {
        let mut badver = header_v1(1, 1, true).encode();
        badver[8] = 99;
        let err = GraphHeader::decode(&badver).unwrap_err();
        assert_eq!(
            err.downcast_ref::<FormatError>(),
            Some(&FormatError::UnsupportedVersion { found: 99 }),
            "error must name the found version: {err:#}"
        );
        assert!(format!("{err}").contains("99"), "message must name the version: {err}");
        // version 0 (pre-versioned garbage) is equally rejected
        let mut zero = header_v1(1, 1, true).encode();
        zero[8..12].copy_from_slice(&0u32.to_le_bytes());
        let err = GraphHeader::decode(&zero).unwrap_err();
        assert_eq!(
            err.downcast_ref::<FormatError>(),
            Some(&FormatError::UnsupportedVersion { found: 0 })
        );
    }

    #[test]
    fn index_roundtrip_and_ranges() {
        let h = header_v1(3, 5, true);
        // v0: in=[..1], out=[..2] at offset 0 => 12 bytes
        // v1: in=0 out=1 at 12; v2: in=1 out=0 at 16
        let idx = GraphIndex::new(h, vec![0, 12, 16], vec![1, 0, 1], vec![2, 1, 0]);
        let enc = idx.encode();
        let dec = GraphIndex::decode(&enc).unwrap();
        assert_eq!(dec.num_vertices(), 3);
        assert_eq!(dec.entry_len(), IDX_ENTRY_LEN_V1);
        assert_eq!(dec.encoding(), EdgeEncoding::FixedU32);
        assert_eq!(dec.in_deg(0), 1);
        assert_eq!(dec.out_deg(0), 2);
        assert_eq!(dec.degree(2), 1);
        assert_eq!(dec.byte_range(0, EdgeRequest::In), (0, 4));
        assert_eq!(dec.byte_range(0, EdgeRequest::Out), (4, 8));
        assert_eq!(dec.byte_range(0, EdgeRequest::Both), (0, 12));
        assert_eq!(dec.byte_range(1, EdgeRequest::Out), (12, 4));
        assert_eq!(dec.byte_range(2, EdgeRequest::In), (16, 4));
        assert_eq!(dec.byte_range(2, EdgeRequest::None), (16, 0));
    }

    #[test]
    fn v2_index_roundtrip_uses_stored_section_bytes() {
        let h = GraphHeader {
            num_vertices: 2,
            num_edges: 4,
            directed: true,
            version: VERSION_V2,
            checksums: false,
        };
        // v0: in-section 3 bytes, out-section 5 bytes at offset 0
        // v1: in-section 0 bytes, out-section 2 bytes at offset 8
        let idx = GraphIndex::new_v2(
            h,
            vec![0, 8],
            vec![2, 0],
            vec![1, 1],
            vec![3, 0],
            vec![5, 2],
        );
        let enc = idx.encode();
        assert_eq!(enc.len(), HEADER_LEN + 2 * IDX_ENTRY_LEN_V2);
        let dec = GraphIndex::decode(&enc).unwrap();
        assert_eq!(dec.encoding(), EdgeEncoding::DeltaVarint);
        assert_eq!(dec.entry_len(), IDX_ENTRY_LEN_V2);
        assert_eq!(dec.byte_range(0, EdgeRequest::In), (0, 3));
        assert_eq!(dec.byte_range(0, EdgeRequest::Out), (3, 5));
        assert_eq!(dec.byte_range(0, EdgeRequest::Both), (0, 8));
        assert_eq!(dec.byte_range(1, EdgeRequest::Out), (8, 2));
        assert_eq!(dec.record_len(0), 8);
        assert_eq!(dec.record_len(1), 2);
    }

    #[test]
    fn index_decode_rejects_implausible_vertex_count() {
        // num_vertices large enough that n * entry_len overflows usize:
        // must come back as a clean error, not a wrap/abort
        let mut bytes = header_v1(0, 0, false).encode().to_vec();
        bytes[16..24].copy_from_slice(&(u64::MAX / 2).to_le_bytes());
        assert!(GraphIndex::decode(&bytes).is_err());
    }

    #[test]
    fn index_decode_rejects_truncation() {
        let h = header_v1(10, 0, false);
        let idx = GraphIndex::new(h, vec![0; 10], vec![0; 10], vec![0; 10]);
        let mut enc = idx.encode();
        enc.truncate(enc.len() - 1);
        assert!(GraphIndex::decode(&enc).is_err());
    }

    #[test]
    fn vertex_edges_decode_both_fixed() {
        let mut bytes = Vec::new();
        for v in [7u32, 9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [1u32, 2, 3] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let enc = EdgeEncoding::FixedU32;
        let ve = VertexEdges::decode(&bytes, 2, 3, EdgeRequest::Both, enc);
        assert_eq!(ve.in_neighbors, vec![7, 9]);
        assert_eq!(ve.out_neighbors, vec![1, 2, 3]);

        let out_only = VertexEdges::decode(&bytes[8..], 2, 3, EdgeRequest::Out, enc);
        assert_eq!(out_only.out_neighbors, vec![1, 2, 3]);
        assert!(out_only.in_neighbors.is_empty());

        let none = VertexEdges::decode(&[], 2, 3, EdgeRequest::None, enc);
        assert!(none.in_neighbors.is_empty() && none.out_neighbors.is_empty());
    }

    #[test]
    fn vertex_edges_decode_both_varint() {
        let ins = vec![7u32, 9];
        let outs = vec![1u32, 2, 300_000];
        let mut bytes = Vec::new();
        varint::encode_deltas(&ins, &mut bytes);
        let in_len = bytes.len();
        varint::encode_deltas(&outs, &mut bytes);
        let enc = EdgeEncoding::DeltaVarint;
        let ve = VertexEdges::decode(&bytes, 2, 3, EdgeRequest::Both, enc);
        assert_eq!(ve.in_neighbors, ins);
        assert_eq!(ve.out_neighbors, outs);

        let in_only = VertexEdges::decode(&bytes[..in_len], 2, 3, EdgeRequest::In, enc);
        assert_eq!(in_only.in_neighbors, ins);
        assert!(in_only.out_neighbors.is_empty());

        let out_only = VertexEdges::decode(&bytes[in_len..], 2, 3, EdgeRequest::Out, enc);
        assert_eq!(out_only.out_neighbors, outs);
        assert!(out_only.in_neighbors.is_empty());
    }

    #[test]
    fn checksum_footer_roundtrip_and_verify() {
        // 2.5 pages of patterned data: full, full, partial
        let data: Vec<u8> = (0..CHECKSUM_PAGE * 5 / 2).map(|i| (i * 37 + 11) as u8).collect();
        let footer = ChecksumFooter::compute(&data);
        assert_eq!(footer.npages(), 3);
        assert_eq!(footer.data_len, data.len() as u64);
        let mut file = data.clone();
        file.extend_from_slice(&footer.encode());
        assert_eq!(file.len() as u64, data.len() as u64 + footer_len(data.len() as u64));
        let dec = ChecksumFooter::from_bytes(&file).unwrap();
        assert_eq!(dec, footer);
        for p in 0..3u64 {
            let s = p as usize * CHECKSUM_PAGE;
            let e = data.len().min(s + CHECKSUM_PAGE);
            assert!(dec.page_ok(p, &data[s..e]), "clean page {p} must verify");
            // a full-page buffer with trailing garbage past the covered
            // length still verifies the partial last page
            let mut padded = data[s..e].to_vec();
            padded.resize(CHECKSUM_PAGE, 0xAB);
            assert!(dec.page_ok(p, &padded));
        }
        assert!(!dec.page_ok(3, &[0u8; CHECKSUM_PAGE]), "page past end must fail");
        // any single flipped bit in any page is detected
        let mut dirty = data.clone();
        dirty[CHECKSUM_PAGE + 100] ^= 0x10;
        assert!(!dec.page_ok(1, &dirty[CHECKSUM_PAGE..2 * CHECKSUM_PAGE]));
        assert!(dec.page_ok(0, &dirty[..CHECKSUM_PAGE]), "other pages unaffected");
    }

    #[test]
    fn checksum_footer_streaming_matches_one_shot() {
        let data: Vec<u8> = (0..10_000usize).map(|i| (i * 131) as u8).collect();
        let mut acc = PageCrcAccumulator::new();
        // feed in awkward chunk sizes straddling page boundaries
        let mut off = 0;
        for step in [1usize, 4095, 4097, 13, 9999].iter().cycle() {
            if off >= data.len() {
                break;
            }
            let end = data.len().min(off + step);
            acc.update(&data[off..end]);
            off = end;
        }
        let (len, crcs) = acc.finish();
        assert_eq!(
            ChecksumFooter::from_parts(len, crcs),
            ChecksumFooter::compute(&data)
        );
    }

    #[test]
    fn checksum_footer_rejects_corruption_of_itself() {
        let data = vec![7u8; 100];
        let footer = ChecksumFooter::compute(&data);
        let mut file = data.clone();
        file.extend_from_slice(&footer.encode());
        // flip a bit inside the crc table: table_crc must catch it
        let mut bad = file.clone();
        bad[data.len()] ^= 1;
        assert!(ChecksumFooter::from_bytes(&bad).is_err());
        // wrong magic
        let mut bad = file.clone();
        let m = file.len() - FOOTER_TRAILER_LEN;
        bad[m] = b'X';
        assert!(ChecksumFooter::from_bytes(&bad).is_err());
        // truncated file (length no longer matches data_len + footer)
        let mut bad = file.clone();
        bad.remove(0);
        assert!(ChecksumFooter::from_bytes(&bad).is_err());
        // empty data: footer is just the trailer and still round-trips
        let empty = ChecksumFooter::compute(&[]);
        assert_eq!(empty.npages(), 0);
        assert_eq!(ChecksumFooter::from_bytes(&empty.encode()).unwrap(), empty);
    }

    #[test]
    fn decode_into_reuses_buffers() {
        let mut bytes = Vec::new();
        varint::encode_deltas(&[4u32, 8, 15], &mut bytes);
        let mut ve = VertexEdges::default();
        ve.decode_into(&bytes, 0, 3, EdgeRequest::Out, EdgeEncoding::DeltaVarint);
        assert_eq!(ve.out_neighbors, vec![4, 8, 15]);
        let cap = ve.out_neighbors.capacity();
        // second decode of a smaller record must not reallocate
        let mut bytes2 = Vec::new();
        varint::encode_deltas(&[16u32, 23], &mut bytes2);
        ve.decode_into(&bytes2, 0, 2, EdgeRequest::Out, EdgeEncoding::DeltaVarint);
        assert_eq!(ve.out_neighbors, vec![16, 23]);
        assert_eq!(ve.out_neighbors.capacity(), cap, "scratch buffer must be reused");
        assert!(ve.in_neighbors.is_empty());
    }
}
