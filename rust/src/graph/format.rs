//! On-disk graph image format.
//!
//! A graph image is two files:
//!
//! * `<name>.gy-idx` — header + per-vertex index. The index is the O(n)
//!   state SEM keeps in memory: 16 bytes per vertex (adjacency byte
//!   offset, in-degree, out-degree).
//! * `<name>.gy-adj` — packed adjacency records, O(m), never held in
//!   memory in full. Directed record: `[in-neighbors u32 × in_deg]
//!   [out-neighbors u32 × out_deg]`; undirected record: `[neighbors u32 ×
//!   deg]` (stored in `out`). Neighbor lists are sorted ascending — the
//!   triangle-counting optimizations (§4.5) rely on this.
//!
//! All integers are little-endian.

use anyhow::{bail, ensure};

use crate::VertexId;

/// Magic bytes at the start of the index file.
pub const MAGIC: &[u8; 8] = b"GRAPHYTI";
/// Format version.
pub const VERSION: u32 = 1;
/// Header length in bytes.
pub const HEADER_LEN: usize = 40;
/// Bytes per index entry.
pub const IDX_ENTRY_LEN: usize = 16;

/// Image header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphHeader {
    /// Number of vertices.
    pub num_vertices: u64,
    /// Number of (directed) edges stored; an undirected edge counts twice.
    pub num_edges: u64,
    /// Directed graph?
    pub directed: bool,
}

impl GraphHeader {
    /// Serialize to the fixed-size on-disk layout.
    pub fn encode(&self) -> [u8; HEADER_LEN] {
        let mut out = [0u8; HEADER_LEN];
        out[..8].copy_from_slice(MAGIC);
        out[8..12].copy_from_slice(&VERSION.to_le_bytes());
        let flags: u32 = self.directed as u32;
        out[12..16].copy_from_slice(&flags.to_le_bytes());
        out[16..24].copy_from_slice(&self.num_vertices.to_le_bytes());
        out[24..32].copy_from_slice(&self.num_edges.to_le_bytes());
        // bytes 32..40 reserved
        out
    }

    /// Parse and validate a header.
    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        ensure!(bytes.len() >= HEADER_LEN, "index file too short for header");
        ensure!(&bytes[..8] == MAGIC, "bad magic: not a graphyti image");
        let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
        if version != VERSION {
            bail!("unsupported image version {version} (expected {VERSION})");
        }
        let flags = u32::from_le_bytes(bytes[12..16].try_into().unwrap());
        Ok(GraphHeader {
            num_vertices: u64::from_le_bytes(bytes[16..24].try_into().unwrap()),
            num_edges: u64::from_le_bytes(bytes[24..32].try_into().unwrap()),
            directed: flags & 1 != 0,
        })
    }
}

/// In-memory per-vertex index: the O(n) SEM state.
///
/// Kept in struct-of-arrays form; 16 bytes/vertex on disk and in memory.
#[derive(Debug, Clone)]
pub struct GraphIndex {
    header: GraphHeader,
    /// Byte offset of each vertex's adjacency record in the adj file.
    offsets: Vec<u64>,
    in_degs: Vec<u32>,
    out_degs: Vec<u32>,
}

impl GraphIndex {
    /// Assemble an index (used by the builder).
    pub fn new(
        header: GraphHeader,
        offsets: Vec<u64>,
        in_degs: Vec<u32>,
        out_degs: Vec<u32>,
    ) -> Self {
        assert_eq!(offsets.len() as u64, header.num_vertices);
        assert_eq!(in_degs.len(), offsets.len());
        assert_eq!(out_degs.len(), offsets.len());
        GraphIndex { header, offsets, in_degs, out_degs }
    }

    /// Image header.
    pub fn header(&self) -> &GraphHeader {
        &self.header
    }

    /// Vertex count.
    pub fn num_vertices(&self) -> usize {
        self.offsets.len()
    }

    /// Stored edge count (undirected edges count twice).
    pub fn num_edges(&self) -> u64 {
        self.header.num_edges
    }

    /// Directed?
    pub fn directed(&self) -> bool {
        self.header.directed
    }

    /// In-degree (0 for undirected images).
    #[inline]
    pub fn in_deg(&self, v: VertexId) -> u32 {
        self.in_degs[v as usize]
    }

    /// Out-degree (== degree for undirected images).
    #[inline]
    pub fn out_deg(&self, v: VertexId) -> u32 {
        self.out_degs[v as usize]
    }

    /// Total degree.
    #[inline]
    pub fn degree(&self, v: VertexId) -> u32 {
        self.in_degs[v as usize] + self.out_degs[v as usize]
    }

    /// Byte length of a vertex's full adjacency record.
    #[inline]
    pub fn record_len(&self, v: VertexId) -> usize {
        (self.in_degs[v as usize] as usize + self.out_degs[v as usize] as usize) * 4
    }

    /// Byte range in the adj file for the given request.
    #[inline]
    pub fn byte_range(&self, v: VertexId, req: EdgeRequest) -> (u64, usize) {
        let off = self.offsets[v as usize];
        let in_bytes = self.in_degs[v as usize] as usize * 4;
        let out_bytes = self.out_degs[v as usize] as usize * 4;
        match req {
            EdgeRequest::None => (off, 0),
            EdgeRequest::In => (off, in_bytes),
            EdgeRequest::Out => (off + in_bytes as u64, out_bytes),
            EdgeRequest::Both => (off, in_bytes + out_bytes),
        }
    }

    /// Serialize header + entries to the `.gy-idx` byte layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.offsets.len() * IDX_ENTRY_LEN);
        out.extend_from_slice(&self.header.encode());
        for i in 0..self.offsets.len() {
            out.extend_from_slice(&self.offsets[i].to_le_bytes());
            out.extend_from_slice(&self.in_degs[i].to_le_bytes());
            out.extend_from_slice(&self.out_degs[i].to_le_bytes());
        }
        out
    }

    /// Parse a `.gy-idx` byte image.
    pub fn decode(bytes: &[u8]) -> crate::Result<Self> {
        let header = GraphHeader::decode(bytes)?;
        let n = header.num_vertices as usize;
        ensure!(
            bytes.len() >= HEADER_LEN + n * IDX_ENTRY_LEN,
            "index file truncated: {} vertices need {} bytes, have {}",
            n,
            HEADER_LEN + n * IDX_ENTRY_LEN,
            bytes.len()
        );
        let mut offsets = Vec::with_capacity(n);
        let mut in_degs = Vec::with_capacity(n);
        let mut out_degs = Vec::with_capacity(n);
        for i in 0..n {
            let e = HEADER_LEN + i * IDX_ENTRY_LEN;
            offsets.push(u64::from_le_bytes(bytes[e..e + 8].try_into().unwrap()));
            in_degs.push(u32::from_le_bytes(bytes[e + 8..e + 12].try_into().unwrap()));
            out_degs.push(u32::from_le_bytes(bytes[e + 12..e + 16].try_into().unwrap()));
        }
        Ok(GraphIndex { header, offsets, in_degs, out_degs })
    }
}

/// Which edge lists an algorithm needs for a vertex — the paper's central
/// I/O-minimization lever ("limit superfluous reads", §4.1): PR-push
/// requests only `Out`, PR-pull only `In`, triangle counting `Both`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeRequest {
    /// No edge data (vertex computes on state/messages alone).
    None,
    /// In-edge list only.
    In,
    /// Out-edge list only.
    Out,
    /// Both lists.
    Both,
}

/// Decoded edge data for one vertex, as fetched by the engine.
#[derive(Debug, Clone, Default)]
pub struct VertexEdges {
    /// In-neighbors (empty unless requested; undirected graphs use `out`).
    pub in_neighbors: Vec<VertexId>,
    /// Out-neighbors (or all neighbors for undirected graphs).
    pub out_neighbors: Vec<VertexId>,
}

impl VertexEdges {
    /// Decode from a record byte slice per the request that produced it.
    pub fn decode(bytes: &[u8], in_deg: u32, out_deg: u32, req: EdgeRequest) -> Self {
        let word = |b: &[u8], i: usize| {
            VertexId::from_le_bytes(b[i * 4..i * 4 + 4].try_into().unwrap())
        };
        match req {
            EdgeRequest::None => VertexEdges::default(),
            EdgeRequest::In => {
                debug_assert_eq!(bytes.len(), in_deg as usize * 4);
                VertexEdges {
                    in_neighbors: (0..in_deg as usize).map(|i| word(bytes, i)).collect(),
                    out_neighbors: Vec::new(),
                }
            }
            EdgeRequest::Out => {
                debug_assert_eq!(bytes.len(), out_deg as usize * 4);
                VertexEdges {
                    in_neighbors: Vec::new(),
                    out_neighbors: (0..out_deg as usize).map(|i| word(bytes, i)).collect(),
                }
            }
            EdgeRequest::Both => {
                debug_assert_eq!(bytes.len(), (in_deg + out_deg) as usize * 4);
                let ind = in_deg as usize;
                VertexEdges {
                    in_neighbors: (0..ind).map(|i| word(bytes, i)).collect(),
                    out_neighbors: (0..out_deg as usize)
                        .map(|i| word(bytes, ind + i))
                        .collect(),
                }
            }
        }
    }

    /// All neighbors for an undirected fetch.
    pub fn neighbors(&self) -> &[VertexId] {
        &self.out_neighbors
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn header_roundtrip() {
        let h = GraphHeader { num_vertices: 42, num_edges: 99, directed: true };
        let enc = h.encode();
        assert_eq!(GraphHeader::decode(&enc).unwrap(), h);
        let h2 = GraphHeader { num_vertices: 0, num_edges: 0, directed: false };
        assert_eq!(GraphHeader::decode(&h2.encode()).unwrap(), h2);
    }

    #[test]
    fn header_rejects_garbage() {
        assert!(GraphHeader::decode(b"short").is_err());
        let mut bad = GraphHeader { num_vertices: 1, num_edges: 1, directed: true }.encode();
        bad[0] = b'X';
        assert!(GraphHeader::decode(&bad).is_err());
        let mut badver = GraphHeader { num_vertices: 1, num_edges: 1, directed: true }.encode();
        badver[8] = 99;
        assert!(GraphHeader::decode(&badver).is_err());
    }

    #[test]
    fn index_roundtrip_and_ranges() {
        let h = GraphHeader { num_vertices: 3, num_edges: 5, directed: true };
        // v0: in=[..1], out=[..2] at offset 0 => 12 bytes
        // v1: in=0 out=1 at 12; v2: in=1 out=0 at 16
        let idx = GraphIndex::new(h, vec![0, 12, 16], vec![1, 0, 1], vec![2, 1, 0]);
        let enc = idx.encode();
        let dec = GraphIndex::decode(&enc).unwrap();
        assert_eq!(dec.num_vertices(), 3);
        assert_eq!(dec.in_deg(0), 1);
        assert_eq!(dec.out_deg(0), 2);
        assert_eq!(dec.degree(2), 1);
        assert_eq!(dec.byte_range(0, EdgeRequest::In), (0, 4));
        assert_eq!(dec.byte_range(0, EdgeRequest::Out), (4, 8));
        assert_eq!(dec.byte_range(0, EdgeRequest::Both), (0, 12));
        assert_eq!(dec.byte_range(1, EdgeRequest::Out), (12, 4));
        assert_eq!(dec.byte_range(2, EdgeRequest::In), (16, 4));
        assert_eq!(dec.byte_range(2, EdgeRequest::None), (16, 0));
    }

    #[test]
    fn index_decode_rejects_truncation() {
        let h = GraphHeader { num_vertices: 10, num_edges: 0, directed: false };
        let idx = GraphIndex::new(h, vec![0; 10], vec![0; 10], vec![0; 10]);
        let mut enc = idx.encode();
        enc.truncate(enc.len() - 1);
        assert!(GraphIndex::decode(&enc).is_err());
    }

    #[test]
    fn vertex_edges_decode_both() {
        let mut bytes = Vec::new();
        for v in [7u32, 9] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [1u32, 2, 3] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let ve = VertexEdges::decode(&bytes, 2, 3, EdgeRequest::Both);
        assert_eq!(ve.in_neighbors, vec![7, 9]);
        assert_eq!(ve.out_neighbors, vec![1, 2, 3]);

        let out_only = VertexEdges::decode(&bytes[8..], 2, 3, EdgeRequest::Out);
        assert_eq!(out_only.out_neighbors, vec![1, 2, 3]);
        assert!(out_only.in_neighbors.is_empty());

        let none = VertexEdges::decode(&[], 2, 3, EdgeRequest::None);
        assert!(none.in_neighbors.is_empty() && none.out_neighbors.is_empty());
    }
}
