//! Graph images, converters, generators and the in-memory baseline.
//!
//! * [`format`] — the on-disk graph image (FlashGraph analogue): a small
//!   in-memory index (O(n)) plus a packed adjacency file (O(m)) that
//!   stays on disk and is read through [`crate::safs`].
//! * [`builder`] — edge-list → graph-image conversion (sort, dedup,
//!   pack), to files or to RAM buffers (the Louvain "RAMDisk" baseline).
//! * [`csr`] — in-memory CSR graph: the "fully in-memory execution"
//!   baseline of the paper's headline comparison, and the substrate for
//!   oracle implementations in tests.
//! * [`gen`] — synthetic workload generators (R-MAT, Erdős–Rényi,
//!   Barabási–Albert, 2-D grid) replacing the paper's Twitter dataset
//!   (DESIGN.md §5).
//! * [`source`] — the [`source::EdgeSource`] abstraction the engine pulls
//!   edge data through: SEM (disk + page cache) or in-memory CSR.

pub mod builder;
pub mod csr;
pub mod format;
pub mod gen;
pub mod source;

pub use builder::GraphBuilder;
pub use csr::Csr;
pub use format::{EdgeRequest, GraphHeader, GraphIndex, VertexEdges};
pub use source::{EdgeSource, MemGraph, SemGraph};
