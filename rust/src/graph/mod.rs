//! Graph images, converters, generators and the in-memory baseline.
//!
//! * [`format`] — the on-disk graph image (FlashGraph analogue): a small
//!   in-memory index (O(n)) plus a packed adjacency file (O(m)) that
//!   stays on disk and is read through [`crate::safs`]. Two versions:
//!   v1 (fixed-width `u32` neighbors) and v2 (delta+varint compressed
//!   sections, ~3x smaller on real graphs); see `docs/FORMAT.md`.
//! * [`varint`] — the LEB128 + delta-coding primitives behind v2.
//! * [`builder`] — edge-list → graph-image conversion (sort, dedup,
//!   pack, either format version), to files or to RAM buffers (the
//!   Louvain "RAMDisk" baseline), plus v1 ↔ v2 image conversion.
//! * [`csr`] — in-memory CSR graph: the "fully in-memory execution"
//!   baseline of the paper's headline comparison, and the substrate for
//!   oracle implementations in tests.
//! * [`gen`] — synthetic workload generators (R-MAT, Erdős–Rényi,
//!   Barabási–Albert, 2-D grid) replacing the paper's Twitter dataset
//!   (DESIGN.md §5).
//! * [`source`] — the [`source::EdgeSource`] abstraction the engine pulls
//!   edge data through: SEM (disk + page cache) or in-memory CSR.

pub mod builder;
pub mod csr;
pub mod format;
pub mod gen;
pub mod scrub;
pub mod source;
pub mod varint;

pub use builder::GraphBuilder;
pub use csr::Csr;
pub use format::{
    ChecksumFooter, EdgeEncoding, EdgeRequest, FormatError, GraphHeader, GraphIndex,
    VertexEdges,
};
pub use scrub::{scrub_file, scrub_image, ScrubOptions, ScrubReport};
pub use source::{EdgeSource, FetchArena, FetchSlot, MemGraph, SemGraph};
