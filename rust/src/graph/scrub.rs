//! Image scrubbing: proactive verification of checksummed graph images.
//!
//! Verify-on-read ([`crate::safs::SemFile`]) only checks pages a job
//! actually touches; latent corruption in cold regions survives until
//! something reads it. The scrubber closes that gap: it streams every
//! page of an image through its [`ChecksumFooter`] with positioned
//! reads — no page cache, no I/O pool, no interference with running
//! jobs — and reports each page whose crc32c disagrees.
//!
//! Two consumers:
//!
//! * the `graphyti scrub` CLI subcommand (offline, exits nonzero on any
//!   failure), and
//! * the service's opt-in background scrubber thread, which sweeps every
//!   registered image at a configured rate limit and feeds
//!   `pages_scrubbed` / `checksum_failures` into the substrate-wide
//!   [`IoStats`] for the metrics registry and the `health` op.
//!
//! Both are deterministic: the same image with the same flipped bits
//! yields the same bad-page list every sweep.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Context;

use crate::graph::format::{ChecksumFooter, GraphHeader, CHECKSUM_PAGE};
use crate::safs::IoStats;

/// How a scrub sweep behaves.
#[derive(Debug, Clone, Default)]
pub struct ScrubOptions {
    /// Maximum bytes verified per second (0 = unthrottled). The
    /// background scrubber sets this so a sweep never competes with job
    /// I/O for more than a sliver of bandwidth.
    pub rate_limit_bytes_per_sec: u64,
    /// Cooperative cancellation: checked between chunks, so a sweep
    /// stops within one chunk of the flag being raised (the report then
    /// covers only the pages scrubbed so far).
    pub cancel: Option<Arc<AtomicBool>>,
}

impl ScrubOptions {
    fn cancelled(&self) -> bool {
        self.cancel.as_ref().is_some_and(|c| c.load(Ordering::Relaxed))
    }
}

/// Outcome of scrubbing one file.
#[derive(Debug, Clone)]
pub struct ScrubReport {
    /// The scrubbed file.
    pub path: PathBuf,
    /// Pages whose crc was verified.
    pub pages_scrubbed: u64,
    /// File-local page numbers that failed verification (sorted; every
    /// failure is also one `checksum_failures` count).
    pub bad_pages: Vec<u64>,
    /// True when the image carries no checksum footer — nothing to
    /// verify, nothing scrubbed.
    pub skipped: bool,
    /// True when a cancel flag stopped the sweep early.
    pub cancelled: bool,
}

impl ScrubReport {
    /// Checksum failures found (length of [`Self::bad_pages`]).
    pub fn checksum_failures(&self) -> u64 {
        self.bad_pages.len() as u64
    }

    fn skipped(path: &Path) -> Self {
        ScrubReport {
            path: path.to_path_buf(),
            pages_scrubbed: 0,
            bad_pages: Vec::new(),
            skipped: true,
            cancelled: false,
        }
    }
}

/// Pages verified per throttle/cancel check: 256 pages = 1 MiB.
const CHUNK_PAGES: u64 = 256;

/// Scrub one checksummed file: validate its footer, then stream every
/// data page through positioned reads and verify each crc. Counters
/// move into `stats` (when given) as the sweep progresses, so a
/// long-running background scrub is observable mid-flight.
pub fn scrub_file(
    path: &Path,
    opts: &ScrubOptions,
    stats: Option<&IoStats>,
) -> crate::Result<ScrubReport> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = f.metadata()?.len();
    let footer = ChecksumFooter::read_from(&f, file_len)
        .with_context(|| format!("checksum footer of {}", path.display()))?;
    let mut report = ScrubReport {
        path: path.to_path_buf(),
        pages_scrubbed: 0,
        bad_pages: Vec::new(),
        skipped: false,
        cancelled: false,
    };
    let npages = footer.npages();
    let mut buf = vec![0u8; (CHUNK_PAGES as usize) * CHECKSUM_PAGE];
    let mut p = 0u64;
    let t0 = std::time::Instant::now();
    let mut bytes_done = 0u64;
    while p < npages {
        if opts.cancelled() {
            report.cancelled = true;
            break;
        }
        let chunk = CHUNK_PAGES.min(npages - p);
        let start = p * CHECKSUM_PAGE as u64;
        let want = ((footer.data_len - start) as usize).min(chunk as usize * CHECKSUM_PAGE);
        {
            use std::os::unix::fs::FileExt;
            f.read_exact_at(&mut buf[..want], start)
                .with_context(|| format!("scrub read at {start} of {}", path.display()))?;
        }
        for i in 0..chunk {
            let off = i as usize * CHECKSUM_PAGE;
            if !footer.page_ok(p + i, &buf[off..want.min(off + CHECKSUM_PAGE)]) {
                report.bad_pages.push(p + i);
                if let Some(s) = stats {
                    s.add_checksum_failure(1);
                }
            }
        }
        report.pages_scrubbed += chunk;
        if let Some(s) = stats {
            s.add_pages_scrubbed(chunk);
        }
        bytes_done += want as u64;
        p += chunk;
        // throttle: sleep until the byte budget the elapsed wall allows
        // catches up with what was actually read
        if opts.rate_limit_bytes_per_sec > 0 {
            let budget_elapsed =
                bytes_done as f64 / opts.rate_limit_bytes_per_sec as f64;
            let ahead = budget_elapsed - t0.elapsed().as_secs_f64();
            if ahead > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(ahead.min(0.25)));
            }
        }
    }
    Ok(report)
}

/// Scrub both files of the image at `<base>.gy-idx` / `<base>.gy-adj`.
///
/// The index header decides whether the image is checksummed at all: a
/// legacy (unfooted) image yields two `skipped` reports rather than an
/// error, so sweeping a mixed registry never fails on old graphs.
pub fn scrub_image(
    base: &Path,
    opts: &ScrubOptions,
    stats: Option<&IoStats>,
) -> crate::Result<Vec<ScrubReport>> {
    let idx_path = base.with_extension("gy-idx");
    let adj_path = base.with_extension("gy-adj");
    let mut head = [0u8; crate::graph::format::HEADER_LEN];
    {
        use std::os::unix::fs::FileExt;
        let f = std::fs::File::open(&idx_path)
            .with_context(|| format!("open {}", idx_path.display()))?;
        f.read_exact_at(&mut head, 0)
            .with_context(|| format!("header of {}", idx_path.display()))?;
    }
    let header = GraphHeader::decode(&head)?;
    if !header.checksums {
        return Ok(vec![ScrubReport::skipped(&idx_path), ScrubReport::skipped(&adj_path)]);
    }
    let idx = scrub_file(&idx_path, opts, stats)?;
    if idx.cancelled {
        return Ok(vec![idx, ScrubReport::skipped(&adj_path)]);
    }
    let adj = scrub_file(&adj_path, opts, stats)?;
    Ok(vec![idx, adj])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn build(tag: &str, checksums: bool) -> PathBuf {
        let base = std::env::temp_dir()
            .join(format!("graphyti-scrub-{}-{tag}", std::process::id()));
        let edges = gen::rmat(8, 2000, 17);
        let mut b = GraphBuilder::new(256, true);
        b.add_edges(&edges).checksums(checksums);
        b.build_files(&base).unwrap();
        base
    }

    fn cleanup(base: &Path) {
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    /// Flip one bit of the data region at `(page, bit)` in-place.
    fn flip_bit(path: &Path, page: u64, bit: u64) {
        use std::os::unix::fs::FileExt;
        let f = std::fs::OpenOptions::new().read(true).write(true).open(path).unwrap();
        let off = page * CHECKSUM_PAGE as u64 + bit / 8;
        let mut b = [0u8; 1];
        f.read_exact_at(&mut b, off).unwrap();
        b[0] ^= 1 << (bit % 8);
        f.write_all_at(&b, off).unwrap();
    }

    #[test]
    fn clean_image_scrubs_clean() {
        let base = build("clean", true);
        let stats = IoStats::new();
        let reports =
            scrub_image(&base, &ScrubOptions::default(), Some(&stats)).unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            assert!(!r.skipped, "{}", r.path.display());
            assert!(r.pages_scrubbed > 0);
            assert!(r.bad_pages.is_empty(), "{:?}", r);
        }
        let s = stats.snapshot();
        assert_eq!(s.checksum_failures, 0);
        assert_eq!(
            s.pages_scrubbed,
            reports.iter().map(|r| r.pages_scrubbed).sum::<u64>()
        );
        cleanup(&base);
    }

    #[test]
    fn scrub_finds_every_injected_flip_deterministically() {
        let base = build("flips", true);
        let adj = base.with_extension("gy-adj");
        // flip bits on three distinct pages of the data region (the adj
        // here spans several pages: 2000 edges * 2 dirs * 4B > 12 KiB)
        let len = std::fs::metadata(&adj).unwrap().len();
        let footer =
            ChecksumFooter::read_from(&std::fs::File::open(&adj).unwrap(), len).unwrap();
        assert!(footer.npages() >= 3, "image too small for the test: {len}");
        for (p, bit) in [(0u64, 7u64), (1, 4096 * 4), (2, 13)] {
            flip_bit(&adj, p, bit);
        }
        for _ in 0..2 {
            let reports = scrub_image(&base, &ScrubOptions::default(), None).unwrap();
            let adj_report = &reports[1];
            assert_eq!(adj_report.bad_pages, vec![0, 1, 2], "{adj_report:?}");
            assert_eq!(adj_report.checksum_failures(), 3);
            assert!(reports[0].bad_pages.is_empty(), "idx untouched");
        }
        cleanup(&base);
    }

    #[test]
    fn unfooted_legacy_image_is_skipped_not_failed() {
        let base = build("legacy", false);
        let reports = scrub_image(&base, &ScrubOptions::default(), None).unwrap();
        assert!(reports.iter().all(|r| r.skipped && r.pages_scrubbed == 0));
        cleanup(&base);
    }

    #[test]
    fn cancel_stops_a_sweep_early() {
        let base = build("cancel", true);
        let cancel = Arc::new(AtomicBool::new(true)); // pre-cancelled
        let opts = ScrubOptions { rate_limit_bytes_per_sec: 0, cancel: Some(cancel) };
        let reports = scrub_image(&base, &opts, None).unwrap();
        assert!(reports[0].cancelled);
        assert_eq!(reports[0].pages_scrubbed, 0);
        cleanup(&base);
    }

    #[test]
    fn rate_limit_paces_the_sweep() {
        let base = build("paced", true);
        let adj = base.with_extension("gy-adj");
        let len = std::fs::metadata(&adj).unwrap().len();
        // budget ~half the file per second => the sweep must take time
        let opts = ScrubOptions { rate_limit_bytes_per_sec: len * 2, cancel: None };
        let t0 = std::time::Instant::now();
        let r = scrub_file(&adj, &opts, None).unwrap();
        assert!(r.bad_pages.is_empty());
        assert!(
            t0.elapsed() >= std::time::Duration::from_millis(100),
            "a rate-limited sweep of {len} bytes at {}B/s finished too fast",
            len * 2
        );
        cleanup(&base);
    }
}
