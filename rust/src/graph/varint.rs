//! LEB128 varint codec + delta coding for sorted neighbor lists — the
//! byte-level primitives of the v2 graph image format.
//!
//! Encoding rules (see `docs/FORMAT.md` for the full spec):
//!
//! * **Varint (LEB128):** a `u32` is emitted as 1–5 bytes, little-endian
//!   base-128 groups, low 7 bits first; the high bit of each byte is a
//!   continuation flag. Values `< 128` take one byte.
//! * **Delta coding:** a sorted-ascending neighbor list `[v0, v1, ...]`
//!   is stored as `varint(v0), varint(v1 - v0), varint(v2 - v1), ...`.
//!   Real graphs have many small gaps between consecutive sorted
//!   neighbors, so most deltas fit in one byte — this is where the
//!   ~3x on-disk reduction over fixed-width `u32` comes from.
//!
//! Decoding is allocation-free: values are appended into a
//! caller-provided buffer and the cursor advances through the byte
//! stream without intermediate copies.

use crate::VertexId;

/// Number of bytes [`encode_u32`] emits for `v` (1–5).
#[inline]
pub fn encoded_len(v: u32) -> usize {
    match v {
        0..=0x7F => 1,
        0x80..=0x3FFF => 2,
        0x4000..=0x1F_FFFF => 3,
        0x20_0000..=0xFFF_FFFF => 4,
        _ => 5,
    }
}

/// Append the LEB128 encoding of `v` to `out`.
#[inline]
pub fn encode_u32(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Decode one LEB128 `u32` starting at `*pos`, advancing `*pos` past it.
///
/// Panics (via slice indexing) if the stream is truncated — the SEM read
/// path only hands this verified in-bounds record slices, matching the
/// fixed-width decoder's behavior on corrupt data.
#[inline]
pub fn decode_u32(bytes: &[u8], pos: &mut usize) -> u32 {
    let mut v = 0u32;
    for shift in [0u32, 7, 14, 21, 28] {
        let b = bytes[*pos];
        *pos += 1;
        v |= ((b & 0x7F) as u32) << shift;
        if b & 0x80 == 0 {
            return v;
        }
    }
    debug_assert!(false, "varint longer than 5 bytes");
    v
}

/// Append the delta+varint encoding of a sorted-ascending list to `out`.
///
/// The first element is stored verbatim; each subsequent element as the
/// difference from its predecessor.
pub fn encode_deltas(sorted: &[VertexId], out: &mut Vec<u8>) {
    let mut prev: u32 = 0;
    for (i, &v) in sorted.iter().enumerate() {
        debug_assert!(i == 0 || v >= prev, "neighbor list must be sorted ascending");
        let delta = if i == 0 { v } else { v.wrapping_sub(prev) };
        encode_u32(delta, out);
        prev = v;
    }
}

/// Byte length [`encode_deltas`] would produce for `sorted`.
pub fn deltas_len(sorted: &[VertexId]) -> usize {
    let mut prev: u32 = 0;
    let mut len = 0;
    for (i, &v) in sorted.iter().enumerate() {
        len += encoded_len(if i == 0 { v } else { v.wrapping_sub(prev) });
        prev = v;
    }
    len
}

/// Decode `count` delta+varint values starting at `*pos`, appending the
/// reconstructed (absolute) values to `out` and advancing `*pos`.
pub fn decode_deltas(bytes: &[u8], count: usize, pos: &mut usize, out: &mut Vec<VertexId>) {
    out.reserve(count);
    let mut prev: u32 = 0;
    for i in 0..count {
        let d = decode_u32(bytes, pos);
        prev = if i == 0 { d } else { prev.wrapping_add(d) };
        out.push(prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: u32) {
        let mut buf = Vec::new();
        encode_u32(v, &mut buf);
        assert_eq!(buf.len(), encoded_len(v), "len mismatch for {v}");
        let mut pos = 0;
        assert_eq!(decode_u32(&buf, &mut pos), v);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn u32_roundtrip_edge_cases() {
        // zero, max, and every single-byte/boundary value
        for v in [
            0u32,
            1,
            0x7F,               // largest 1-byte
            0x80,               // smallest 2-byte
            0x3FFF,             // largest 2-byte
            0x4000,             // smallest 3-byte
            0x1F_FFFF,          // largest 3-byte
            0x20_0000,          // smallest 4-byte
            0xFFF_FFFF,         // largest 4-byte
            0x1000_0000,        // smallest 5-byte
            u32::MAX - 1,
            u32::MAX,
        ] {
            roundtrip(v);
        }
    }

    #[test]
    fn u32_roundtrip_sweep() {
        let mut rng = crate::util::XorShift::new(99);
        for _ in 0..5000 {
            roundtrip(rng.next_u64() as u32);
        }
    }

    #[test]
    fn encoded_len_boundaries() {
        assert_eq!(encoded_len(0), 1);
        assert_eq!(encoded_len(127), 1);
        assert_eq!(encoded_len(128), 2);
        assert_eq!(encoded_len(16_383), 2);
        assert_eq!(encoded_len(16_384), 3);
        assert_eq!(encoded_len(u32::MAX), 5);
    }

    #[test]
    fn deltas_roundtrip_and_len() {
        let cases: Vec<Vec<u32>> = vec![
            vec![],
            vec![0],
            vec![u32::MAX],
            vec![0, 1, 2, 3],
            vec![5, 5_000, 5_001, 4_000_000_000],
            (0..1000).map(|i| i * 7 + 3).collect(),
        ];
        for list in cases {
            let mut buf = Vec::new();
            encode_deltas(&list, &mut buf);
            assert_eq!(buf.len(), deltas_len(&list), "{list:?}");
            let mut pos = 0;
            let mut out = Vec::new();
            decode_deltas(&buf, list.len(), &mut pos, &mut out);
            assert_eq!(out, list);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn dense_lists_compress_to_one_byte_per_edge() {
        // consecutive neighbors => every delta is 1 => 1 byte each
        let list: Vec<u32> = (1000..2000).collect();
        let mut buf = Vec::new();
        encode_deltas(&list, &mut buf);
        assert_eq!(buf.len(), encoded_len(1000) + (list.len() - 1));
    }

    #[test]
    fn concatenated_streams_decode_sequentially() {
        // the v2 record layout is [in-stream][out-stream] back to back;
        // the decoder must leave the cursor exactly at the boundary
        let ins = vec![3u32, 9, 12];
        let outs = vec![0u32, 500_000];
        let mut buf = Vec::new();
        encode_deltas(&ins, &mut buf);
        let boundary = buf.len();
        encode_deltas(&outs, &mut buf);
        let mut pos = 0;
        let mut got_in = Vec::new();
        decode_deltas(&buf, ins.len(), &mut pos, &mut got_in);
        assert_eq!(pos, boundary);
        let mut got_out = Vec::new();
        decode_deltas(&buf, outs.len(), &mut pos, &mut got_out);
        assert_eq!((got_in, got_out), (ins, outs));
        assert_eq!(pos, buf.len());
    }
}
