//! LEB128 varint codec + delta coding for sorted neighbor lists — the
//! byte-level primitives of the v2 graph image format.
//!
//! Encoding rules (see `docs/FORMAT.md` for the full spec):
//!
//! * **Varint (LEB128):** a `u32` is emitted as 1–5 bytes, little-endian
//!   base-128 groups, low 7 bits first; the high bit of each byte is a
//!   continuation flag. Values `< 128` take one byte.
//! * **Delta coding:** a sorted-ascending neighbor list `[v0, v1, ...]`
//!   is stored as `varint(v0), varint(v1 - v0), varint(v2 - v1), ...`.
//!   Real graphs have many small gaps between consecutive sorted
//!   neighbors, so most deltas fit in one byte — this is where the
//!   ~3x on-disk reduction over fixed-width `u32` comes from.
//!
//! Decoding is allocation-free: values are appended into a
//! caller-provided buffer and the cursor advances through the byte
//! stream without intermediate copies.

use crate::VertexId;

/// Number of bytes [`encode_u32`] emits for `v` (1–5).
#[inline]
pub fn encoded_len(v: u32) -> usize {
    match v {
        0..=0x7F => 1,
        0x80..=0x3FFF => 2,
        0x4000..=0x1F_FFFF => 3,
        0x20_0000..=0xFFF_FFFF => 4,
        _ => 5,
    }
}

/// Append the LEB128 encoding of `v` to `out`.
#[inline]
pub fn encode_u32(mut v: u32, out: &mut Vec<u8>) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Decode one LEB128 `u32` starting at `*pos`, advancing `*pos` past it.
///
/// Panics (via slice indexing) if the stream is truncated — the SEM read
/// path only hands this verified in-bounds record slices, matching the
/// fixed-width decoder's behavior on corrupt data.
#[inline]
pub fn decode_u32(bytes: &[u8], pos: &mut usize) -> u32 {
    let mut v = 0u32;
    for shift in [0u32, 7, 14, 21, 28] {
        let b = bytes[*pos];
        *pos += 1;
        v |= ((b & 0x7F) as u32) << shift;
        if b & 0x80 == 0 {
            return v;
        }
    }
    debug_assert!(false, "varint longer than 5 bytes");
    v
}

/// Append the delta+varint encoding of a sorted-ascending list to `out`.
///
/// The first element is stored verbatim; each subsequent element as the
/// difference from its predecessor.
pub fn encode_deltas(sorted: &[VertexId], out: &mut Vec<u8>) {
    let mut prev: u32 = 0;
    for (i, &v) in sorted.iter().enumerate() {
        debug_assert!(i == 0 || v >= prev, "neighbor list must be sorted ascending");
        let delta = if i == 0 { v } else { v.wrapping_sub(prev) };
        encode_u32(delta, out);
        prev = v;
    }
}

/// Byte length [`encode_deltas`] would produce for `sorted`.
pub fn deltas_len(sorted: &[VertexId]) -> usize {
    let mut prev: u32 = 0;
    let mut len = 0;
    for (i, &v) in sorted.iter().enumerate() {
        len += encoded_len(if i == 0 { v } else { v.wrapping_sub(prev) });
        prev = v;
    }
    len
}

/// Continuation-flag bit of every byte lane in a little-endian u64 load.
const CONT_MASK: u64 = 0x8080_8080_8080_8080;

/// Decode `count` delta+varint values starting at `*pos`, appending the
/// reconstructed (absolute) values to `out` and advancing `*pos`.
///
/// **Word-level fast path.** Real sorted neighbor lists are dominated by
/// one-byte deltas (gaps < 128 — the property the v2 format's ~3x
/// compression rests on), so the scalar decoder's per-byte
/// load/test/branch is almost all overhead. This decoder loads 8 bytes
/// at a time and uses the continuation-bit mask to find the leading run
/// of one-byte values: `conts = w & 0x8080…80`; if byte `j` is the
/// first with its continuation flag set, `conts.trailing_zeros()/8 == j`
/// and bytes `0..j` are each a complete value. Those `j` (up to 8)
/// deltas decode branch-free — one shift+mask+add each, no per-byte
/// continuation test — and prefix-sum into the running `prev`
/// (`prev` starts at 0 and the first delta IS the absolute first value,
/// so the unconditional `wrapping_add` is bit-identical to the scalar
/// initialisation). Multi-byte deltas and the final <8 bytes of the
/// buffer fall back to the scalar [`decode_u32`] loop, so the two paths
/// produce byte-identical output and cursor positions on every stream —
/// the differential property the test suite pins.
///
/// The 8-byte load may peek past this stream's logical end into
/// whatever follows it in the record slice (the v2 layout concatenates
/// the in- and out-streams back to back); only `count` values' bytes
/// are ever *consumed*, so the cursor contract is unchanged.
pub fn decode_deltas(bytes: &[u8], count: usize, pos: &mut usize, out: &mut Vec<VertexId>) {
    out.reserve(count);
    let mut prev: u32 = 0;
    let mut i = 0usize;
    let mut p = *pos;
    while i < count && p + 8 <= bytes.len() {
        let w = u64::from_le_bytes(bytes[p..p + 8].try_into().unwrap());
        let conts = w & CONT_MASK;
        let run = if conts == 0 { 8 } else { (conts.trailing_zeros() / 8) as usize };
        if run == 0 {
            // a multi-byte delta leads the window: scalar-decode one
            // value, then re-enter the fast path
            let d = decode_u32(bytes, &mut p);
            prev = if i == 0 { d } else { prev.wrapping_add(d) };
            out.push(prev);
            i += 1;
            continue;
        }
        let take = run.min(count - i);
        for b in 0..take {
            // continuation flag is clear for these lanes, so the low 7
            // bits are the whole delta
            let d = ((w >> (8 * b)) & 0x7F) as u32;
            prev = prev.wrapping_add(d);
            out.push(prev);
        }
        p += take;
        i += take;
    }
    while i < count {
        let d = decode_u32(bytes, &mut p);
        prev = if i == 0 { d } else { prev.wrapping_add(d) };
        out.push(prev);
        i += 1;
    }
    *pos = p;
}

/// The byte-at-a-time reference decoder [`decode_deltas`] replaced:
/// kept public as the differential-test oracle and the `fig_decode`
/// baseline. Semantics are identical by construction — the fast path's
/// tests assert bit-identical output and cursor on adversarial streams.
pub fn decode_deltas_scalar(bytes: &[u8], count: usize, pos: &mut usize, out: &mut Vec<VertexId>) {
    out.reserve(count);
    let mut prev: u32 = 0;
    for i in 0..count {
        let d = decode_u32(bytes, pos);
        prev = if i == 0 { d } else { prev.wrapping_add(d) };
        out.push(prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: u32) {
        let mut buf = Vec::new();
        encode_u32(v, &mut buf);
        assert_eq!(buf.len(), encoded_len(v), "len mismatch for {v}");
        let mut pos = 0;
        assert_eq!(decode_u32(&buf, &mut pos), v);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn u32_roundtrip_edge_cases() {
        // zero, max, and every single-byte/boundary value
        for v in [
            0u32,
            1,
            0x7F,               // largest 1-byte
            0x80,               // smallest 2-byte
            0x3FFF,             // largest 2-byte
            0x4000,             // smallest 3-byte
            0x1F_FFFF,          // largest 3-byte
            0x20_0000,          // smallest 4-byte
            0xFFF_FFFF,         // largest 4-byte
            0x1000_0000,        // smallest 5-byte
            u32::MAX - 1,
            u32::MAX,
        ] {
            roundtrip(v);
        }
    }

    #[test]
    fn u32_roundtrip_sweep() {
        let mut rng = crate::util::XorShift::new(99);
        for _ in 0..5000 {
            roundtrip(rng.next_u64() as u32);
        }
    }

    #[test]
    fn encoded_len_boundaries() {
        assert_eq!(encoded_len(0), 1);
        assert_eq!(encoded_len(127), 1);
        assert_eq!(encoded_len(128), 2);
        assert_eq!(encoded_len(16_383), 2);
        assert_eq!(encoded_len(16_384), 3);
        assert_eq!(encoded_len(u32::MAX), 5);
    }

    #[test]
    fn deltas_roundtrip_and_len() {
        let cases: Vec<Vec<u32>> = vec![
            vec![],
            vec![0],
            vec![u32::MAX],
            vec![0, 1, 2, 3],
            vec![5, 5_000, 5_001, 4_000_000_000],
            (0..1000).map(|i| i * 7 + 3).collect(),
        ];
        for list in cases {
            let mut buf = Vec::new();
            encode_deltas(&list, &mut buf);
            assert_eq!(buf.len(), deltas_len(&list), "{list:?}");
            let mut pos = 0;
            let mut out = Vec::new();
            decode_deltas(&buf, list.len(), &mut pos, &mut out);
            assert_eq!(out, list);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn dense_lists_compress_to_one_byte_per_edge() {
        // consecutive neighbors => every delta is 1 => 1 byte each
        let list: Vec<u32> = (1000..2000).collect();
        let mut buf = Vec::new();
        encode_deltas(&list, &mut buf);
        assert_eq!(buf.len(), encoded_len(1000) + (list.len() - 1));
    }

    /// Assert the word-level and scalar decoders produce bit-identical
    /// output and land the cursor on the same byte.
    fn differential(list: &[u32]) {
        let mut buf = Vec::new();
        encode_deltas(list, &mut buf);
        let (mut p_word, mut p_scalar) = (0usize, 0usize);
        let (mut word, mut scalar) = (Vec::new(), Vec::new());
        decode_deltas(&buf, list.len(), &mut p_word, &mut word);
        decode_deltas_scalar(&buf, list.len(), &mut p_scalar, &mut scalar);
        assert_eq!(word, scalar, "decoded values diverge for {list:?}");
        assert_eq!(word, list, "round-trip broken for {list:?}");
        assert_eq!(p_word, p_scalar, "cursor diverges for {list:?}");
        assert_eq!(p_word, buf.len());
    }

    #[test]
    fn word_decoder_matches_scalar_on_all_delta_widths() {
        // every 1–5 byte delta width, alone and surrounded by one-byte
        // runs of every length 0..=9, so multi-byte varints land at every
        // offset inside (and straddling) the 8-byte windows
        let widths: [u32; 5] = [1, 0x80, 0x4000, 0x20_0000, 0x1000_0000];
        for &big in &widths {
            for lead in 0..=9usize {
                for trail in 0..=9usize {
                    let mut list: Vec<u32> = Vec::new();
                    let mut v = 3u32;
                    for _ in 0..lead {
                        list.push(v);
                        v += 1; // one-byte deltas
                    }
                    v = v.saturating_add(big);
                    list.push(v);
                    for _ in 0..trail {
                        v += 1;
                        list.push(v);
                    }
                    differential(&list);
                }
            }
        }
    }

    #[test]
    fn word_decoder_matches_scalar_on_max_value_deltas() {
        // maximal 5-byte deltas, including wrap-adjacent sums
        differential(&[u32::MAX]);
        differential(&[0, u32::MAX]);
        differential(&[1, 2, 3, u32::MAX - 1, u32::MAX]);
        differential(&[u32::MAX - 7, u32::MAX - 6, u32::MAX]);
    }

    #[test]
    fn word_decoder_matches_scalar_randomized() {
        // adversarial mixed-magnitude streams: each element jumps by a
        // random gap whose byte width is itself random
        let mut rng = crate::util::XorShift::new(0xD0DE);
        for _ in 0..300 {
            let len = (rng.next_u64() % 48) as usize;
            let mut v: u32 = (rng.next_u64() % 128) as u32;
            let mut list = Vec::with_capacity(len);
            for _ in 0..len {
                list.push(v);
                let width = rng.next_u64() % 5;
                let gap = match width {
                    0 => rng.next_u64() % 0x80,
                    1 => 0x80 + rng.next_u64() % 0x3F80,
                    2 => 0x4000 + rng.next_u64() % 0x1C_0000,
                    3 => 0x20_0000 + rng.next_u64() % 0xDE0_0000,
                    _ => 0x1000_0000 + rng.next_u64() % 0x1000_0000,
                } as u32;
                v = v.saturating_add(gap.max(1));
            }
            list.dedup();
            differential(&list);
        }
    }

    #[test]
    fn word_decoder_never_consumes_past_its_stream() {
        // long one-byte-delta stream followed by a second stream: the
        // 8-byte loads peek across the boundary but must not consume it
        let first: Vec<u32> = (100..165).collect(); // 65 values, 1-byte deltas
        let second = vec![7u32, 1_000_000];
        let mut buf = Vec::new();
        encode_deltas(&first, &mut buf);
        let boundary = buf.len();
        encode_deltas(&second, &mut buf);
        let mut pos = 0;
        let mut out = Vec::new();
        decode_deltas(&buf, first.len(), &mut pos, &mut out);
        assert_eq!(out, first);
        assert_eq!(pos, boundary, "fast path consumed peeked bytes");
        let mut out2 = Vec::new();
        decode_deltas(&buf, second.len(), &mut pos, &mut out2);
        assert_eq!(out2, second);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn concatenated_streams_decode_sequentially() {
        // the v2 record layout is [in-stream][out-stream] back to back;
        // the decoder must leave the cursor exactly at the boundary
        let ins = vec![3u32, 9, 12];
        let outs = vec![0u32, 500_000];
        let mut buf = Vec::new();
        encode_deltas(&ins, &mut buf);
        let boundary = buf.len();
        encode_deltas(&outs, &mut buf);
        let mut pos = 0;
        let mut got_in = Vec::new();
        decode_deltas(&buf, ins.len(), &mut pos, &mut got_in);
        assert_eq!(pos, boundary);
        let mut got_out = Vec::new();
        decode_deltas(&buf, outs.len(), &mut pos, &mut got_out);
        assert_eq!((got_in, got_out), (ins, outs));
        assert_eq!(pos, buf.len());
    }
}
