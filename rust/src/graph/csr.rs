//! In-memory CSR graph — the paper's "totally in-memory execution"
//! baseline, and the substrate for oracle algorithm implementations used
//! in tests.

use crate::VertexId;

/// Compressed sparse row graph (both directions for directed graphs).
#[derive(Debug, Clone)]
pub struct Csr {
    directed: bool,
    out_offsets: Vec<u64>,
    out_neigh: Vec<VertexId>,
    in_offsets: Vec<u64>,
    in_neigh: Vec<VertexId>,
}

impl Csr {
    /// Build from an edge list (self-loops dropped, duplicates removed,
    /// undirected edges symmetrized) — mirrors
    /// [`super::builder::GraphBuilder`] normalization so SEM and
    /// in-memory runs see identical graphs.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)], directed: bool) -> Self {
        let mut es: Vec<(VertexId, VertexId)> =
            Vec::with_capacity(edges.len() * if directed { 1 } else { 2 });
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            es.push((u, v));
            if !directed {
                es.push((v, u));
            }
        }
        es.sort_unstable();
        es.dedup();

        let mut out_offsets = vec![0u64; n + 1];
        for &(u, _) in &es {
            out_offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            out_offsets[i + 1] += out_offsets[i];
        }
        let out_neigh: Vec<VertexId> = es.iter().map(|&(_, v)| v).collect();

        let (in_offsets, in_neigh) = if directed {
            let mut rev: Vec<(VertexId, VertexId)> = es.iter().map(|&(u, v)| (v, u)).collect();
            rev.sort_unstable();
            let mut io = vec![0u64; n + 1];
            for &(v, _) in &rev {
                io[v as usize + 1] += 1;
            }
            for i in 0..n {
                io[i + 1] += io[i];
            }
            (io, rev.into_iter().map(|(_, u)| u).collect())
        } else {
            (Vec::new(), Vec::new())
        };

        Csr { directed, out_offsets, out_neigh, in_offsets, in_neigh }
    }

    /// Vertex count.
    pub fn num_vertices(&self) -> usize {
        self.out_offsets.len() - 1
    }

    /// Stored edge count (undirected edges count twice).
    pub fn num_edges(&self) -> u64 {
        self.out_neigh.len() as u64
    }

    /// Directed?
    pub fn directed(&self) -> bool {
        self.directed
    }

    /// Out-neighbors of `v` (all neighbors for undirected), sorted.
    #[inline]
    pub fn out(&self, v: VertexId) -> &[VertexId] {
        &self.out_neigh[self.out_offsets[v as usize] as usize
            ..self.out_offsets[v as usize + 1] as usize]
    }

    /// In-neighbors of `v` (directed only), sorted.
    #[inline]
    pub fn inn(&self, v: VertexId) -> &[VertexId] {
        if !self.directed {
            return self.out(v);
        }
        &self.in_neigh
            [self.in_offsets[v as usize] as usize..self.in_offsets[v as usize + 1] as usize]
    }

    /// Out-degree.
    #[inline]
    pub fn out_deg(&self, v: VertexId) -> u32 {
        (self.out_offsets[v as usize + 1] - self.out_offsets[v as usize]) as u32
    }

    /// In-degree.
    #[inline]
    pub fn in_deg(&self, v: VertexId) -> u32 {
        if !self.directed {
            return self.out_deg(v);
        }
        (self.in_offsets[v as usize + 1] - self.in_offsets[v as usize]) as u32
    }

    /// Approximate resident bytes (for the memory-ratio headline).
    pub fn resident_bytes(&self) -> u64 {
        ((self.out_offsets.len() + self.in_offsets.len()) * 8
            + (self.out_neigh.len() + self.in_neigh.len()) * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn directed_adjacency() {
        let c = Csr::from_edges(4, &[(0, 1), (0, 2), (1, 2), (2, 0), (0, 1), (3, 3)], true);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.out(0), &[1, 2]);
        assert_eq!(c.inn(2), &[0, 1]);
        assert_eq!(c.out_deg(3), 0);
        assert_eq!(c.in_deg(0), 1);
    }

    #[test]
    fn undirected_symmetric() {
        let c = Csr::from_edges(3, &[(0, 1), (2, 1)], false);
        assert_eq!(c.num_edges(), 4);
        assert_eq!(c.out(1), &[0, 2]);
        assert_eq!(c.inn(1), &[0, 2], "inn falls back to out for undirected");
        assert_eq!(c.out_deg(1), 2);
        assert_eq!(c.in_deg(1), 2);
    }

    #[test]
    fn isolated_vertices() {
        let c = Csr::from_edges(5, &[(0, 1)], true);
        for v in 2..5 {
            assert_eq!(c.out(v), &[] as &[VertexId]);
            assert_eq!(c.inn(v), &[] as &[VertexId]);
        }
    }

    #[test]
    fn matches_builder_image() {
        use crate::graph::builder::GraphBuilder;
        use crate::graph::format::EdgeRequest;
        let edges = [(0u32, 1u32), (1, 3), (3, 0), (2, 3), (0, 2), (1, 0)];
        let c = Csr::from_edges(4, &edges, true);
        let mut b = GraphBuilder::new(4, true);
        b.add_edges(&edges);
        let img = b.build_ram();
        for v in 0..4u32 {
            assert_eq!(img.index.out_deg(v), c.out_deg(v), "v={v}");
            assert_eq!(img.index.in_deg(v), c.in_deg(v), "v={v}");
            let (off, len) = img.index.byte_range(v, EdgeRequest::Both);
            let ve = crate::graph::format::VertexEdges::decode(
                &img.adj[off as usize..off as usize + len],
                img.index.in_deg(v),
                img.index.out_deg(v),
                EdgeRequest::Both,
                img.index.encoding(),
            );
            assert_eq!(ve.out_neighbors, c.out(v));
            assert_eq!(ve.in_neighbors, c.inn(v));
        }
    }
}
