//! Synthetic workload generators.
//!
//! The paper evaluates on the Twitter graph (42 M vertices, 1.5 B edges,
//! heavy-tailed degree distribution). That dataset is not available here,
//! so benches generate **R-MAT** graphs with the same edge factor (~35)
//! and Kronecker parameters known to match social-network skew
//! (a=0.57, b=0.19, c=0.19, d=0.05 — the Graph500 defaults). See
//! DESIGN.md §5 for why this substitution preserves the paper's effects.
//!
//! Also provided: Erdős–Rényi (uniform), Barabási–Albert (preferential
//! attachment), a 2-D grid (road-like, high diameter — exercises the
//! diameter estimator), and tiny deterministic shapes for tests.

use crate::util::XorShift;
use crate::VertexId;

/// R-MAT generator (Graph500 parameters by default).
///
/// Produces `num_edges` directed edge samples over `2^scale` vertices.
/// Duplicates and self-loops are *not* removed here — the builder/CSR
/// normalize — matching how R-MAT is conventionally specified.
pub fn rmat(scale: u32, num_edges: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    rmat_with(scale, num_edges, 0.57, 0.19, 0.19, seed)
}

/// R-MAT with explicit quadrant probabilities (d = 1 - a - b - c).
pub fn rmat_with(
    scale: u32,
    num_edges: usize,
    a: f64,
    b: f64,
    c: f64,
    seed: u64,
) -> Vec<(VertexId, VertexId)> {
    assert!(scale <= 31, "scale {scale} exceeds u32 vertex ids");
    assert!(a + b + c <= 1.0 + 1e-9);
    let mut rng = XorShift::new(seed);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let (mut u, mut v) = (0u32, 0u32);
        for _ in 0..scale {
            u <<= 1;
            v <<= 1;
            let r = rng.next_f64();
            if r < a {
                // top-left: no bits set
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        edges.push((u, v));
    }
    edges
}

/// Erdős–Rényi G(n, m): `m` uniform edge samples.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    assert!(n >= 2);
    let mut rng = XorShift::new(seed);
    (0..m)
        .map(|_| {
            (
                rng.next_below(n as u64) as VertexId,
                rng.next_below(n as u64) as VertexId,
            )
        })
        .collect()
}

/// Barabási–Albert preferential attachment: each new vertex attaches to
/// `k` existing vertices with probability proportional to degree.
pub fn barabasi_albert(n: usize, k: usize, seed: u64) -> Vec<(VertexId, VertexId)> {
    assert!(n > k && k >= 1);
    let mut rng = XorShift::new(seed);
    let mut edges = Vec::with_capacity(n * k);
    // repeated-endpoints trick: sampling uniformly from the endpoint list
    // is sampling proportional to degree
    let mut endpoints: Vec<VertexId> = Vec::with_capacity(2 * n * k);
    // seed clique over the first k+1 vertices
    for u in 0..=(k as VertexId) {
        for v in 0..u {
            edges.push((u, v));
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    for u in (k + 1)..n {
        let mut chosen = Vec::with_capacity(k);
        while chosen.len() < k {
            let t = endpoints[rng.next_below(endpoints.len() as u64) as usize];
            if t != u as VertexId && !chosen.contains(&t) {
                chosen.push(t);
            }
        }
        for &v in &chosen {
            edges.push((u as VertexId, v));
            endpoints.push(u as VertexId);
            endpoints.push(v);
        }
    }
    edges
}

/// 2-D grid (rows × cols), 4-connected — road-network-like, high diameter.
pub fn grid_2d(rows: usize, cols: usize) -> Vec<(VertexId, VertexId)> {
    let mut edges = Vec::with_capacity(2 * rows * cols);
    let id = |r: usize, c: usize| (r * cols + c) as VertexId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((id(r, c), id(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((id(r, c), id(r + 1, c)));
            }
        }
    }
    edges
}

/// Directed cycle 0 -> 1 -> ... -> n-1 -> 0.
pub fn cycle(n: usize) -> Vec<(VertexId, VertexId)> {
    (0..n).map(|i| (i as VertexId, ((i + 1) % n) as VertexId)).collect()
}

/// Path 0 - 1 - ... - n-1.
pub fn path(n: usize) -> Vec<(VertexId, VertexId)> {
    (0..n - 1).map(|i| (i as VertexId, (i + 1) as VertexId)).collect()
}

/// Star: center 0 connected to 1..n-1.
pub fn star(n: usize) -> Vec<(VertexId, VertexId)> {
    (1..n).map(|i| (0, i as VertexId)).collect()
}

/// Complete graph on n vertices.
pub fn complete(n: usize) -> Vec<(VertexId, VertexId)> {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u as VertexId, v as VertexId));
        }
    }
    edges
}

/// Two cliques of size `half` joined by a single bridge edge — the classic
/// community-detection fixture (Louvain tests).
pub fn two_cliques(half: usize) -> Vec<(VertexId, VertexId)> {
    let mut edges = Vec::new();
    for u in 0..half {
        for v in (u + 1)..half {
            edges.push((u as VertexId, v as VertexId));
            edges.push(((u + half) as VertexId, (v + half) as VertexId));
        }
    }
    edges.push((0, half as VertexId));
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::csr::Csr;

    #[test]
    fn rmat_deterministic_and_in_range() {
        let e1 = rmat(10, 5000, 42);
        let e2 = rmat(10, 5000, 42);
        assert_eq!(e1, e2);
        assert!(e1.iter().all(|&(u, v)| u < 1024 && v < 1024));
        assert_ne!(e1, rmat(10, 5000, 43));
    }

    #[test]
    fn rmat_is_heavy_tailed() {
        // hub vertices should dominate: max out-degree far above mean
        let n = 1 << 12;
        let edges = rmat(12, n * 8, 7);
        let c = Csr::from_edges(n, &edges, true);
        let max_deg = (0..n as VertexId).map(|v| c.out_deg(v)).max().unwrap();
        let mean = c.num_edges() as f64 / n as f64;
        assert!(
            max_deg as f64 > 10.0 * mean,
            "max {max_deg} should be >> mean {mean:.1} for a power-law graph"
        );
    }

    #[test]
    fn erdos_renyi_is_not_heavy_tailed() {
        let n = 1 << 12;
        let edges = erdos_renyi(n, n * 8, 7);
        let c = Csr::from_edges(n, &edges, true);
        let max_deg = (0..n as VertexId).map(|v| c.out_deg(v)).max().unwrap();
        let mean = c.num_edges() as f64 / n as f64;
        assert!(
            (max_deg as f64) < 6.0 * mean,
            "ER max degree {max_deg} should stay near mean {mean:.1}"
        );
    }

    #[test]
    fn barabasi_albert_edge_count_and_connectivity() {
        let n = 500;
        let edges = barabasi_albert(n, 3, 1);
        let c = Csr::from_edges(n, &edges, false);
        // every non-seed vertex attaches to 3 distinct targets
        assert!(c.num_edges() >= 2 * 3 * (n as u64 - 4));
        // connected: BFS reaches everyone
        let mut seen = vec![false; n];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut cnt = 1;
        while let Some(v) = stack.pop() {
            for &w in c.out(v) {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    cnt += 1;
                    stack.push(w);
                }
            }
        }
        assert_eq!(cnt, n);
    }

    #[test]
    fn grid_shapes() {
        let edges = grid_2d(3, 4);
        // horizontal: 3*3, vertical: 2*4
        assert_eq!(edges.len(), 9 + 8);
        let c = Csr::from_edges(12, &edges, false);
        assert_eq!(c.out_deg(0), 2); // corner
        assert_eq!(c.out_deg(1), 3); // edge
        assert_eq!(c.out_deg(5), 4); // interior
    }

    #[test]
    fn deterministic_shapes() {
        assert_eq!(cycle(3), vec![(0, 1), (1, 2), (2, 0)]);
        assert_eq!(path(3), vec![(0, 1), (1, 2)]);
        assert_eq!(star(4), vec![(0, 1), (0, 2), (0, 3)]);
        assert_eq!(complete(4).len(), 6);
        let tc = two_cliques(3);
        assert_eq!(tc.len(), 3 + 3 + 1);
    }
}
