//! Edge-list → graph-image conversion.
//!
//! Produces the `.gy-idx`/`.gy-adj` pair ([`super::format`]) from an edge
//! list: sorts, removes self-loops and duplicates, packs sorted adjacency
//! records. Can emit to files (the normal path) or to RAM buffers — the
//! latter is how the Louvain §4.6 "best-case physical modification"
//! baseline measures rewrite cost without disk write throughput (the
//! paper used a DDR4 RAMDisk; an in-RAM re-pack measures the same bound).

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::graph::format::{GraphHeader, GraphIndex};
use crate::VertexId;

/// A built graph image held in memory.
pub struct RamImage {
    /// The in-memory index.
    pub index: GraphIndex,
    /// Packed adjacency bytes (`.gy-adj` contents).
    pub adj: Vec<u8>,
}

/// Edge-list to image builder.
pub struct GraphBuilder {
    num_vertices: usize,
    directed: bool,
    edges: Vec<(VertexId, VertexId)>,
    keep_self_loops: bool,
}

impl GraphBuilder {
    /// Start building a graph over `num_vertices` vertices.
    pub fn new(num_vertices: usize, directed: bool) -> Self {
        GraphBuilder { num_vertices, directed, edges: Vec::new(), keep_self_loops: false }
    }

    /// Add one edge (`u -> v`; for undirected graphs order is irrelevant).
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        debug_assert!((u as usize) < self.num_vertices && (v as usize) < self.num_vertices);
        self.edges.push((u, v));
        self
    }

    /// Bulk-add edges.
    pub fn add_edges(&mut self, edges: &[(VertexId, VertexId)]) -> &mut Self {
        self.edges.extend_from_slice(edges);
        self
    }

    /// Keep self loops (default: dropped).
    pub fn keep_self_loops(&mut self, keep: bool) -> &mut Self {
        self.keep_self_loops = keep;
        self
    }

    /// Number of raw (pre-dedup) edges added.
    pub fn raw_edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Build the image in RAM.
    pub fn build_ram(&self) -> RamImage {
        let n = self.num_vertices;
        // normalize: drop self loops, symmetrize if undirected, dedup
        let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(
            self.edges.len() * if self.directed { 1 } else { 2 },
        );
        for &(u, v) in &self.edges {
            if u == v && !self.keep_self_loops {
                continue;
            }
            edges.push((u, v));
            if !self.directed && u != v {
                edges.push((v, u));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let m = edges.len() as u64;

        // out-degree histogram + out lists (already src-sorted, dst ascending)
        let mut out_degs = vec![0u32; n];
        for &(u, _) in &edges {
            out_degs[u as usize] += 1;
        }
        // in lists: counting-sort by dst
        let mut in_degs = vec![0u32; n];
        if self.directed {
            for &(_, v) in &edges {
                in_degs[v as usize] += 1;
            }
        }
        let mut in_lists: Vec<Vec<VertexId>> = vec![Vec::new(); if self.directed { n } else { 0 }];
        if self.directed {
            for i in 0..n {
                in_lists[i] = Vec::with_capacity(in_degs[i] as usize);
            }
            for &(u, v) in &edges {
                in_lists[v as usize].push(u); // u ascending => sorted
            }
        }

        // pack records: [in][out]
        let mut adj =
            Vec::with_capacity(edges.len() * 4 * if self.directed { 2 } else { 1 });
        let mut offsets = Vec::with_capacity(n);
        let mut edge_cursor = 0usize;
        for v in 0..n {
            offsets.push(adj.len() as u64);
            if self.directed {
                for &u in &in_lists[v] {
                    adj.extend_from_slice(&u.to_le_bytes());
                }
            }
            let deg = out_degs[v] as usize;
            for &(_, dst) in &edges[edge_cursor..edge_cursor + deg] {
                adj.extend_from_slice(&dst.to_le_bytes());
            }
            edge_cursor += deg;
        }
        debug_assert_eq!(edge_cursor, edges.len());

        let header = GraphHeader {
            num_vertices: n as u64,
            num_edges: m,
            directed: self.directed,
        };
        let index = GraphIndex::new(header, offsets, in_degs, out_degs);
        RamImage { index, adj }
    }

    /// Build and write `<base>.gy-idx` / `<base>.gy-adj`.
    /// Returns the two paths.
    pub fn build_files(&self, base: &Path) -> crate::Result<(PathBuf, PathBuf)> {
        let img = self.build_ram();
        write_image(&img, base)
    }
}

/// Write a RAM image to `<base>.gy-idx` / `<base>.gy-adj`.
pub fn write_image(img: &RamImage, base: &Path) -> crate::Result<(PathBuf, PathBuf)> {
    let idx_path = base.with_extension("gy-idx");
    let adj_path = base.with_extension("gy-adj");
    if let Some(dir) = base.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(&idx_path)?;
    f.write_all(&img.index.encode())?;
    f.sync_all()?;
    let mut f = std::fs::File::create(&adj_path)?;
    f.write_all(&img.adj)?;
    f.sync_all()?;
    Ok((idx_path, adj_path))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::format::{EdgeRequest, VertexEdges};

    fn decode_vertex(img: &RamImage, v: VertexId) -> VertexEdges {
        let (off, len) = img.index.byte_range(v, EdgeRequest::Both);
        VertexEdges::decode(
            &img.adj[off as usize..off as usize + len],
            img.index.in_deg(v),
            img.index.out_deg(v),
            EdgeRequest::Both,
        )
    }

    #[test]
    fn directed_build_basic() {
        let mut b = GraphBuilder::new(4, true);
        b.add_edges(&[(0, 1), (0, 2), (1, 2), (2, 0), (3, 3), (0, 1)]); // dup + self loop
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 4); // dedup + loop dropped
        let v0 = decode_vertex(&img, 0);
        assert_eq!(v0.out_neighbors, vec![1, 2]);
        assert_eq!(v0.in_neighbors, vec![2]);
        let v2 = decode_vertex(&img, 2);
        assert_eq!(v2.in_neighbors, vec![0, 1]);
        assert_eq!(v2.out_neighbors, vec![0]);
        let v3 = decode_vertex(&img, 3);
        assert!(v3.in_neighbors.is_empty() && v3.out_neighbors.is_empty());
    }

    #[test]
    fn undirected_symmetrizes() {
        let mut b = GraphBuilder::new(3, false);
        b.add_edges(&[(0, 1), (2, 1)]);
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 4); // each undirected edge stored twice
        assert_eq!(decode_vertex(&img, 1).neighbors(), &[0, 2]);
        assert_eq!(decode_vertex(&img, 0).neighbors(), &[1]);
        assert_eq!(img.index.in_deg(1), 0, "undirected images keep in_deg 0");
        assert_eq!(img.index.out_deg(1), 2);
    }

    #[test]
    fn neighbor_lists_sorted() {
        let mut b = GraphBuilder::new(10, true);
        b.add_edges(&[(5, 9), (5, 1), (5, 4), (5, 0), (9, 5), (0, 5), (3, 5)]);
        let img = b.build_ram();
        let v5 = decode_vertex(&img, 5);
        assert_eq!(v5.out_neighbors, vec![0, 1, 4, 9]);
        assert_eq!(v5.in_neighbors, vec![0, 3, 9]);
    }

    #[test]
    fn file_roundtrip() {
        let mut b = GraphBuilder::new(5, true);
        b.add_edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (0, 3)]);
        let ram = b.build_ram();
        let base = std::env::temp_dir().join(format!("graphyti-builder-{}", std::process::id()));
        let (idx_path, adj_path) = b.build_files(&base).unwrap();
        let idx_bytes = std::fs::read(&idx_path).unwrap();
        let adj_bytes = std::fs::read(&adj_path).unwrap();
        let idx = GraphIndex::decode(&idx_bytes).unwrap();
        assert_eq!(idx.num_vertices(), 5);
        assert_eq!(idx.num_edges(), 6);
        assert_eq!(adj_bytes, ram.adj);
        let _ = std::fs::remove_file(idx_path);
        let _ = std::fs::remove_file(adj_path);
    }

    #[test]
    fn empty_graph() {
        let img = GraphBuilder::new(3, false).build_ram();
        assert_eq!(img.index.num_edges(), 0);
        assert!(img.adj.is_empty());
        for v in 0..3 {
            assert_eq!(img.index.degree(v), 0);
        }
    }

    #[test]
    fn self_loops_kept_when_asked() {
        let mut b = GraphBuilder::new(2, true);
        b.keep_self_loops(true).add_edges(&[(0, 0), (0, 1)]);
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 2);
        assert_eq!(decode_vertex(&img, 0).out_neighbors, vec![0, 1]);
    }
}
