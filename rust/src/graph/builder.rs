//! Edge-list → graph-image conversion, and image ↔ image format
//! conversion.
//!
//! [`GraphBuilder`] produces the `.gy-idx`/`.gy-adj` pair
//! ([`super::format`]) from an edge list: sorts, removes self-loops and
//! duplicates, packs sorted adjacency records in either format version
//! (v1 fixed-width by default; v2 delta+varint via
//! [`GraphBuilder::format_version`]). It can emit to files (the normal
//! path) or to RAM buffers — the latter is how the Louvain §4.6
//! "best-case physical modification" baseline measures rewrite cost
//! without disk write throughput (the paper used a DDR4 RAMDisk; an
//! in-RAM re-pack measures the same bound).
//!
//! [`convert_image`] / [`convert_ram`] rewrite an existing image into
//! the other format version without re-sorting: each vertex's records
//! are decoded with the source encoding and re-packed with the target's,
//! preserving vertex ids, edge order and the header's graph metadata.
//! Converting v1 → v2 → v1 is byte-identical.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure};

use crate::graph::format::{
    ChecksumFooter, EdgeRequest, GraphHeader, GraphIndex, PageCrcAccumulator, VertexEdges,
    VERSION_V1, VERSION_V2,
};
use crate::graph::varint;
use crate::VertexId;

/// A built graph image held in memory.
pub struct RamImage {
    /// The in-memory index.
    pub index: GraphIndex,
    /// Packed adjacency bytes (`.gy-adj` contents).
    pub adj: Vec<u8>,
}

/// Edge-list to image builder.
pub struct GraphBuilder {
    num_vertices: usize,
    directed: bool,
    edges: Vec<(VertexId, VertexId)>,
    keep_self_loops: bool,
    format_version: u32,
    checksums: bool,
}

impl GraphBuilder {
    /// Start building a graph over `num_vertices` vertices. The image is
    /// written as format v1 unless [`Self::format_version`] says
    /// otherwise.
    pub fn new(num_vertices: usize, directed: bool) -> Self {
        GraphBuilder {
            num_vertices,
            directed,
            edges: Vec::new(),
            keep_self_loops: false,
            format_version: VERSION_V1,
            checksums: true,
        }
    }

    /// Add one edge (`u -> v`; for undirected graphs order is irrelevant).
    pub fn add_edge(&mut self, u: VertexId, v: VertexId) -> &mut Self {
        debug_assert!((u as usize) < self.num_vertices && (v as usize) < self.num_vertices);
        self.edges.push((u, v));
        self
    }

    /// Bulk-add edges.
    pub fn add_edges(&mut self, edges: &[(VertexId, VertexId)]) -> &mut Self {
        self.edges.extend_from_slice(edges);
        self
    }

    /// Keep self loops (default: dropped).
    pub fn keep_self_loops(&mut self, keep: bool) -> &mut Self {
        self.keep_self_loops = keep;
        self
    }

    /// Select the on-disk format version: [`VERSION_V1`] (fixed-width
    /// `u32` neighbors, the default) or [`VERSION_V2`] (delta+varint
    /// compressed sections, ~3x smaller on real graphs).
    ///
    /// Panics on any other value.
    pub fn format_version(&mut self, version: u32) -> &mut Self {
        assert!(
            version == VERSION_V1 || version == VERSION_V2,
            "unknown format version {version}"
        );
        self.format_version = version;
        self
    }

    /// Write per-page crc32c checksum footers on both image files
    /// (default: on — new images are born verified; `--no-checksums`
    /// on the CLI routes here). RAM images never carry footers; the
    /// flag only controls what [`write_image`] appends and sets the
    /// header bit readers use to look for the footer.
    pub fn checksums(&mut self, on: bool) -> &mut Self {
        self.checksums = on;
        self
    }

    /// Number of raw (pre-dedup) edges added.
    pub fn raw_edge_count(&self) -> usize {
        self.edges.len()
    }

    /// Build the image in RAM.
    pub fn build_ram(&self) -> RamImage {
        let n = self.num_vertices;
        // normalize: drop self loops, symmetrize if undirected, dedup
        let mut edges: Vec<(VertexId, VertexId)> = Vec::with_capacity(
            self.edges.len() * if self.directed { 1 } else { 2 },
        );
        for &(u, v) in &self.edges {
            if u == v && !self.keep_self_loops {
                continue;
            }
            edges.push((u, v));
            if !self.directed && u != v {
                edges.push((v, u));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let m = edges.len() as u64;

        // out-degree histogram + out lists (already src-sorted, dst ascending)
        let mut out_degs = vec![0u32; n];
        for &(u, _) in &edges {
            out_degs[u as usize] += 1;
        }
        // in lists: counting-sort by dst
        let mut in_degs = vec![0u32; n];
        if self.directed {
            for &(_, v) in &edges {
                in_degs[v as usize] += 1;
            }
        }
        let mut in_lists: Vec<Vec<VertexId>> = vec![Vec::new(); if self.directed { n } else { 0 }];
        if self.directed {
            for i in 0..n {
                in_lists[i] = Vec::with_capacity(in_degs[i] as usize);
            }
            for &(u, v) in &edges {
                in_lists[v as usize].push(u); // u ascending => sorted
            }
        }

        // pack records: [in-section][out-section], in the chosen encoding
        let v2 = self.format_version == VERSION_V2;
        let mut adj =
            Vec::with_capacity(edges.len() * 4 * if self.directed { 2 } else { 1 });
        let mut offsets = Vec::with_capacity(n);
        let mut in_bytes = Vec::with_capacity(if v2 { n } else { 0 });
        let mut out_bytes = Vec::with_capacity(if v2 { n } else { 0 });
        let mut scratch: Vec<VertexId> = Vec::new();
        let mut edge_cursor = 0usize;
        for v in 0..n {
            offsets.push(adj.len() as u64);
            let deg = out_degs[v] as usize;
            scratch.clear();
            scratch.extend(edges[edge_cursor..edge_cursor + deg].iter().map(|&(_, d)| d));
            let ins: &[VertexId] = if self.directed { &in_lists[v] } else { &[] };
            let (ib, ob) = pack_record(ins, &scratch, self.format_version, &mut adj);
            if v2 {
                in_bytes.push(ib);
                out_bytes.push(ob);
            }
            edge_cursor += deg;
        }
        debug_assert_eq!(edge_cursor, edges.len());

        let header = GraphHeader {
            num_vertices: n as u64,
            num_edges: m,
            directed: self.directed,
            version: self.format_version,
            checksums: self.checksums,
        };
        let index = assemble_index(header, offsets, in_degs, out_degs, in_bytes, out_bytes);
        RamImage { index, adj }
    }

    /// Build and write `<base>.gy-idx` / `<base>.gy-adj`.
    /// Returns the two paths.
    pub fn build_files(&self, base: &Path) -> crate::Result<(PathBuf, PathBuf)> {
        let img = self.build_ram();
        write_image(&img, base)
    }
}

/// Append one vertex's `[in-section][out-section]` record to `adj` in
/// the given format version; returns the two section byte lengths.
/// This is the single definition of record packing — the builder and
/// both converters call it, so the encodings cannot drift apart.
fn pack_record(
    ins: &[VertexId],
    outs: &[VertexId],
    version: u32,
    adj: &mut Vec<u8>,
) -> (u32, u32) {
    if version == VERSION_V2 {
        let start = adj.len();
        varint::encode_deltas(ins, adj);
        let in_bytes = (adj.len() - start) as u32;
        let start = adj.len();
        varint::encode_deltas(outs, adj);
        (in_bytes, (adj.len() - start) as u32)
    } else {
        for &u in ins.iter().chain(outs) {
            adj.extend_from_slice(&u.to_le_bytes());
        }
        (ins.len() as u32 * 4, outs.len() as u32 * 4)
    }
}

/// Assemble a [`GraphIndex`] for a freshly packed image, picking the
/// entry layout from `header.version`; the `*_bytes` columns are only
/// consumed for v2 (pass empty vectors for v1). Single definition of
/// index assembly shared by the builder and both converters.
fn assemble_index(
    header: GraphHeader,
    offsets: Vec<u64>,
    in_degs: Vec<u32>,
    out_degs: Vec<u32>,
    in_bytes: Vec<u32>,
    out_bytes: Vec<u32>,
) -> GraphIndex {
    if header.version == VERSION_V2 {
        GraphIndex::new_v2(header, offsets, in_degs, out_degs, in_bytes, out_bytes)
    } else {
        GraphIndex::new(header, offsets, in_degs, out_degs)
    }
}

/// Write a RAM image to `<base>.gy-idx` / `<base>.gy-adj`. When the
/// header's checksum flag is set, each file gets a per-page crc32c
/// footer appended after its data bytes (FORMAT.md §5); the data
/// layout itself is byte-identical either way.
pub fn write_image(img: &RamImage, base: &Path) -> crate::Result<(PathBuf, PathBuf)> {
    let idx_path = base.with_extension("gy-idx");
    let adj_path = base.with_extension("gy-adj");
    if let Some(dir) = base.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let checksums = img.index.header().checksums;
    let mut f = std::fs::File::create(&idx_path)?;
    let idx_bytes = img.index.encode();
    f.write_all(&idx_bytes)?;
    if checksums {
        f.write_all(&ChecksumFooter::compute(&idx_bytes).encode())?;
    }
    f.sync_all()?;
    let mut f = std::fs::File::create(&adj_path)?;
    f.write_all(&img.adj)?;
    if checksums {
        f.write_all(&ChecksumFooter::compute(&img.adj).encode())?;
    }
    f.sync_all()?;
    Ok((idx_path, adj_path))
}

/// Re-pack a RAM image into `target_version`, preserving the graph
/// exactly (same vertex ids, same sorted neighbor lists, same header
/// metadata). Converting an image to its own version rebuilds it
/// byte-identically.
pub fn convert_ram(img: &RamImage, target_version: u32) -> crate::Result<RamImage> {
    if target_version != VERSION_V1 && target_version != VERSION_V2 {
        bail!("unknown target format version {target_version}");
    }
    let src = &img.index;
    let n = src.num_vertices();
    let src_enc = src.encoding();
    let v2 = target_version == VERSION_V2;
    let mut adj = Vec::with_capacity(img.adj.len());
    let mut offsets = Vec::with_capacity(n);
    let mut in_degs = Vec::with_capacity(n);
    let mut out_degs = Vec::with_capacity(n);
    let mut in_bytes = Vec::with_capacity(if v2 { n } else { 0 });
    let mut out_bytes = Vec::with_capacity(if v2 { n } else { 0 });
    let mut ve = VertexEdges::default();
    for v in 0..n as VertexId {
        let (off, len) = src.byte_range(v, EdgeRequest::Both);
        let (off, end) = (off as usize, off as usize + len);
        ensure!(end <= img.adj.len(), "adjacency truncated at vertex {v}");
        let record = &img.adj[off..end];
        ve.decode_into(record, src.in_deg(v), src.out_deg(v), EdgeRequest::Both, src_enc);
        offsets.push(adj.len() as u64);
        in_degs.push(ve.in_neighbors.len() as u32);
        out_degs.push(ve.out_neighbors.len() as u32);
        let (ib, ob) = pack_record(&ve.in_neighbors, &ve.out_neighbors, target_version, &mut adj);
        if v2 {
            in_bytes.push(ib);
            out_bytes.push(ob);
        }
    }
    let header = GraphHeader { version: target_version, ..*src.header() };
    let index = assemble_index(header, offsets, in_degs, out_degs, in_bytes, out_bytes);
    Ok(RamImage { index, adj })
}

/// Read the image at `<src_base>.gy-idx/.gy-adj`, re-pack it into
/// `target_version`, and write it to `<dst_base>.gy-idx/.gy-adj` with
/// checksum footers (the default for newly written images). See
/// [`convert_image_opts`] to opt out.
pub fn convert_image(
    src_base: &Path,
    dst_base: &Path,
    target_version: u32,
) -> crate::Result<(PathBuf, PathBuf)> {
    convert_image_opts(src_base, dst_base, target_version, true)
}

/// Read the image at `<src_base>.gy-idx/.gy-adj`, re-pack it into
/// `target_version`, and write it to `<dst_base>.gy-idx/.gy-adj`.
/// Returns the two written paths. The source image may be either
/// version, with or without checksum footers; `checksums` selects
/// whether the destination gets them (its data bytes are identical
/// either way, so checksummed ↔ plain conversion round-trips the data
/// byte-identically).
///
/// Conversion **streams** the adjacency: records are read, re-encoded
/// and written one vertex at a time through buffered I/O, so edge
/// memory stays O(max record), never O(m) — images far larger than RAM
/// convert fine, in keeping with the SEM contract. Only the O(n) index
/// columns are held in memory (exactly what opening the image costs);
/// destination page crcs accumulate in a streaming window, never a
/// second copy of the adjacency.
pub fn convert_image_opts(
    src_base: &Path,
    dst_base: &Path,
    target_version: u32,
    checksums: bool,
) -> crate::Result<(PathBuf, PathBuf)> {
    use std::io::{BufReader, BufWriter, Read};

    if target_version != VERSION_V1 && target_version != VERSION_V2 {
        bail!("unknown target format version {target_version}");
    }
    let src = GraphIndex::decode(&std::fs::read(src_base.with_extension("gy-idx"))?)?;
    let src_enc = src.encoding();
    let n = src.num_vertices();
    let v2 = target_version == VERSION_V2;

    let adj_path = src_base.with_extension("gy-adj");
    let adj_len = std::fs::metadata(&adj_path)?.len();
    let total: u64 = (0..n as VertexId).map(|v| src.record_len(v) as u64).sum();
    ensure!(
        total <= adj_len,
        "adjacency truncated: index promises {total} bytes, file has {adj_len}"
    );
    let mut reader = BufReader::new(std::fs::File::open(&adj_path)?);

    let dst_idx = dst_base.with_extension("gy-idx");
    let dst_adj = dst_base.with_extension("gy-adj");
    // refuse in-place conversion: creating the destination would
    // truncate the very files we are streaming from, destroying the
    // source image before anything useful is written
    let same_file = |a: &Path, b: &Path| {
        a.exists()
            && b.exists()
            && std::fs::canonicalize(a).ok() == std::fs::canonicalize(b).ok()
    };
    ensure!(
        !same_file(&dst_adj, &adj_path)
            && !same_file(&dst_idx, &src_base.with_extension("gy-idx")),
        "conversion target must differ from the source image (in-place \
         conversion would destroy it)"
    );
    if let Some(dir) = dst_base.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let adj_file = std::fs::File::create(&dst_adj)?;
    let mut writer = BufWriter::new(&adj_file);

    let mut offsets = Vec::with_capacity(n);
    let mut in_degs = Vec::with_capacity(n);
    let mut out_degs = Vec::with_capacity(n);
    let mut in_bytes = Vec::with_capacity(if v2 { n } else { 0 });
    let mut out_bytes = Vec::with_capacity(if v2 { n } else { 0 });
    let mut record = Vec::new();
    let mut packed = Vec::new();
    let mut ve = VertexEdges::default();
    let mut written = 0u64;
    let mut consumed = 0u64;
    let mut adj_crcs = PageCrcAccumulator::new();
    for v in 0..n as VertexId {
        // records must tile the file (FORMAT.md §3) for sequential reads
        // to line up with the index's offsets
        ensure!(
            src.byte_range(v, EdgeRequest::Both).0 == consumed,
            "non-contiguous adjacency record at vertex {v}"
        );
        record.resize(src.record_len(v), 0);
        reader.read_exact(&mut record)?;
        consumed += record.len() as u64;
        ve.decode_into(&record, src.in_deg(v), src.out_deg(v), EdgeRequest::Both, src_enc);
        offsets.push(written);
        in_degs.push(ve.in_neighbors.len() as u32);
        out_degs.push(ve.out_neighbors.len() as u32);
        packed.clear();
        let (ib, ob) =
            pack_record(&ve.in_neighbors, &ve.out_neighbors, target_version, &mut packed);
        if v2 {
            in_bytes.push(ib);
            out_bytes.push(ob);
        }
        writer.write_all(&packed)?;
        if checksums {
            adj_crcs.update(&packed);
        }
        written += packed.len() as u64;
    }
    writer.flush()?;
    drop(writer);
    if checksums {
        let (data_len, crcs) = adj_crcs.finish();
        debug_assert_eq!(data_len, written);
        (&adj_file).write_all(&ChecksumFooter::from_parts(data_len, crcs).encode())?;
    }
    adj_file.sync_all()?;

    let header = GraphHeader { version: target_version, checksums, ..*src.header() };
    let index = assemble_index(header, offsets, in_degs, out_degs, in_bytes, out_bytes);
    let mut f = std::fs::File::create(&dst_idx)?;
    let idx_bytes = index.encode();
    f.write_all(&idx_bytes)?;
    if checksums {
        f.write_all(&ChecksumFooter::compute(&idx_bytes).encode())?;
    }
    f.sync_all()?;
    Ok((dst_idx, dst_adj))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::format::{EdgeRequest, VertexEdges};

    fn decode_vertex(img: &RamImage, v: VertexId) -> VertexEdges {
        let (off, len) = img.index.byte_range(v, EdgeRequest::Both);
        VertexEdges::decode(
            &img.adj[off as usize..off as usize + len],
            img.index.in_deg(v),
            img.index.out_deg(v),
            EdgeRequest::Both,
            img.index.encoding(),
        )
    }

    #[test]
    fn directed_build_basic() {
        let mut b = GraphBuilder::new(4, true);
        b.add_edges(&[(0, 1), (0, 2), (1, 2), (2, 0), (3, 3), (0, 1)]); // dup + self loop
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 4); // dedup + loop dropped
        let v0 = decode_vertex(&img, 0);
        assert_eq!(v0.out_neighbors, vec![1, 2]);
        assert_eq!(v0.in_neighbors, vec![2]);
        let v2 = decode_vertex(&img, 2);
        assert_eq!(v2.in_neighbors, vec![0, 1]);
        assert_eq!(v2.out_neighbors, vec![0]);
        let v3 = decode_vertex(&img, 3);
        assert!(v3.in_neighbors.is_empty() && v3.out_neighbors.is_empty());
    }

    #[test]
    fn undirected_symmetrizes() {
        let mut b = GraphBuilder::new(3, false);
        b.add_edges(&[(0, 1), (2, 1)]);
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 4); // each undirected edge stored twice
        assert_eq!(decode_vertex(&img, 1).neighbors(), &[0, 2]);
        assert_eq!(decode_vertex(&img, 0).neighbors(), &[1]);
        assert_eq!(img.index.in_deg(1), 0, "undirected images keep in_deg 0");
        assert_eq!(img.index.out_deg(1), 2);
    }

    #[test]
    fn neighbor_lists_sorted() {
        let mut b = GraphBuilder::new(10, true);
        b.add_edges(&[(5, 9), (5, 1), (5, 4), (5, 0), (9, 5), (0, 5), (3, 5)]);
        let img = b.build_ram();
        let v5 = decode_vertex(&img, 5);
        assert_eq!(v5.out_neighbors, vec![0, 1, 4, 9]);
        assert_eq!(v5.in_neighbors, vec![0, 3, 9]);
    }

    #[test]
    fn file_roundtrip() {
        let mut b = GraphBuilder::new(5, true);
        b.add_edges(&[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (0, 3)]);
        let ram = b.build_ram();
        let base = std::env::temp_dir().join(format!("graphyti-builder-{}", std::process::id()));
        let (idx_path, adj_path) = b.build_files(&base).unwrap();
        let idx_bytes = std::fs::read(&idx_path).unwrap();
        let adj_bytes = std::fs::read(&adj_path).unwrap();
        let idx = GraphIndex::decode(&idx_bytes).unwrap();
        assert_eq!(idx.num_vertices(), 5);
        assert_eq!(idx.num_edges(), 6);
        // files carry checksum footers by default: the data prefix is
        // the RAM image, the footer verifies every data page
        assert!(idx.header().checksums);
        assert_eq!(&adj_bytes[..ram.adj.len()], &ram.adj[..]);
        let adj_footer = ChecksumFooter::from_bytes(&adj_bytes).unwrap();
        assert_eq!(adj_footer.data_len as usize, ram.adj.len());
        assert!(adj_footer.page_ok(0, &ram.adj));
        let idx_footer = ChecksumFooter::from_bytes(&idx_bytes).unwrap();
        assert!(idx_footer.page_ok(0, &idx_bytes[..idx_footer.data_len as usize]));
        let _ = std::fs::remove_file(idx_path);
        let _ = std::fs::remove_file(adj_path);
    }

    #[test]
    fn no_checksums_opt_out_writes_bare_files() {
        let mut b = GraphBuilder::new(5, true);
        b.add_edges(&[(0, 1), (1, 2), (2, 3)]).checksums(false);
        let ram = b.build_ram();
        assert!(!ram.index.header().checksums);
        let base = std::env::temp_dir()
            .join(format!("graphyti-builder-plain-{}", std::process::id()));
        let (idx_path, adj_path) = b.build_files(&base).unwrap();
        let adj_bytes = std::fs::read(&adj_path).unwrap();
        assert_eq!(adj_bytes, ram.adj, "opt-out must write exactly the data bytes");
        let idx = GraphIndex::decode(&std::fs::read(&idx_path).unwrap()).unwrap();
        assert!(!idx.header().checksums);
        let _ = std::fs::remove_file(idx_path);
        let _ = std::fs::remove_file(adj_path);
    }

    #[test]
    fn convert_checksummed_and_plain_roundtrip_data_identically() {
        let edges = crate::graph::gen::rmat(7, 900, 21);
        let mut b = GraphBuilder::new(128, true);
        b.add_edges(&edges);
        let src = std::env::temp_dir()
            .join(format!("graphyti-convert-ck-src-{}", std::process::id()));
        let plain = std::env::temp_dir()
            .join(format!("graphyti-convert-ck-plain-{}", std::process::id()));
        let back = std::env::temp_dir()
            .join(format!("graphyti-convert-ck-back-{}", std::process::id()));
        b.build_files(&src).unwrap();
        let src_adj = std::fs::read(src.with_extension("gy-adj")).unwrap();
        let src_footer = ChecksumFooter::from_bytes(&src_adj).unwrap();
        // checksummed -> plain: data bytes survive, footer dropped
        convert_image_opts(&src, &plain, VERSION_V1, false).unwrap();
        let plain_adj = std::fs::read(plain.with_extension("gy-adj")).unwrap();
        assert_eq!(plain_adj, src_adj[..src_footer.data_len as usize]);
        assert!(ChecksumFooter::from_bytes(&plain_adj).is_err());
        // plain -> checksummed: whole files byte-identical to the source
        convert_image_opts(&plain, &back, VERSION_V1, true).unwrap();
        assert_eq!(std::fs::read(back.with_extension("gy-adj")).unwrap(), src_adj);
        assert_eq!(
            std::fs::read(back.with_extension("gy-idx")).unwrap(),
            std::fs::read(src.with_extension("gy-idx")).unwrap()
        );
        for b in [&src, &plain, &back] {
            let _ = std::fs::remove_file(b.with_extension("gy-idx"));
            let _ = std::fs::remove_file(b.with_extension("gy-adj"));
        }
    }

    #[test]
    fn empty_graph() {
        let img = GraphBuilder::new(3, false).build_ram();
        assert_eq!(img.index.num_edges(), 0);
        assert!(img.adj.is_empty());
        for v in 0..3 {
            assert_eq!(img.index.degree(v), 0);
        }
    }

    #[test]
    fn self_loops_kept_when_asked() {
        let mut b = GraphBuilder::new(2, true);
        b.keep_self_loops(true).add_edges(&[(0, 0), (0, 1)]);
        let img = b.build_ram();
        assert_eq!(img.index.num_edges(), 2);
        assert_eq!(decode_vertex(&img, 0).out_neighbors, vec![0, 1]);
    }

    #[test]
    fn v2_build_matches_v1_lists_and_is_smaller() {
        let edges = crate::graph::gen::rmat(9, 5000, 17);
        let mut b1 = GraphBuilder::new(512, true);
        b1.add_edges(&edges);
        let v1 = b1.build_ram();
        let mut b2 = GraphBuilder::new(512, true);
        b2.add_edges(&edges).format_version(VERSION_V2);
        let v2 = b2.build_ram();
        assert_eq!(v2.index.header().version, VERSION_V2);
        assert_eq!(v1.index.num_edges(), v2.index.num_edges());
        for v in 0..512u32 {
            let a = decode_vertex(&v1, v);
            let b = decode_vertex(&v2, v);
            assert_eq!(a.in_neighbors, b.in_neighbors, "v={v}");
            assert_eq!(a.out_neighbors, b.out_neighbors, "v={v}");
        }
        assert!(
            v2.adj.len() * 2 < v1.adj.len(),
            "delta+varint should at least halve RMAT adjacency: v1={} v2={}",
            v1.adj.len(),
            v2.adj.len()
        );
    }

    #[test]
    fn v2_handles_self_loops_and_undirected() {
        let mut b = GraphBuilder::new(4, false);
        b.format_version(VERSION_V2).keep_self_loops(true);
        b.add_edges(&[(0, 0), (0, 1), (2, 1), (3, 0)]);
        let img = b.build_ram();
        assert_eq!(decode_vertex(&img, 0).neighbors(), &[0, 1, 3]);
        assert_eq!(decode_vertex(&img, 1).neighbors(), &[0, 2]);
        assert_eq!(img.index.in_deg(0), 0);
    }

    #[test]
    fn convert_roundtrip_is_byte_identical() {
        let edges = crate::graph::gen::rmat(8, 2000, 5);
        let mut b = GraphBuilder::new(256, true);
        b.add_edges(&edges);
        let v1 = b.build_ram();
        let v2 = convert_ram(&v1, VERSION_V2).unwrap();
        assert_eq!(v2.index.header().version, VERSION_V2);
        assert!(v2.adj.len() < v1.adj.len());
        let back = convert_ram(&v2, VERSION_V1).unwrap();
        assert_eq!(back.adj, v1.adj, "v1 -> v2 -> v1 must restore the adjacency bytes");
        assert_eq!(back.index.encode(), v1.index.encode(), "and the index bytes");
        // converting to one's own version is the identity
        let same = convert_ram(&v2, VERSION_V2).unwrap();
        assert_eq!(same.adj, v2.adj);
        assert_eq!(same.index.encode(), v2.index.encode());
        // direct v2 build and converted v2 agree byte-for-byte
        let mut b2 = GraphBuilder::new(256, true);
        b2.add_edges(&edges).format_version(VERSION_V2);
        let built = b2.build_ram();
        assert_eq!(built.adj, v2.adj);
        assert_eq!(built.index.encode(), v2.index.encode());
    }

    #[test]
    fn convert_rejects_unknown_target() {
        let img = GraphBuilder::new(2, true).build_ram();
        assert!(convert_ram(&img, 7).is_err());
    }

    #[test]
    fn convert_refuses_in_place_and_leaves_source_intact() {
        let mut b = GraphBuilder::new(8, true);
        b.add_edges(&[(0, 1), (1, 2), (2, 3)]);
        let src = std::env::temp_dir()
            .join(format!("graphyti-convert-inplace-{}", std::process::id()));
        b.build_files(&src).unwrap();
        let before = std::fs::read(src.with_extension("gy-adj")).unwrap();
        assert!(convert_image(&src, &src, VERSION_V2).is_err());
        assert_eq!(
            std::fs::read(src.with_extension("gy-adj")).unwrap(),
            before,
            "a refused in-place convert must not touch the source"
        );
        assert!(GraphIndex::decode(&std::fs::read(src.with_extension("gy-idx")).unwrap()).is_ok());
        let _ = std::fs::remove_file(src.with_extension("gy-idx"));
        let _ = std::fs::remove_file(src.with_extension("gy-adj"));
    }

    #[test]
    fn convert_image_files() {
        let edges = crate::graph::gen::rmat(7, 800, 9);
        let mut b = GraphBuilder::new(128, true);
        b.add_edges(&edges);
        let src = std::env::temp_dir()
            .join(format!("graphyti-convert-src-{}", std::process::id()));
        let dst = std::env::temp_dir()
            .join(format!("graphyti-convert-dst-{}", std::process::id()));
        b.build_files(&src).unwrap();
        let (idx, adj) = convert_image(&src, &dst, VERSION_V2).unwrap();
        let v2_idx = GraphIndex::decode(&std::fs::read(&idx).unwrap()).unwrap();
        assert_eq!(v2_idx.header().version, VERSION_V2);
        assert_eq!(v2_idx.num_edges(), b.build_ram().index.num_edges());
        let v1_adj = std::fs::metadata(src.with_extension("gy-adj")).unwrap().len();
        let v2_adj = std::fs::metadata(&adj).unwrap().len();
        assert!(v2_adj < v1_adj);
        for p in [
            src.with_extension("gy-idx"),
            src.with_extension("gy-adj"),
            idx,
            adj,
        ] {
            let _ = std::fs::remove_file(p);
        }
    }
}
