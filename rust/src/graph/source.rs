//! [`EdgeSource`] — where the engine gets edge data.
//!
//! The paper's headline experiment compares the *same* algorithm running
//! semi-externally (edges on disk behind a page cache) vs fully
//! in-memory. Both modes implement this trait, so every algorithm runs
//! unchanged in either mode:
//!
//! * [`SemGraph`] — the SEM data plane: in-memory [`GraphIndex`] (O(n)) +
//!   a [`SemFile`] adjacency file read through the page cache (O(m) on
//!   disk).
//! * [`MemGraph`] — the in-memory baseline: the same packed image held in
//!   RAM; fetches decode straight from the buffer.
//!
//! Both sources are format-version agnostic: the image header selects
//! the record encoding (v1 fixed-width or v2 delta+varint, see
//! [`crate::graph::format`]) and every fetch decodes with
//! [`GraphIndex::encoding`]. A v2 image reads proportionally fewer
//! bytes per fetch — the compression shows up directly in
//! `logical_bytes`/`bytes_read` of [`crate::safs::IoStats`].

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context};

use crate::graph::builder::RamImage;
use crate::graph::format::{
    ChecksumFooter, EdgeRequest, GraphIndex, VertexEdges, CHECKSUM_PAGE,
};
use crate::safs::{
    IoConfig, IoPool, IoStats, PageCache, PageChecksums, PendingRead, RangeBuf, RangeScratch,
    SemFile,
};
use crate::VertexId;

/// Per-worker reusable fetch state: the engine's steady-state
/// allocation-free path.
///
/// One arena lives on each engine worker thread and is threaded through
/// [`EdgeSource::fetch_batch_into`] every batch. It owns
///
/// * the decoded [`VertexEdges`] for the current batch (neighbor vectors
///   reused across batches — capacity converges to the largest record
///   seen, then decoding allocates nothing),
/// * the batch's byte ranges and [`RangeBuf`] views, and
/// * the [`RangeScratch`] the SEM read path assembles page-spanning
///   ranges from.
///
/// [`Self::allocs`] counts every heap allocation performed through the
/// arena; a steady-state batch over cached pages keeps it flat — the
/// property the hot-path tests assert.
#[derive(Default)]
pub struct FetchArena {
    /// Decoded edges; `edges[..batch_len]` is the current batch.
    edges: Vec<VertexEdges>,
    batch_len: usize,
    ranges: Vec<(u64, usize)>,
    bufs: Vec<RangeBuf>,
    scratch: RangeScratch,
    allocs: u64,
}

impl FetchArena {
    /// Fresh arena with no retained buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// The decoded edges of the most recent batch, aligned with the
    /// request slice passed to [`EdgeSource::fetch_batch_into`].
    pub fn edges(&self) -> &[VertexEdges] {
        &self.edges[..self.batch_len]
    }

    /// Cumulative heap allocations performed through the arena
    /// (neighbor-vector growth, range scratch, bookkeeping vectors).
    /// Flat across batches in steady state.
    pub fn allocs(&self) -> u64 {
        self.allocs + self.scratch.allocs()
    }

    /// Make `edges[..n]` valid, reusing existing slots.
    fn prepare(&mut self, n: usize) {
        let cap = self.edges.capacity();
        while self.edges.len() < n {
            self.edges.push(VertexEdges::default());
        }
        if self.edges.capacity() != cap {
            self.allocs += 1;
        }
        self.batch_len = n;
    }

    /// Decode the batch's fetched [`RangeBuf`]s into the edge slots
    /// (SEM path). `self.bufs` must be index-aligned with `reqs`.
    fn decode_bufs(&mut self, reqs: &[(VertexId, EdgeRequest)], index: &GraphIndex) {
        self.prepare(reqs.len());
        let enc = index.encoding();
        let FetchArena { edges, bufs, allocs, .. } = self;
        for (i, &(v, r)) in reqs.iter().enumerate() {
            decode_record(&mut edges[i], allocs, bufs[i].as_slice(), index, v, r, enc);
        }
    }

    /// Decode the batch straight out of a RAM image (in-memory path).
    fn decode_image(&mut self, reqs: &[(VertexId, EdgeRequest)], index: &GraphIndex, adj: &[u8]) {
        self.prepare(reqs.len());
        let enc = index.encoding();
        let FetchArena { edges, allocs, .. } = self;
        for (i, &(v, r)) in reqs.iter().enumerate() {
            let (off, len) = index.byte_range(v, r);
            let bytes = &adj[off as usize..off as usize + len];
            decode_record(&mut edges[i], allocs, bytes, index, v, r, enc);
        }
    }

    /// Install an owned batch (used by the trait's fallback path).
    fn set_batch(&mut self, edges: Vec<VertexEdges>) {
        self.batch_len = edges.len();
        self.allocs += 1; // owned batches are inherently allocating
        self.edges = edges;
    }
}

/// Decode one record into an arena slot, counting neighbor-vector
/// growth into the arena's allocation counter. The single accounting
/// point for both the SEM and in-memory decode paths — keep them in
/// lockstep or the steady-state zero-alloc contract diverges.
fn decode_record(
    e: &mut VertexEdges,
    allocs: &mut u64,
    bytes: &[u8],
    index: &GraphIndex,
    v: VertexId,
    r: EdgeRequest,
    enc: crate::graph::format::EdgeEncoding,
) {
    let (ci, co) = (e.in_neighbors.capacity(), e.out_neighbors.capacity());
    e.decode_into(bytes, index.in_deg(v), index.out_deg(v), r, enc);
    if e.in_neighbors.capacity() != ci {
        *allocs += 1;
    }
    if e.out_neighbors.capacity() != co {
        *allocs += 1;
    }
}

/// One unit of the engine's overlapped fetch pipeline: a batch of
/// requests, the per-slot [`FetchArena`] its results decode into, and
/// (for SEM sources) the in-flight I/O between
/// [`EdgeSource::submit_batch`] and [`EdgeSource::finish_batch`].
///
/// Engine workers keep a small ring of slots: fill `reqs`, submit, keep
/// filling/submitting further slots while earlier ones' pages land, and
/// finish whichever completes first. Slots are reused across batches so
/// the steady state stays allocation-free (tracked by [`Self::allocs`]).
#[derive(Default)]
pub struct FetchSlot {
    /// The batch's requests; valid between fill and `finish_batch`.
    pub reqs: Vec<(VertexId, EdgeRequest)>,
    /// Engine-assigned label for the work this slot carries (the chunk
    /// id in the runner); opaque to sources.
    pub tag: usize,
    arena: FetchArena,
    pending: Option<PendingRead>,
}

impl FetchSlot {
    /// Fresh slot with no retained buffers.
    pub fn new() -> Self {
        Self::default()
    }

    /// Decoded edges of the last finished batch, aligned with `reqs`.
    pub fn edges(&self) -> &[VertexEdges] {
        self.arena.edges()
    }

    /// Cumulative heap allocations through the slot's arena.
    pub fn allocs(&self) -> u64 {
        self.arena.allocs()
    }

    /// True while a submitted batch has not been finished yet.
    pub fn in_flight(&self) -> bool {
        self.pending.is_some()
    }
}

/// Abstract supply of per-vertex edge data.
pub trait EdgeSource: Send + Sync {
    /// The in-memory vertex index (degrees, offsets).
    fn index(&self) -> &GraphIndex;

    /// Fetch edge data for a batch of vertices. SEM implementations
    /// overlap the underlying page reads across the whole batch.
    fn fetch_batch(&self, reqs: &[(VertexId, EdgeRequest)]) -> crate::Result<Vec<VertexEdges>>;

    /// Fetch a batch into a reusable per-worker [`FetchArena`]; results
    /// land in `arena.edges()[..reqs.len()]`. This is the engine's hot
    /// path: the SEM, in-memory and service-mode sources all override it
    /// with an implementation that is allocation-free in steady state.
    /// The default falls back to [`Self::fetch_batch`].
    fn fetch_batch_into(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        arena: &mut FetchArena,
    ) -> crate::Result<()> {
        arena.set_batch(self.fetch_batch(reqs)?);
        Ok(())
    }

    /// Fetch a single vertex's edge data.
    fn fetch(&self, v: VertexId, req: EdgeRequest) -> crate::Result<VertexEdges> {
        Ok(self.fetch_batch(&[(v, req)])?.pop().unwrap())
    }

    /// Begin fetching `slot.reqs` without blocking. SEM sources probe
    /// the cache and hand misses to the I/O pool here; the default is a
    /// no-op, meaning all work happens in [`Self::finish_batch`] —
    /// correct for in-memory sources, which have nothing to overlap.
    fn submit_batch(&self, _slot: &mut FetchSlot) -> crate::Result<()> {
        Ok(())
    }

    /// True once the slot's submitted I/O has fully landed, i.e.
    /// [`Self::finish_batch`] will not block. Sources that do all work
    /// synchronously are always ready.
    fn poll_batch(&self, _slot: &mut FetchSlot) -> bool {
        true
    }

    /// Complete the slot: wait for any outstanding I/O and decode
    /// `slot.reqs` into the slot's arena (results via
    /// [`FetchSlot::edges`]). Must also work on a slot that was never
    /// submitted — the default simply performs the synchronous fetch.
    fn finish_batch(&self, slot: &mut FetchSlot) -> crate::Result<()> {
        let FetchSlot { reqs, arena, .. } = slot;
        self.fetch_batch_into(reqs, arena)
    }

    /// Hint that these vertices will be fetched soon.
    fn prefetch(&self, _reqs: &[(VertexId, EdgeRequest)]) {}

    /// I/O statistics (logical requests also counted by MemGraph so the
    /// two modes are comparable).
    fn io_stats(&self) -> &Arc<IoStats>;

    /// Bytes of graph data resident in memory (index + any cached or
    /// fully-loaded adjacency) — the paper's memory-consumption metric.
    fn resident_bytes(&self) -> u64;
}

/// Semi-external-memory graph: index in RAM, adjacency on disk.
pub struct SemGraph {
    index: GraphIndex,
    adj: SemFile,
    stats: Arc<IoStats>,
}

impl SemGraph {
    /// Open `<base>.gy-idx` / `<base>.gy-adj` with a page cache of
    /// `cache_bytes` and the given I/O pool configuration.
    pub fn open(base: &Path, cache_bytes: usize, io: IoConfig) -> crate::Result<Self> {
        let stats = Arc::new(IoStats::new());
        let cache = Arc::new(PageCache::new(cache_bytes, stats.clone()));
        let pool = Arc::new(IoPool::new(io, stats));
        Self::open_shared(base, cache, pool, 0)
    }

    /// Open on an existing substrate (page cache + I/O pool) shared with
    /// other graphs — service mode. `key_base` namespaces this file's
    /// pages inside the shared cache; the
    /// [`crate::service::GraphRegistry`] hands out disjoint bases. The
    /// graph's stats handle is the substrate-wide one
    /// (`cache.stats()`); per-job attribution comes from
    /// [`Self::fetch_batch_tracked`].
    pub fn open_shared(
        base: &Path,
        cache: Arc<PageCache>,
        pool: Arc<IoPool>,
        key_base: u64,
    ) -> crate::Result<Self> {
        let stats = cache.stats().clone();
        let idx_path = base.with_extension("gy-idx");
        let adj_path = base.with_extension("gy-adj");
        let idx_bytes = std::fs::read(&idx_path)?;
        let header = crate::graph::format::GraphHeader::decode(&idx_bytes)?;
        let mut adj = SemFile::open_keyed(&adj_path, cache, pool, key_base)?;
        let index = if header.checksums {
            // The index is RAM-resident and read exactly once, so it is
            // verified in full here at open; a corrupt index fails loudly
            // before any job can run on it.
            let footer = ChecksumFooter::from_bytes(&idx_bytes)
                .with_context(|| format!("checksum footer of {}", idx_path.display()))?;
            let data = &idx_bytes[..footer.data_len as usize];
            for p in 0..footer.npages() {
                ensure!(
                    footer.page_ok(p, &data[p as usize * CHECKSUM_PAGE..]),
                    "checksum mismatch on page {p} of {}",
                    idx_path.display()
                );
            }
            // The adjacency footer is loaded via direct positioned reads
            // — outside the pool and the stats — and installed on the
            // SemFile, which shrinks its visible length to the data
            // region: page requests, EOF clamping and bytes_read stay
            // byte-identical to a plain image, and every page entering
            // the cache is verified against its crc.
            let adj_file = std::fs::File::open(&adj_path)
                .with_context(|| format!("open {}", adj_path.display()))?;
            let adj_len = adj_file.metadata()?.len();
            let adj_footer = ChecksumFooter::read_from(&adj_file, adj_len)
                .with_context(|| format!("checksum footer of {}", adj_path.display()))?;
            let (data_len, crcs) = adj_footer.into_parts();
            adj.install_checksums(PageChecksums::new(data_len, crcs));
            GraphIndex::decode(data)?
        } else {
            GraphIndex::decode(&idx_bytes)?
        };
        Ok(SemGraph { index, adj, stats })
    }

    /// The underlying SEM file (exposed for substrate benchmarks).
    pub fn adj_file(&self) -> &SemFile {
        &self.adj
    }

    /// [`EdgeSource::fetch_batch`] with per-job attribution: all I/O
    /// counters this batch moves are recorded into `job` as well as the
    /// graph's own (substrate-wide) stats. Service-mode jobs wrap the
    /// shared graph in a [`crate::service::JobGraph`] that routes every
    /// fetch through here with its private [`IoStats`].
    pub fn fetch_batch_tracked(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        job: Option<&IoStats>,
    ) -> crate::Result<Vec<VertexEdges>> {
        let mut arena = FetchArena::new();
        self.fetch_batch_tracked_into(reqs, job, &mut arena)?;
        let FetchArena { mut edges, batch_len, .. } = arena;
        edges.truncate(batch_len);
        Ok(edges)
    }

    /// The zero-copy, arena-reusing fetch: byte ranges, page views and
    /// decoded neighbor lists all live in `arena`, so a steady-state
    /// batch over cached pages performs no heap allocation. Per-job
    /// attribution is identical to [`Self::fetch_batch_tracked`] — every
    /// counter the batch moves also lands in `job` when given.
    pub fn fetch_batch_tracked_into(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        job: Option<&IoStats>,
        arena: &mut FetchArena,
    ) -> crate::Result<()> {
        arena.ranges.clear();
        let cap = arena.ranges.capacity();
        arena.ranges.extend(reqs.iter().map(|&(v, r)| self.index.byte_range(v, r)));
        if arena.ranges.capacity() != cap {
            arena.allocs += 1;
        }
        let logical: u64 = arena.ranges.iter().map(|&(_, len)| len as u64).sum();
        self.stats.add_logical_bytes(logical);
        if let Some(j) = job {
            j.add_logical_bytes(logical);
        }
        let cap = arena.bufs.capacity();
        self.adj.read_ranges_into(&arena.ranges, job, &mut arena.scratch, &mut arena.bufs)?;
        if arena.bufs.capacity() != cap {
            arena.allocs += 1;
        }
        arena.decode_bufs(reqs, &self.index);
        Ok(())
    }

    /// [`EdgeSource::submit_batch`] with per-job attribution: computes
    /// the batch's byte ranges, counts logical bytes, probes the cache
    /// and hands misses to the pool — all without blocking.
    pub fn submit_batch_tracked(
        &self,
        slot: &mut FetchSlot,
        job: Option<&IoStats>,
    ) -> crate::Result<()> {
        let FetchSlot { reqs, arena, pending, .. } = slot;
        arena.ranges.clear();
        let cap = arena.ranges.capacity();
        arena.ranges.extend(reqs.iter().map(|&(v, r)| self.index.byte_range(v, r)));
        if arena.ranges.capacity() != cap {
            arena.allocs += 1;
        }
        let logical: u64 = arena.ranges.iter().map(|&(_, len)| len as u64).sum();
        self.stats.add_logical_bytes(logical);
        if let Some(j) = job {
            j.add_logical_bytes(logical);
        }
        *pending = Some(self.adj.submit_ranges(&arena.ranges, job)?);
        Ok(())
    }

    /// [`EdgeSource::poll_batch`] with per-job attribution.
    pub fn poll_batch_tracked(&self, slot: &mut FetchSlot, job: Option<&IoStats>) -> bool {
        match slot.pending.as_mut() {
            Some(p) => self.adj.poll_ranges(p, job),
            None => true,
        }
    }

    /// [`EdgeSource::finish_batch`] with per-job attribution. A slot
    /// that was never submitted falls back to the synchronous fetch.
    pub fn finish_batch_tracked(
        &self,
        slot: &mut FetchSlot,
        job: Option<&IoStats>,
    ) -> crate::Result<()> {
        match slot.pending.take() {
            Some(p) => {
                let FetchSlot { reqs, arena, .. } = slot;
                let cap = arena.bufs.capacity();
                self.adj.finish_ranges(&arena.ranges, p, job, &mut arena.scratch, &mut arena.bufs)?;
                if arena.bufs.capacity() != cap {
                    arena.allocs += 1;
                }
                arena.decode_bufs(reqs, &self.index);
                Ok(())
            }
            None => {
                let FetchSlot { reqs, arena, .. } = slot;
                self.fetch_batch_tracked_into(reqs, job, arena)
            }
        }
    }
}

impl EdgeSource for SemGraph {
    fn index(&self) -> &GraphIndex {
        &self.index
    }

    fn fetch_batch(&self, reqs: &[(VertexId, EdgeRequest)]) -> crate::Result<Vec<VertexEdges>> {
        self.fetch_batch_tracked(reqs, None)
    }

    fn fetch_batch_into(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        arena: &mut FetchArena,
    ) -> crate::Result<()> {
        self.fetch_batch_tracked_into(reqs, None, arena)
    }

    fn submit_batch(&self, slot: &mut FetchSlot) -> crate::Result<()> {
        self.submit_batch_tracked(slot, None)
    }

    fn poll_batch(&self, slot: &mut FetchSlot) -> bool {
        self.poll_batch_tracked(slot, None)
    }

    fn finish_batch(&self, slot: &mut FetchSlot) -> crate::Result<()> {
        self.finish_batch_tracked(slot, None)
    }

    fn prefetch(&self, reqs: &[(VertexId, EdgeRequest)]) {
        let ranges: Vec<(u64, usize)> =
            reqs.iter().map(|&(v, r)| self.index.byte_range(v, r)).collect();
        self.adj.prefetch(&ranges);
    }

    fn io_stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    fn resident_bytes(&self) -> u64 {
        // Index entries only: the page cache's resident bytes are
        // accounted by the coordinator, which owns the cache capacity
        // knob (resident <= capacity by construction).
        self.index.num_vertices() as u64 * self.index.entry_len() as u64
    }
}

/// Fully in-memory graph: the packed image in a RAM buffer.
pub struct MemGraph {
    index: GraphIndex,
    adj: Vec<u8>,
    stats: Arc<IoStats>,
}

impl MemGraph {
    /// Wrap a built RAM image.
    pub fn from_image(img: RamImage) -> Self {
        MemGraph { index: img.index, adj: img.adj, stats: Arc::new(IoStats::new()) }
    }

    /// Build directly from an edge list.
    pub fn from_edges(n: usize, edges: &[(VertexId, VertexId)], directed: bool) -> Self {
        let mut b = super::builder::GraphBuilder::new(n, directed);
        b.add_edges(edges);
        Self::from_image(b.build_ram())
    }
}

impl EdgeSource for MemGraph {
    fn index(&self) -> &GraphIndex {
        &self.index
    }

    fn fetch_batch(&self, reqs: &[(VertexId, EdgeRequest)]) -> crate::Result<Vec<VertexEdges>> {
        self.stats.add_read_request(reqs.len() as u64);
        self.stats.add_logical_bytes(
            reqs.iter().map(|&(v, r)| self.index.byte_range(v, r).1 as u64).sum(),
        );
        let enc = self.index.encoding();
        Ok(reqs
            .iter()
            .map(|&(v, r)| {
                let (off, len) = self.index.byte_range(v, r);
                VertexEdges::decode(
                    &self.adj[off as usize..off as usize + len],
                    self.index.in_deg(v),
                    self.index.out_deg(v),
                    r,
                    enc,
                )
            })
            .collect())
    }

    fn fetch_batch_into(
        &self,
        reqs: &[(VertexId, EdgeRequest)],
        arena: &mut FetchArena,
    ) -> crate::Result<()> {
        self.stats.add_read_request(reqs.len() as u64);
        self.stats.add_logical_bytes(
            reqs.iter().map(|&(v, r)| self.index.byte_range(v, r).1 as u64).sum(),
        );
        arena.decode_image(reqs, &self.index, &self.adj);
        Ok(())
    }

    fn io_stats(&self) -> &Arc<IoStats> {
        &self.stats
    }

    fn resident_bytes(&self) -> u64 {
        (self.index.num_vertices() * self.index.entry_len() + self.adj.len()) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::builder::GraphBuilder;
    use crate::graph::gen;

    fn build_files(
        n: usize,
        edges: &[(VertexId, VertexId)],
        directed: bool,
        tag: &str,
    ) -> std::path::PathBuf {
        let base = std::env::temp_dir().join(format!(
            "graphyti-source-{}-{tag}",
            std::process::id()
        ));
        let mut b = GraphBuilder::new(n, directed);
        b.add_edges(edges);
        b.build_files(&base).unwrap();
        base
    }

    #[test]
    fn sem_and_mem_agree() {
        let n = 300;
        let edges = gen::rmat(9, 3000, 5);
        let edges: Vec<_> = edges.into_iter().filter(|&(u, v)| (u as usize) < n && (v as usize) < n).collect();
        let base = build_files(n, &edges, true, "agree");
        let sem = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let mem = MemGraph::from_edges(n, &edges, true);
        assert_eq!(sem.index().num_edges(), mem.index().num_edges());
        for v in 0..n as VertexId {
            for req in [EdgeRequest::In, EdgeRequest::Out, EdgeRequest::Both] {
                let a = sem.fetch(v, req).unwrap();
                let b = mem.fetch(v, req).unwrap();
                assert_eq!(a.in_neighbors, b.in_neighbors, "v={v} {req:?}");
                assert_eq!(a.out_neighbors, b.out_neighbors, "v={v} {req:?}");
            }
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn sem_batch_fetch_counts_requests() {
        let edges = gen::cycle(100);
        let base = build_files(100, &edges, true, "batch");
        let sem = SemGraph::open(&base, 256 * 4096, IoConfig::default()).unwrap();
        let reqs: Vec<_> = (0..50u32).map(|v| (v, EdgeRequest::Out)).collect();
        let out = sem.fetch_batch(&reqs).unwrap();
        assert_eq!(out.len(), 50);
        for (v, ve) in out.iter().enumerate() {
            assert_eq!(ve.out_neighbors, vec![(v as u32 + 1) % 100]);
        }
        assert_eq!(sem.io_stats().snapshot().read_requests, 50);
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn sem_v2_image_agrees_and_reads_fewer_bytes() {
        let n = 300;
        let edges = gen::rmat(9, 3000, 5);
        let edges: Vec<_> = edges
            .into_iter()
            .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
            .collect();
        let base2 = std::env::temp_dir()
            .join(format!("graphyti-source-{}-v2", std::process::id()));
        let mut b = GraphBuilder::new(n, true);
        b.add_edges(&edges).format_version(crate::graph::format::VERSION_V2);
        b.build_files(&base2).unwrap();
        let sem2 = SemGraph::open(&base2, 64 * 4096, IoConfig::default()).unwrap();
        let mem = MemGraph::from_edges(n, &edges, true);
        for v in 0..n as VertexId {
            for req in [EdgeRequest::In, EdgeRequest::Out, EdgeRequest::Both] {
                let a = sem2.fetch(v, req).unwrap();
                let b = mem.fetch(v, req).unwrap();
                assert_eq!(a.in_neighbors, b.in_neighbors, "v={v} {req:?}");
                assert_eq!(a.out_neighbors, b.out_neighbors, "v={v} {req:?}");
            }
        }
        // compressed sections => strictly fewer logical bytes than the
        // same fetches against fixed-width v1 records would request
        let v1_logical: u64 = (0..n as VertexId)
            .map(|v| 2 * 4 * (mem.index().degree(v) as u64))
            .sum();
        let got = sem2.io_stats().snapshot().logical_bytes;
        assert!(got < v1_logical, "v2 logical {got} !< v1 equivalent {v1_logical}");
        let _ = std::fs::remove_file(base2.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base2.with_extension("gy-adj"));
    }

    #[test]
    fn arena_fetch_agrees_with_owned_fetch_both_sources() {
        let n = 300;
        let edges = gen::rmat(9, 3000, 5);
        let edges: Vec<_> = edges
            .into_iter()
            .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
            .collect();
        let base = build_files(n, &edges, true, "arena-agree");
        let sem = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let mem = MemGraph::from_edges(n, &edges, true);
        let reqs: Vec<_> = (0..n as VertexId)
            .map(|v| {
                let r = match v % 3 {
                    0 => EdgeRequest::In,
                    1 => EdgeRequest::Out,
                    _ => EdgeRequest::Both,
                };
                (v, r)
            })
            .collect();
        let mut arena = FetchArena::new();
        for src in [&sem as &dyn EdgeSource, &mem as &dyn EdgeSource] {
            let owned = src.fetch_batch(&reqs).unwrap();
            src.fetch_batch_into(&reqs, &mut arena).unwrap();
            assert_eq!(arena.edges().len(), reqs.len());
            for (i, e) in arena.edges().iter().enumerate() {
                assert_eq!(e.in_neighbors, owned[i].in_neighbors, "req {i}");
                assert_eq!(e.out_neighbors, owned[i].out_neighbors, "req {i}");
            }
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn steady_state_cached_fetch_is_allocation_free() {
        // the acceptance criterion: once the cache and the arena are
        // warm, fetching a batch of cached vertices performs zero heap
        // allocations — the FetchArena counter must stay exactly flat
        let n = 256;
        let edges = gen::rmat(8, 2500, 13);
        let edges: Vec<_> = edges
            .into_iter()
            .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
            .collect();
        let base = build_files(n, &edges, true, "arena-flat");
        // cache big enough to hold the whole image: all rounds after the
        // first are pure hits
        let sem = SemGraph::open(&base, 1024 * 4096, IoConfig::default()).unwrap();
        let reqs: Vec<_> = (0..n as VertexId).map(|v| (v, EdgeRequest::Both)).collect();
        let mut arena = FetchArena::new();
        // warm-up rounds: pages stream in, buffers grow to steady size
        sem.fetch_batch_into(&reqs, &mut arena).unwrap();
        sem.fetch_batch_into(&reqs, &mut arena).unwrap();
        let warm = arena.allocs();
        for round in 0..20 {
            sem.fetch_batch_into(&reqs, &mut arena).unwrap();
            assert_eq!(
                arena.allocs(),
                warm,
                "round {round}: steady-state fetch must not allocate"
            );
        }
        // and the data is still right
        let owned = sem.fetch_batch(&reqs).unwrap();
        for (i, e) in arena.edges().iter().enumerate() {
            assert_eq!(e.out_neighbors, owned[i].out_neighbors);
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn slot_pipeline_agrees_with_sync_fetch() {
        let n = 300;
        let edges = gen::rmat(9, 3000, 5);
        let edges: Vec<_> = edges
            .into_iter()
            .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
            .collect();
        let base = build_files(n, &edges, true, "slot-agree");
        let sem = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let mem = MemGraph::from_edges(n, &edges, true);
        let reqs: Vec<_> = (0..n as VertexId)
            .map(|v| {
                let r = match v % 3 {
                    0 => EdgeRequest::In,
                    1 => EdgeRequest::Out,
                    _ => EdgeRequest::Both,
                };
                (v, r)
            })
            .collect();
        for src in [&sem as &dyn EdgeSource, &mem as &dyn EdgeSource] {
            let owned = src.fetch_batch(&reqs).unwrap();
            let mut slot = FetchSlot::new();
            slot.reqs = reqs.clone();
            src.submit_batch(&mut slot).unwrap();
            // backoff ladder instead of a bare yield spin: the wakeup
            // condition is the I/O pool completing the batch, which can
            // be milliseconds out — parking releases the core to the
            // pool threads. The deadline bounds the loop either way.
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
            let mut backoff = crate::util::Backoff::new();
            while !src.poll_batch(&mut slot) {
                assert!(std::time::Instant::now() < deadline, "slot never became ready");
                backoff.snooze();
            }
            src.finish_batch(&mut slot).unwrap();
            assert!(!slot.in_flight());
            assert_eq!(slot.edges().len(), reqs.len());
            for (i, e) in slot.edges().iter().enumerate() {
                assert_eq!(e.in_neighbors, owned[i].in_neighbors, "req {i}");
                assert_eq!(e.out_neighbors, owned[i].out_neighbors, "req {i}");
            }
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn overlapping_slots_finish_in_any_order() {
        let n = 400;
        let edges = gen::rmat(9, 4000, 21);
        let edges: Vec<_> = edges
            .into_iter()
            .filter(|&(u, v)| (u as usize) < n && (v as usize) < n)
            .collect();
        let base = build_files(n, &edges, true, "slot-overlap");
        let sem = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let owned = sem
            .fetch_batch(&(0..n as VertexId).map(|v| (v, EdgeRequest::Out)).collect::<Vec<_>>())
            .unwrap();
        // three in-flight slots over disjoint vertex thirds, finished in
        // reverse submit order
        let mut slots: Vec<FetchSlot> = (0..3)
            .map(|k| {
                let mut s = FetchSlot::new();
                s.tag = k;
                s.reqs = (0..n as VertexId)
                    .filter(|v| *v as usize % 3 == k)
                    .map(|v| (v, EdgeRequest::Out))
                    .collect();
                sem.submit_batch(&mut s).unwrap();
                s
            })
            .collect();
        while let Some(mut s) = slots.pop() {
            sem.finish_batch(&mut s).unwrap();
            for (&(v, _), e) in s.reqs.iter().zip(s.edges()) {
                assert_eq!(e.out_neighbors, owned[v as usize].out_neighbors, "v={v}");
            }
        }
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn finish_without_submit_falls_back_to_sync_fetch() {
        let edges = gen::cycle(64);
        let base = build_files(64, &edges, true, "slot-nosubmit");
        let sem = SemGraph::open(&base, 64 * 4096, IoConfig::default()).unwrap();
        let mut slot = FetchSlot::new();
        slot.reqs = vec![(5, EdgeRequest::Out), (6, EdgeRequest::Out)];
        sem.finish_batch(&mut slot).unwrap();
        assert_eq!(slot.edges()[0].out_neighbors, vec![6]);
        assert_eq!(slot.edges()[1].out_neighbors, vec![7]);
        let _ = std::fs::remove_file(base.with_extension("gy-idx"));
        let _ = std::fs::remove_file(base.with_extension("gy-adj"));
    }

    #[test]
    fn mem_resident_exceeds_sem_index_only() {
        let n = 2000;
        let edges = gen::rmat(11, 30_000, 3);
        let edges: Vec<_> = edges.into_iter().filter(|&(u, v)| (u as usize) < n && (v as usize) < n).collect();
        let mem = MemGraph::from_edges(n, &edges, true);
        // in-memory must hold all adjacency; SEM index-only is far smaller
        let sem_index_bytes = n as u64 * 16;
        assert!(mem.resident_bytes() > 3 * sem_index_bytes);
    }
}
